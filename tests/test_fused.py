"""Fused single-dispatch decode step: token-for-token parity with the
unfused scheduler path.

The fused step moves the whole serving epilogue — seeded sampling,
stop/eos/budget/context checks, the position advance — onto the device;
the unfused path computes the same decisions on the host from the raw
logits. Every test drives BOTH paths over the same queue and asserts
identical tokens and identical finish reasons, across:

* all six cache families (dense/moe/vlm/audio/ssm/hybrid), with greedy,
  sampled and stop-token requests in one queue — including a stop id
  that hits MID-stream (learned from a probe run) and a stop id that
  appears in the PROMPT (which must never trigger);
* retirement landing in the same scheduler step as a waiting request's
  admission (slot churn exercises the device-state rebuild);
* all three server types: the paged+chunked SlotServer, the stacked
  MixtureSlotServer, and the top-1 DecentralizedSlotServer.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.router import CentroidRouter, RouterConfig
from repro.models import build_model
from repro.serve.api import SamplingParams
from repro.serve.scheduler import (DecentralizedSlotServer,
                                   MixtureSlotServer, Request, SlotServer)

FAMILY_ARCHS = [
    ("qwen3_8b", "dense"),
    ("deepseek_moe_16b", "moe"),
    ("internvl2_2b", "vlm"),
    ("whisper_small", "audio"),
    ("xlstm_125m", "ssm"),
    ("zamba2_2_7b", "hybrid"),
]

PROMPT_LENS = (7, 11, 5, 9)


def _extras(cfg, rng):
    extras = {}
    if cfg.family == "vlm":
        extras["patches"] = rng.normal(
            size=(cfg.n_patches, cfg.vision_dim)).astype(np.float32)
    if cfg.family == "audio":
        extras["frames"] = rng.normal(
            size=(cfg.n_audio_frames, cfg.audio_dim)).astype(np.float32)
    return extras


def _prompts(cfg, seed=42):
    rng = np.random.default_rng(seed)
    ps = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
          for n in PROMPT_LENS]
    ex = [_extras(cfg, rng) for _ in PROMPT_LENS]
    return ps, ex


def _mixed_queue(cfg, stop_id, feats=None):
    """Greedy + sampled + stop-mid-stream + stop-id-in-prompt, rebuilt
    identically (fixed seed) for each server so runs are comparable."""
    ps, ex = _prompts(cfg)
    f = (lambda i: feats[i]) if feats is not None else (lambda i: None)
    return [
        Request(0, ps[0], 6, extras=ex[0], features=f(0)),
        Request(1, ps[1], 5, extras=ex[1], features=f(1),
                params=SamplingParams(max_new=5, temperature=0.8,
                                      top_k=8, seed=123)),
        # the probe guarantees this id is GENERATED mid-stream: the
        # request must retire early with finish_reason == "stop"
        Request(2, ps[2], 8, extras=ex[2], features=f(2),
                params=SamplingParams(max_new=8, stop_token_ids=(stop_id,))),
        # same stop id sitting in the PROMPT: admission must not trigger
        # it (only generated tokens are inspected)
        Request(3, np.append(ps[3], stop_id).astype(np.int32), 4,
                extras=ex[3], features=f(3),
                params=SamplingParams(max_new=4, stop_token_ids=(stop_id,))),
    ]


def _probe_stop_id(mk_server, cfg, feats=None):
    """Second generated token of request 2's solo greedy run — a stop id
    that the full queue's request 2 will emit mid-stream (per-request
    decoding is independent of co-scheduled traffic)."""
    ps, ex = _prompts(cfg)
    f = feats[2] if feats is not None else None
    out = mk_server(False).serve(
        [Request(2, ps[2], 8, extras=ex[2], features=f)])
    assert len(out[2]) >= 2
    return int(out[2][1])


def _assert_pair_parity(mk_server, cfg, feats=None):
    stop_id = _probe_stop_id(mk_server, cfg, feats)
    qf = _mixed_queue(cfg, stop_id, feats)
    got_f = mk_server(True).serve(qf)
    qu = _mixed_queue(cfg, stop_id, feats)
    got_u = mk_server(False).serve(qu)
    assert got_f == got_u, (got_f, got_u)
    for rf, ru in zip(qf, qu):
        assert rf.finish_reason == ru.finish_reason, \
            (rf.rid, rf.finish_reason, ru.finish_reason)
    # the mid-stream stop fired early, on the stop token itself
    assert qf[2].finish_reason == "stop", qf[2].finish_reason
    assert len(qf[2].out) < 8 and qf[2].out[-1] == stop_id
    # the in-prompt stop id did NOT fire at admission: the request decoded
    # its first token, and only a GENERATED occurrence may retire it
    assert len(qf[3].out) >= 1
    if qf[3].finish_reason == "stop":
        assert qf[3].out[-1] == stop_id


@pytest.mark.parametrize("arch,family", FAMILY_ARCHS)
def test_fused_family_parity(arch, family):
    """Contiguous SlotServer, fused vs unfused, for every cache family."""
    cfg = get_smoke_config(arch).reduced(vocab=256)
    assert cfg.family == family
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def mk(fused):
        return SlotServer(model, params, n_slots=2, cache_len=40,
                          fused_step=fused)

    _assert_pair_parity(mk, cfg)


def test_fused_retirement_with_admission():
    """Budgets differing by one make a slot retire while a request is
    still waiting: the fused path's device-state rebuild on the
    retire/admit churn must not perturb any request's tokens."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    ps = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
          for n in (6, 8, 5)]

    def queue():
        return [Request(i, p, m) for i, (p, m) in
                enumerate(zip(ps, (3, 4, 3)))]

    def mk(fused):
        return SlotServer(model, params, n_slots=2, cache_len=32,
                          fused_step=fused)

    qf, qu = queue(), queue()
    assert mk(True).serve(qf) == mk(False).serve(qu)
    for rf, ru in zip(qf, qu):
        assert rf.finish_reason == ru.finish_reason == "length"


def _mixture_setup():
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=128)
    model = build_model(cfg)
    K, Df = 3, 16
    experts = [model.init(jax.random.PRNGKey(k)) for k in range(K)]
    rng = np.random.default_rng(1)
    router = CentroidRouter(
        jnp.asarray(rng.normal(size=(K, Df)), jnp.float32),
        RouterConfig(top_k=2))
    feats = rng.normal(size=(len(PROMPT_LENS), Df)).astype(np.float32)
    return cfg, model, experts, router, feats


def test_fused_paged_chunked_server_parity():
    """Paged + chunked-prefill SlotServer: the fused step co-schedules a
    prefill chunk with the decode dispatch; both halves must agree with
    the unfused scheduler token-for-token."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def mk(fused):
        return SlotServer(model, params, n_slots=2, cache_len=48,
                          page_block=8, chunk=8, fused_step=fused)

    _assert_pair_parity(mk, cfg)


def test_fused_mixture_server_parity():
    """Stacked mixture server: Eq. 27 mixing + epilogue in one dispatch
    must equal the unfused mix-then-host-epilogue path."""
    cfg, model, experts, router, feats = _mixture_setup()

    def mk(fused):
        return MixtureSlotServer(model, experts, router, n_slots=2,
                                 cache_len=24, fused_step=fused)

    _assert_pair_parity(mk, cfg, feats)


def test_fused_decentralized_server_parity():
    """Top-1 decentralized server: every pod's fused step must agree with
    its unfused twin under routed admission."""
    cfg, model, experts, router, feats = _mixture_setup()

    def mk(fused):
        return DecentralizedSlotServer(model, experts, router, n_slots=2,
                                       cache_len=24, strategy="top1",
                                       fused_step=fused)

    _assert_pair_parity(mk, cfg, feats)
