"""repro-lint rule tests: true positives, true negatives, waivers.

Every fixture is a small source file written to ``tmp_path`` and run
through the real ``run_paths`` pipeline — the same path ``python -m
repro.analysis`` takes — so directive parsing, hot/jit scope detection
and waiver bookkeeping are all exercised, not just the rule callbacks.
The true-positive fixtures for host-sync and retrace-hazard are the
regression shapes named in docs/analysis.md: PR 6's greedy-argmax host
sync and the jit-in-a-loop retrace storm.
"""
import textwrap
from pathlib import Path

from repro.analysis.lint import main as lint_main
from repro.analysis.lint import run_paths

REPO = Path(__file__).resolve().parents[1]


def lint(tmp_path, src, rules=None, name="mod.py"):
    p = tmp_path / name
    p.write_text(textwrap.dedent(src))
    return run_paths([str(p)], rules)


def unwaived(findings):
    return [f for f in findings if not f.waived]


# ---------------------------------------------------------------------------
# host-sync
# ---------------------------------------------------------------------------

def test_host_sync_flags_pr6_greedy_argmax(tmp_path):
    """The exact PR 6 incident shape: a hot-class step loop coercing an
    eagerly-computed device argmax — an implicit blocking sync per token."""
    fs = lint(tmp_path, """
        import numpy as np
        import jax.numpy as jnp

        class _SlotTable:
            def _next_tokens(self, scores):
                return np.asarray(jnp.argmax(scores, -1))
    """, rules=["host-sync"])
    assert len(unwaived(fs)) == 1
    assert "host path" in fs[0].msg or "host hot path" in fs[0].msg
    assert fs[0].line == 7


def test_host_sync_flags_eager_dispatch_in_marked_fn(tmp_path):
    fs = lint(tmp_path, """
        import jax.numpy as jnp

        def poll(scores):  # repro: hot-path
            probs = jnp.log(scores)
            return probs
    """, rules=["host-sync"])
    assert len(unwaived(fs)) == 1
    assert "eager" in fs[0].msg


def test_host_sync_flags_truth_test_under_jit(tmp_path):
    fs = lint(tmp_path, """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            if jnp.any(x):
                return x
            return -x
    """, rules=["host-sync"])
    assert len(unwaived(fs)) == 1
    assert "truth-value" in fs[0].msg


def test_host_sync_flags_int_coercion_under_jit(tmp_path):
    fs = lint(tmp_path, """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            n = int(jnp.sum(x))
            return jnp.zeros((4,)) + n
    """, rules=["host-sync"])
    assert any("int" in f.msg and "traced" in f.msg for f in unwaived(fs))


def test_host_sync_clean_on_sanctioned_device_get(tmp_path):
    """The fused-step contract: one jitted dispatch, one explicit
    jax.device_get, then free host coercion of the fetched value."""
    fs = lint(tmp_path, """
        import jax
        import numpy as np

        class _SlotTable:
            def _decode_step(self):
                toks = jax.device_get(self._fstep(self.state))
                return int(np.asarray(toks)[0])
    """, rules=["host-sync"])
    assert unwaived(fs) == []


def test_host_sync_clean_outside_hot_scope(tmp_path):
    """The same eager coercion in an unmarked, non-serving class is not a
    hot-path bug — scope detection keeps the rule quiet there."""
    fs = lint(tmp_path, """
        import numpy as np
        import jax.numpy as jnp

        class OfflineEval:
            def best(self, scores):
                return np.asarray(jnp.argmax(scores, -1))
    """, rules=["host-sync"])
    assert unwaived(fs) == []


def test_host_sync_static_flag_param_not_flagged(tmp_path):
    """A literal-defaulted keyword flag is static under trace — branching
    on it is ordinary Python config, not a concretization hazard."""
    fs = lint(tmp_path, """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def mix(logits, log_space=False):
            if log_space:
                return jnp.exp(logits)
            return logits
    """, rules=["host-sync"])
    assert unwaived(fs) == []


def test_host_sync_waiver(tmp_path):
    fs = lint(tmp_path, """
        import numpy as np
        import jax.numpy as jnp

        class _SlotTable:
            def _next_tokens(self, scores):
                # repro: allow-host-sync
                return np.asarray(jnp.argmax(scores, -1))
    """, rules=["host-sync"])
    assert len(fs) == 1 and fs[0].waived
    assert unwaived(fs) == []


# ---------------------------------------------------------------------------
# retrace-hazard
# ---------------------------------------------------------------------------

def test_retrace_flags_jit_in_loop(tmp_path):
    fs = lint(tmp_path, """
        import jax

        def bench(fns, x):
            outs = []
            for fn in fns:
                outs.append(jax.jit(lambda a: fn(a))(x))
            return outs
    """, rules=["retrace-hazard"])
    assert len(unwaived(fs)) == 1
    assert "loop" in fs[0].msg


def test_retrace_flags_traced_shape_derivation(tmp_path):
    fs = lint(tmp_path, """
        import jax.numpy as jnp

        def pad(x):
            return jnp.zeros(int(jnp.sum(x)))
    """, rules=["retrace-hazard"])
    assert len(unwaived(fs)) == 1


def test_retrace_flags_mutable_static_arg(tmp_path):
    fs = lint(tmp_path, """
        import jax

        def f(x, opts):
            return x

        g = jax.jit(f, static_argnums=(1,))

        def call(x):
            return g(x, {"mode": "fast"})
    """, rules=["retrace-hazard"])
    assert len(unwaived(fs)) >= 1
    assert any("static" in f.msg for f in fs)


def test_retrace_clean_on_setup_jit(tmp_path):
    """jit at construction time (the sanctioned make_*-fns pattern) is the
    fix for the hazard, not an instance of it."""
    fs = lint(tmp_path, """
        import jax

        class Engine:
            def __init__(self, model):
                self._step = jax.jit(model.decode_step)

        def make_serve_fns(model):
            return jax.jit(model.prefill), jax.jit(model.decode_step)
    """, rules=["retrace-hazard"])
    assert unwaived(fs) == []


# ---------------------------------------------------------------------------
# kernel-bounds
# ---------------------------------------------------------------------------

def test_kernel_bounds_flags_unclamped_growth(tmp_path):
    fs = lint(tmp_path, """
        import jax.experimental.pallas as pl

        def make_spec(bps):
            return pl.BlockSpec((1, 8), lambda i, j: (i * bps + 1, 0))
    """, rules=["kernel-bounds"])
    assert len(unwaived(fs)) == 1


def test_kernel_bounds_clean_on_clamped_growth(tmp_path):
    fs = lint(tmp_path, """
        import jax.numpy as jnp
        import jax.experimental.pallas as pl

        def make_spec(bps, nb):
            return pl.BlockSpec(
                (1, 8), lambda i, j: (jnp.minimum(i * bps + j, nb - 1), 0))
    """, rules=["kernel-bounds"])
    assert unwaived(fs) == []


def test_kernel_bounds_clean_on_contracting_floordiv(tmp_path):
    """h // g never exceeds h — the flash kernels' head-group maps pass
    without annotation."""
    fs = lint(tmp_path, """
        import jax.experimental.pallas as pl

        def make_spec(g):
            return pl.BlockSpec((1, 8), lambda h, i: (h // g, 0))
    """, rules=["kernel-bounds"])
    assert unwaived(fs) == []


def test_kernel_bounds_prefetch_ref_needs_annotation(tmp_path):
    src_unannotated = """
        import jax.experimental.pallas as pl

        def make_spec():
            def imap(b, kc, bt_r):
                return (bt_r[b, kc], 0)
            return pl.BlockSpec((1, 8), imap)
    """
    fs = lint(tmp_path, src_unannotated, rules=["kernel-bounds"])
    assert len(unwaived(fs)) == 1
    assert "bt_r" in fs[0].msg

    src_annotated = """
        import jax.experimental.pallas as pl

        def make_spec():
            def imap(b, kc, bt_r):
                # repro: bounds bt_r holds pool ids < the pool's leading
                # dim (allocator invariant)
                return (bt_r[b, kc], 0)
            return pl.BlockSpec((1, 8), imap)
    """
    fs = lint(tmp_path, src_annotated, rules=["kernel-bounds"],
              name="annotated.py")
    assert unwaived(fs) == []


def test_kernel_bounds_waiver(tmp_path):
    fs = lint(tmp_path, """
        import jax.experimental.pallas as pl

        def make_spec(bps):
            # repro: allow-kernel-bounds
            return pl.BlockSpec((1, 8), lambda i, j: (i * bps + 1, 0))
    """, rules=["kernel-bounds"])
    assert len(fs) == 1 and fs[0].waived


# ---------------------------------------------------------------------------
# runner + merged-tree acceptance
# ---------------------------------------------------------------------------

def test_main_exit_codes(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent("""
        import numpy as np
        import jax.numpy as jnp

        class _SlotTable:
            def f(self, s):
                return np.asarray(jnp.argmax(s))
    """))
    assert lint_main([str(bad)]) == 1
    out = capsys.readouterr().out
    assert "[host-sync]" in out and "1 finding(s)" in out

    good = tmp_path / "good.py"
    good.write_text("x = 1\n")
    assert lint_main([str(good)]) == 0


def test_merged_tree_is_clean():
    """The acceptance bar: zero unwaived findings over src/ and
    benchmarks/, and zero waivers at all inside the serving hot path —
    including the telemetry layer (``src/repro/obs``), which stamps the
    scheduler's hot loop host-side and so must be clean by construction
    (it imports no jax), never by waiver."""
    fs = run_paths([str(REPO / "src"), str(REPO / "benchmarks")])
    bad = [f.format() for f in fs if not f.waived]
    assert bad == [], "\n".join(bad)
    hot_waivers = [f.format() for f in fs
                   if f.waived and ("serve" in str(f.path)
                                    or "obs" in str(f.path))]
    assert hot_waivers == [], "\n".join(hot_waivers)
    for d in ("serve", "obs"):
        for p in (REPO / "src" / "repro" / d).glob("*.py"):
            assert "repro: allow-" not in p.read_text(), \
                f"waiver comment in hot-path module {p}"


def test_obs_imports_no_jax():
    """The telemetry package's structural lint guarantee: pure host
    code. No module under src/repro/obs may import jax (directly or via
    ``from jax``) — span stamping happens at scheduler boundaries only,
    and keeping jax out of the package makes 'no device syncs inside
    telemetry' a property, not a review item."""
    for p in (REPO / "src" / "repro" / "obs").glob("*.py"):
        text = p.read_text()
        assert "import jax" not in text and "from jax" not in text, \
            f"telemetry module {p} imports jax"
