"""End-to-end dry-run smoke: runs repro.launch.dryrun in a SUBPROCESS (the
512-placeholder-device env must not leak into this test process) for the
smallest assigned arch, both modes, and checks the artifact contract."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("mode,mesh", [("dense", "single"),
                                       ("decentralized", "multi")])
def test_dryrun_smallest_case(tmp_path, mode, mesh):
    out = str(tmp_path)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "xlstm_125m",
         "--shape", "train_4k", "--mesh", mesh, "--mode", mode,
         "--out", out],
        env=env, capture_output=True, text=True, timeout=480, cwd=REPO)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    case = f"xlstm_125m.train_4k.{mesh}.{mode}"
    with open(os.path.join(out, case + ".json")) as f:
        rec = json.load(f)
    assert rec["status"] == "ok"
    assert rec["n_devices"] == (512 if mesh == "multi" else 256)
    assert rec["roofline"]["bottleneck"] in ("compute", "memory",
                                             "collective")
    assert rec["cost"]["flops"] > 0
    if mode == "decentralized":
        # the paper's invariant, from the compiled module
        assert rec["collectives"]["cross_pod_bytes"] == 0
        assert rec["collectives"]["cross_pod_ops"] == 0
