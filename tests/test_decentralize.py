"""The headline theorem (paper §4.3): exact equality between the global
generating velocity and the router-weighted sum of expert velocities."""
import numpy as np
import pytest
from _hyp import given, settings, st

import jax.numpy as jnp

from repro.core.decentralize import (ClusterSplit, decomposition_residual,
                                     mix_expert_distributions, router_weights,
                                     topk_filter_renorm)
from repro.core.dfm import enumerate_states, n_states


def make_split(d, N, K, rng, mask_id):
    S = n_states(d, N)
    states = enumerate_states(d, N)
    q = rng.random(S)
    q[(states == mask_id).any(1)] = 0.0          # mask never in targets
    q[rng.random(S) < 0.3] = 0.0                 # sparse support
    if q.sum() == 0:
        valid = np.where(~(states == mask_id).any(1))[0]
        q[valid[0]] = 1.0
    q = q / q.sum()
    assignment = rng.integers(0, K, size=S)
    # ensure every cluster owns at least one supported state when possible
    supp = np.where(q > 0)[0]
    for k in range(min(K, len(supp))):
        assignment[supp[k]] = k
    return ClusterSplit(q=jnp.asarray(q), assignment=assignment, K=K)


@pytest.mark.parametrize("d,N,P,K", [(3, 3, 0, 2), (3, 3, 1, 3), (2, 4, 0, 2),
                                     (4, 2, 0, 4)])
def test_decomposition_exact(d, N, P, K):
    """u_global == Σ_k r_k · u_expert_k at every timestep, exactly."""
    rng = np.random.default_rng(0)
    mask_id = d - 1
    split = make_split(d, N, K, rng, mask_id)
    for t in range(N - P):
        res = decomposition_residual(split, P, t, d, N, mask_id)
        assert float(res) < 1e-12


def test_router_weights_are_posterior():
    """Router weights are a proper posterior: nonneg, sum to 1 over k."""
    d, N, P, K = 3, 3, 0, 3
    rng = np.random.default_rng(1)
    split = make_split(d, N, K, rng, d - 1)
    for t in range(N):
        r = np.asarray(router_weights(split, P, t, d, N, d - 1))
        assert (r >= -1e-15).all()
        np.testing.assert_allclose(r.sum(0), 1.0, atol=1e-12)


def test_priors_and_cluster_targets_consistent():
    d, N, K = 3, 3, 2
    rng = np.random.default_rng(2)
    split = make_split(d, N, K, rng, d - 1)
    prior = np.asarray(split.prior())
    np.testing.assert_allclose(prior.sum(), 1.0, atol=1e-12)
    # mixture of cluster targets with prior weights == global target
    mix = sum(prior[k] * np.asarray(split.cluster_target(k))
              for k in range(K))
    np.testing.assert_allclose(mix, np.asarray(split.q), atol=1e-12)


@settings(max_examples=15, deadline=None)
@given(K=st.integers(2, 5), seed=st.integers(0, 10_000),
       t=st.integers(0, 2))
def test_property_decomposition(K, seed, t):
    d, N, P = 3, 3, 0
    rng = np.random.default_rng(seed)
    split = make_split(d, N, K, rng, d - 1)
    res = decomposition_residual(split, P, min(t, N - 1), d, N, d - 1)
    assert float(res) < 1e-10


# ---------------------------------------------------------------------------
# Production-form mixing utilities
# ---------------------------------------------------------------------------

def test_topk_filter_renorm():
    w = jnp.asarray([[0.5, 0.1], [0.3, 0.6], [0.2, 0.3]])  # (K=3, B=2)
    out = np.asarray(topk_filter_renorm(w, 1))
    np.testing.assert_allclose(out[:, 0], [1.0, 0.0, 0.0])
    np.testing.assert_allclose(out[:, 1], [0.0, 1.0, 0.0])
    out2 = np.asarray(topk_filter_renorm(w, 2))
    np.testing.assert_allclose(out2.sum(0), 1.0, atol=1e-12)
    assert (out2 > 0).sum() == 4
    # top-k == K is the identity (after normalization)
    out3 = np.asarray(topk_filter_renorm(w, 3))
    np.testing.assert_allclose(out3, np.asarray(w / w.sum(0)), atol=1e-12)


def test_mix_expert_distributions_is_convex():
    rng = np.random.default_rng(3)
    K, B, V = 4, 5, 7
    probs = rng.random((K, B, V))
    probs /= probs.sum(-1, keepdims=True)
    w = rng.random((K, B))
    w /= w.sum(0, keepdims=True)
    mixed = np.asarray(mix_expert_distributions(jnp.asarray(probs),
                                                jnp.asarray(w)))
    np.testing.assert_allclose(mixed.sum(-1), 1.0, atol=1e-12)
    assert (mixed >= 0).all()
    assert mixed.max() <= probs.max() + 1e-12
