"""MoE layer invariants (hypothesis property tests) — the dispatch/combine
machinery must conserve tokens and respect capacity."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.moe import (_capacity, load_balance_stats, moe_ffn,
                              moe_specs, route_topk)
from repro.models.params import init_params


def make_cfg(E, K, cf=8.0, d=32, fe=16):
    return ModelConfig(arch_id="t", family="moe", n_layers=1, d_model=d,
                       n_heads=2, n_kv_heads=2, d_ff=fe, vocab=64,
                       moe=MoEConfig(n_experts=E, top_k=K, d_ff_expert=fe,
                                     capacity_factor=cf),
                       param_dtype="float32", compute_dtype="float32")


@settings(max_examples=10, deadline=None)
@given(E=st.integers(2, 8), K=st.integers(1, 3), B=st.integers(1, 3),
       S=st.sampled_from([4, 8, 16]), seed=st.integers(0, 100))
def test_property_moe_finite_and_shaped(E, K, B, S, seed):
    K = min(K, E)
    cfg = make_cfg(E, K)
    params = init_params(jax.random.PRNGKey(seed), moe_specs(cfg),
                         jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, S, cfg.d_model))
    out = moe_ffn(params, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())


def test_route_topk_distinct_and_normalized():
    logits = jax.random.normal(jax.random.PRNGKey(0), (64, 8))
    w, idx = route_topk(logits, 3)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-6)
    # top-k indices are distinct per token
    idx = np.asarray(idx)
    for row in idx:
        assert len(set(row.tolist())) == 3


def test_capacity_drop_changes_only_dropped_tokens():
    """With cf large enough nothing drops; shrinking cf must only zero the
    contribution of over-capacity tokens (never corrupt kept ones)."""
    cfg_hi = make_cfg(2, 1, cf=64.0)
    cfg_lo = make_cfg(2, 1, cf=0.25)
    params = init_params(jax.random.PRNGKey(2), moe_specs(cfg_hi),
                         jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, cfg_hi.d_model))
    hi = moe_ffn(params, x, cfg_hi)
    lo = moe_ffn(params, x, cfg_lo)
    same = np.isclose(np.asarray(hi), np.asarray(lo), atol=1e-6).all(-1)
    dropped = ~same
    # dropped tokens produce exactly the shared-expert output (here: zero)
    assert dropped.any()
    np.testing.assert_allclose(np.asarray(lo)[dropped], 0.0, atol=1e-6)


def test_capacity_formula():
    assert _capacity(128, 8, 2, 1.0) == 32
    assert _capacity(1, 64, 6, 1.25) == 1     # decode: at least 1


def test_load_balance_stats():
    E = 8
    logits = jnp.tile(jnp.arange(E, dtype=jnp.float32), (32, 1))
    stats = load_balance_stats(logits, 2)     # everyone picks experts 6,7
    assert float(stats["load_entropy"]) < 0.5
    balanced = jax.random.normal(jax.random.PRNGKey(0), (4096, E))
    stats2 = load_balance_stats(balanced, 2)
    assert float(stats2["load_entropy"]) > 0.95
