"""Radix prefix cache correctness.

* A prefix-cached ``SlotServer`` must produce greedy outputs identical to
  the uncached path for every participating family — including prefix
  boundaries that split a page block — while actually skipping prefill
  work (the stats prove the hit happened).
* Copy-on-write discipline: two requests share a prefix then diverge with
  no cross-contamination; a block-aligned fully-cached prompt recomputes
  its final block into a private block (shared blocks are never written).
* Eviction: admission under pool pressure evicts LRU unreferenced cached
  blocks before making requests wait, leaves-first, never touching blocks
  a live request maps.
* Requests with different modality extras (VLM patches) must never share
  blocks even with identical token ids.
* Recurrent families (ssm/hybrid) degrade to the uncached path.
* Per-request seeded sampling: deterministic given the seed, independent
  of co-scheduled traffic; top_k=1 coincides with greedy; greedy requests
  in a mixed batch are unaffected.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.router import CentroidRouter, RouterConfig
from repro.models import build_model
from repro.serve.prefix_cache import PrefixCache, block_keys
from repro.serve.scheduler import (BlockAllocator, DecentralizedSlotServer,
                                   MixtureSlotServer, Request, SlotServer)

from test_scheduler import make_requests


# ---------------------------------------------------------------------------
# PrefixCache unit tests (no model)
# ---------------------------------------------------------------------------

def keys_of(tokens, bs, n_blocks):
    return block_keys(np.asarray(tokens, np.int32), {}, bs, n_blocks)


def test_radix_match_insert_and_refcounts():
    alloc = BlockAllocator(10)
    cache = PrefixCache(alloc, 4)
    toks = list(range(12))
    keys = keys_of(toks, 4, 3)
    assert cache.match(keys, 12) == []            # cold
    blocks = alloc.alloc(3)
    assert cache.insert(keys, blocks) == 3
    # full-block hits, capped so >= 1 position is re-prefilled
    assert cache.match(keys, 12) == blocks[:2]    # 12 % 4 == 0 → cap at 2
    assert cache.match(keys, 13) == blocks[:3]
    assert cache.match(keys_of(toks[:8] + [99] * 4, 4, 3), 13) == blocks[:2]
    assert cache.match(keys_of([99] + toks[1:], 4, 3), 13) == []
    # owner's refs: releasing parks blocks on the LRU list, keeps them
    for b in blocks:
        assert cache.release(b)
    assert cache.n_evictable == 3 and cache.n_cached == 3
    assert not cache.release(alloc.alloc(1)[0])   # untracked block
    # re-acquiring removes from LRU
    cache.acquire(blocks[:2])
    assert cache.n_evictable == 1


def test_radix_eviction_is_lru_and_leaves_first():
    alloc = BlockAllocator(8)
    cache = PrefixCache(alloc, 2)
    a = alloc.alloc(2)                            # chain A: two blocks
    cache.insert(keys_of([1, 2, 3, 4], 2, 2), a)
    b = alloc.alloc(2)                            # chain B
    cache.insert(keys_of([5, 6, 7, 8], 2, 2), b)
    for blk in a + b:
        cache.release(blk)                        # LRU: a0 a1 b0 b1
    # a0 is oldest but an interior node — its leaf a1 must go first
    assert cache.evict(1) == 1
    assert cache.evicted_blocks == 1 and a[1] not in cache._by_block
    assert cache.match(keys_of([1, 2, 3, 4], 2, 2), 5) == [a[0]]
    # touching chain A makes chain B the eviction victim
    cache.acquire([a[0]])
    cache.release(a[0])
    assert cache.evict(2) == 2
    assert cache.n_cached == 1 and cache.match(
        keys_of([5, 6, 7, 8], 2, 2), 5) == []
    # evicted blocks actually returned to the allocator: 7 usable,
    # 4 allocated, 3 evicted back
    assert alloc.n_free == 6


def test_block_keys_extras_digest_roots_the_path():
    toks = np.arange(8, dtype=np.int32)
    plain = block_keys(toks, {}, 4, 2)
    patch = block_keys(toks, {"patches": np.ones((2, 3), np.float32)}, 4, 2)
    other = block_keys(toks, {"patches": np.zeros((2, 3), np.float32)}, 4, 2)
    assert plain[0] != patch[0] != other[0]
    assert plain[1] == patch[1] == other[1]       # only the root differs
    # a vlm-style modality prefix consumes leading positions
    pre = block_keys(toks, {}, 4, 3, n_prefix=6)
    assert pre[0][1] == () and pre[1] == (0, 1) and pre[2] == tuple(range(2, 6))


# ---------------------------------------------------------------------------
# BlockAllocator hardening (required once refcounts share blocks)
# ---------------------------------------------------------------------------

def test_block_allocator_guards_double_free_and_range():
    alloc = BlockAllocator(6)
    blocks = alloc.alloc(3)
    alloc.free(blocks[:1])
    with pytest.raises(ValueError, match="double free"):
        alloc.free(blocks[:1])                    # already on the free list
    with pytest.raises(ValueError, match="double free"):
        alloc.free([blocks[1], blocks[1]])        # duplicate in one call
    with pytest.raises(ValueError, match="outside the pool"):
        alloc.free([0])                           # the reserved scratch block
    with pytest.raises(ValueError, match="outside the pool"):
        alloc.free([6])
    alloc.free(blocks[1:])                        # the rest frees cleanly
    assert alloc.n_free == 5


# ---------------------------------------------------------------------------
# Prefix-cached serving == uncached serving (per family)
# ---------------------------------------------------------------------------

# prefix length 19 splits page_block=8: two full shared blocks + a split
PREFIX_FAMILY_ARCHS = [
    ("qwen3_8b", "dense", 6),
    ("deepseek_moe_16b", "moe", 6),
    ("internvl2_2b", "vlm", 8),
    ("whisper_small", "audio", 6),
]


def shared_prefix_requests(cfg, seed=21):
    """Three requests sharing a 19-token prefix (splits page_block=8) with
    different continuations, plus an identical repeat — same modality
    extras across all of them so vlm/audio can actually share."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab, size=19).astype(np.int32)
    sufs = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
            for n in (6, 9, 6)]
    extras = {}
    if cfg.family == "vlm":
        extras["patches"] = rng.normal(
            size=(cfg.n_patches, cfg.vision_dim)).astype(np.float32)
    if cfg.family == "audio":
        extras["frames"] = rng.normal(
            size=(cfg.n_audio_frames, cfg.audio_dim)).astype(np.float32)
    prompts = [np.concatenate([shared, s]) for s in sufs] + \
        [np.concatenate([shared, sufs[0]])]       # exact repeat of req 0
    return [Request(i, p, 5, extras=dict(extras))
            for i, p in enumerate(prompts)]


@pytest.mark.parametrize("arch,family,chunk", PREFIX_FAMILY_ARCHS)
def test_prefix_cached_matches_uncached(arch, family, chunk):
    """n_slots=1 serializes the queue, so every request after the first
    hits the tree; outputs must equal the uncached server token-for-token
    even though the prefix boundary splits a page block."""
    cfg = get_smoke_config(arch).reduced(vocab=256)
    assert cfg.family == family
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    want = SlotServer(model, params, n_slots=1, cache_len=48, page_block=8,
                      chunk=chunk).serve(shared_prefix_requests(cfg))
    srv = SlotServer(model, params, n_slots=1, cache_len=48, page_block=8,
                     chunk=chunk, prefix_cache=True)
    got = srv.serve(shared_prefix_requests(cfg))
    assert set(got) == set(want)
    for rid in want:
        assert got[rid] == want[rid], (arch, rid, got[rid], want[rid])
    # the hits really happened: reqs 1..3 each skipped the 2 full shared
    # blocks (16 tokens); the exact repeat additionally reuses req 0's
    # third block (its full extent is prompt content)
    assert srv.prefix.skipped_tokens >= 3 * 16
    assert srv.prefix.hit_rate > 0
    # cached blocks stay resident; the rest of the pool was returned
    assert srv.allocator.n_free == \
        srv.allocator.n_blocks - 1 - srv.prefix.n_cached
    assert srv.prefix.n_evictable == srv.prefix.n_cached


def test_prefix_divergence_no_cross_contamination():
    """A and B share a prefix then diverge; B decodes long past its
    prompt. Serving A again afterwards must reproduce A exactly — B's
    decode writes landed in private blocks, never in the shared ones."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    shared = rng.integers(0, cfg.vocab, size=16).astype(np.int32)
    a = np.concatenate([shared, rng.integers(0, cfg.vocab, size=3)
                        .astype(np.int32)])
    b = np.concatenate([shared, rng.integers(0, cfg.vocab, size=5)
                        .astype(np.int32)])

    def ref(prompt, new):
        return SlotServer(model, params, n_slots=1, cache_len=64,
                          page_block=8, chunk=8).serve(
            [Request(0, prompt, new)])[0]

    srv = SlotServer(model, params, n_slots=1, cache_len=64, page_block=8,
                     chunk=8, prefix_cache=True)
    assert srv.serve([Request(0, a, 4)])[0] == ref(a, 4)
    assert srv.serve([Request(1, b, 20)])[1] == ref(b, 20)
    assert srv.serve([Request(2, a, 4)])[2] == ref(a, 4)
    assert srv.prefix.skipped_tokens == 2 * 16    # b and the second a


def test_block_aligned_fully_cached_prompt_recomputes_last_block():
    """Prompt width is an exact block multiple and fully cached: the match
    cap forces the final block's positions to re-prefill into a FRESH
    private block (the copy-on-write rule) — the shared block is never
    written, and the first token still comes out exact."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.random.default_rng(4).integers(0, cfg.vocab, size=16) \
        .astype(np.int32)                         # exactly 2 blocks of 8
    want = SlotServer(model, params, n_slots=1, cache_len=48, page_block=8,
                      chunk=8).serve([Request(0, prompt, 6)])
    srv = SlotServer(model, params, n_slots=1, cache_len=48, page_block=8,
                     chunk=8, prefix_cache=True)
    first = srv.serve([Request(0, prompt, 6)])
    shared_block = int(srv.block_tables[0, 0])    # table already cleared
    again = srv.serve([Request(1, prompt, 6)])
    assert first[0] == again[1] == want[0]
    assert srv.prefix.skipped_tokens == 8         # capped at (16-1)//8 = 1
    assert shared_block == 0                      # sanity: slot released


def test_admission_under_pressure_evicts_lru_before_waiting():
    """The pool is too small to hold the cached prefix AND the next
    request's reservation: admission must evict the LRU unreferenced
    cached blocks and proceed — on an idle server a refusal would be
    fatal (the 'cannot admit even on an idle server' path)."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    p1 = rng.integers(0, cfg.vocab, size=16).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab, size=30).astype(np.int32)

    def ref(prompt, new):
        return SlotServer(model, params, n_slots=1, cache_len=40,
                          page_block=8, chunk=8).serve(
            [Request(0, prompt, new)])[0]

    # 5 usable blocks: p1 caches 2; p2 needs 4 → must evict at least 1
    srv = SlotServer(model, params, n_slots=1, cache_len=40, page_block=8,
                     chunk=8, pool_blocks=6, prefix_cache=True)
    assert srv.serve([Request(0, p1, 4)])[0] == ref(p1, 4)
    assert srv.prefix.n_evictable == 2
    assert srv.serve([Request(1, p2, 4)])[1] == ref(p2, 4)
    assert srv.prefix.evicted_blocks >= 1


def test_eviction_never_takes_the_matched_run():
    """Regression: the matched prefix is refcount-0 on the LRU until the
    admission pins it — and it can be the OLDEST entry. When the fresh-
    block allocation triggers eviction, the matched run must be pinned
    first, or eviction frees (and re-allocates, as the same request's
    private blocks!) the blocks the admission is about to map read-only."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(14)
    a = rng.integers(0, cfg.vocab, size=16).astype(np.int32)   # 2 blocks
    x = rng.integers(0, cfg.vocab, size=8).astype(np.int32)    # 1 block
    b = np.concatenate([a, rng.integers(0, cfg.vocab, size=10)
                        .astype(np.int32)])                    # shares a

    def queue():
        return [Request(0, a, 4), Request(1, x, 4), Request(2, b, 4)]

    want = SlotServer(model, params, n_slots=1, cache_len=40, page_block=8,
                      chunk=8, pool_blocks=5).serve(queue())
    # 4 usable blocks; after a and x retire the LRU holds a's chain
    # (oldest) then x's block, with 1 block free. b matches a's 2 blocks
    # and needs 2 fresh ones → eviction must take x's block, not a's.
    srv = SlotServer(model, params, n_slots=1, cache_len=40, page_block=8,
                     chunk=8, pool_blocks=5, prefix_cache=True)
    got = srv.serve(queue())
    assert got == want
    assert srv.prefix.skipped_tokens == 16        # the hit really happened
    assert srv.prefix.evicted_blocks >= 1         # and pressure was real


def test_vlm_different_patches_never_share():
    """Identical token ids under different image patches must MISS (the
    extras digest roots the key path) and still decode exactly."""
    cfg = get_smoke_config("internvl2_2b").reduced(vocab=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(6)
    toks = rng.integers(0, cfg.vocab, size=18).astype(np.int32)
    patches = [rng.normal(size=(cfg.n_patches, cfg.vision_dim))
               .astype(np.float32) for _ in range(2)]

    def queue():
        return [Request(i, toks, 4, extras={"patches": patches[i]})
                for i in range(2)]

    want = SlotServer(model, params, n_slots=1, cache_len=48, page_block=8,
                      chunk=8).serve(queue())
    srv = SlotServer(model, params, n_slots=1, cache_len=48, page_block=8,
                     chunk=8, prefix_cache=True)
    got = srv.serve(queue())
    assert got == want
    assert srv.prefix.skipped_tokens == 0         # digests differ: no hit


@pytest.mark.parametrize("arch", ["xlstm_125m", "zamba2_2_7b"])
def test_recurrent_families_degrade_to_uncached(arch):
    """ssm/hybrid state accumulates outside the pool: prefix_cache=True
    must silently take the direct path (no tree, exact parity)."""
    cfg = get_smoke_config(arch).reduced(vocab=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    want = SlotServer(model, params, n_slots=2, cache_len=48, page_block=8,
                      chunk=16).serve(make_requests(cfg, (7, 11), (4, 3)))
    srv = SlotServer(model, params, n_slots=2, cache_len=48, page_block=8,
                     chunk=16, prefix_cache=True)
    assert srv.prefix is None
    assert srv.serve(make_requests(cfg, (7, 11), (4, 3))) == want


def test_prefix_cache_requires_paged_chunked():
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="chunked prefill"):
        SlotServer(model, params, n_slots=1, cache_len=16, page_block=8,
                   prefix_cache=True)


# ---------------------------------------------------------------------------
# Mixture core and decentralized pods
# ---------------------------------------------------------------------------

def mixture_fixture(K=2, B=4, seed=7):
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=128)
    model = build_model(cfg)
    experts = [model.init(jax.random.PRNGKey(k)) for k in range(K)]
    rng = np.random.default_rng(seed)
    Df = 16
    router = CentroidRouter(
        jnp.asarray(rng.normal(size=(K, Df)), jnp.float32),
        RouterConfig(top_k=K))
    shared = rng.integers(0, cfg.vocab, size=17).astype(np.int32)
    prompts = [np.concatenate(
        [shared, rng.integers(0, cfg.vocab, size=4).astype(np.int32)])
        for _ in range(B)]
    feats = rng.normal(size=(B, Df)).astype(np.float32)
    return cfg, model, experts, router, prompts, feats


def test_prefix_cached_mixture_matches_uncached():
    """One block table per slot, shared by all K stacked experts: a prefix
    hit reuses the shared blocks for the whole ensemble at once."""
    cfg, model, experts, router, prompts, feats = mixture_fixture()

    def queue():
        return [Request(i, p, 4, features=feats[i])
                for i, p in enumerate(prompts)]

    want = MixtureSlotServer(model, experts, router, n_slots=1,
                             cache_len=40, page_block=8,
                             chunk=8).serve(queue())
    srv = MixtureSlotServer(model, experts, router, n_slots=1, cache_len=40,
                            page_block=8, chunk=8, prefix_cache=True)
    got = srv.serve(queue())
    assert got == want
    assert srv.prefix.skipped_tokens >= 3 * 16    # reqs 1..3 hit 2 blocks


def test_decentralized_prefix_cache_and_occupancy_stats():
    """Per-pod caches on the top-1 front end: parity with prefix off, and
    occupancy() reports pool-free-block counts and the hit rate."""
    cfg, model, experts, router, prompts, feats = mixture_fixture(seed=9)

    def queue():
        return [Request(i, p, 4, features=feats[i])
                for i, p in enumerate(prompts)]

    want = DecentralizedSlotServer(model, experts, router, n_slots=1,
                                   cache_len=40, page_block=8,
                                   chunk=8).serve(queue())
    srv = DecentralizedSlotServer(model, experts, router, n_slots=1,
                                  cache_len=40, page_block=8, chunk=8,
                                  prefix_cache=True)
    assert srv.serve(queue()) == want
    occ = srv.occupancy()
    assert len(occ) == len(experts)
    for pod_stats in occ:
        assert pod_stats["active"] == 0
        assert 0 < pod_stats["pool_free_blocks"] <= pod_stats["pool_blocks"]
        assert 0.0 <= pod_stats["prefix_hit_rate"] <= 1.0
    # the 4 shared-prefix requests landed somewhere: at least one pod
    # that served >= 2 of them hit the cache
    assert sum(p["prefix_skipped_tokens"] for p in occ) > 0


# ---------------------------------------------------------------------------
# Per-request seeded sampling
# ---------------------------------------------------------------------------

def test_sampling_deterministic_given_seed_and_schedule_independent():
    """A sampled request's output depends only on (seed, params, prompt):
    identical across fresh servers and across co-scheduled traffic."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab, size=9).astype(np.int32)
    other = rng.integers(0, cfg.vocab, size=5).astype(np.int32)

    def sampled():
        return Request(0, prompt, 8, temperature=0.8, top_k=20, seed=123)

    alone = SlotServer(model, params, n_slots=2,
                       cache_len=32).serve([sampled()])[0]
    again = SlotServer(model, params, n_slots=2,
                       cache_len=32).serve([sampled()])[0]
    crowded = SlotServer(model, params, n_slots=2, cache_len=32).serve(
        [sampled(), Request(1, other, 10)])[0]
    paged = SlotServer(model, params, n_slots=2, cache_len=32, page_block=8,
                       chunk=4).serve([sampled()])[0]
    assert alone == again == crowded == paged
    other_seed = SlotServer(model, params, n_slots=2, cache_len=32).serve(
        [Request(0, prompt, 8, temperature=0.8, top_k=20, seed=124)])[0]
    assert alone != other_seed                    # the seed is the stream
    # negative seeds wrap into uint32 instead of crashing the serve loop
    neg = SlotServer(model, params, n_slots=2, cache_len=32).serve(
        [Request(0, prompt, 8, temperature=0.8, top_k=20, seed=-3)])[0]
    assert len(neg) == 8


def test_top_k_one_is_greedy_and_greedy_neighbors_unaffected():
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(12)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (7, 9)]
    want = SlotServer(model, params, n_slots=2, cache_len=32).serve(
        [Request(i, p, 6) for i, p in enumerate(prompts)])
    got = SlotServer(model, params, n_slots=2, cache_len=32).serve(
        [Request(0, prompts[0], 6, temperature=2.5, top_k=1, seed=5),
         Request(1, prompts[1], 6)])
    assert got == want                            # top_k=1 ≡ argmax, and
    #                                             # the greedy slot is exact


def test_sampled_mixture_deterministic():
    cfg, model, experts, router, prompts, feats = mixture_fixture(seed=13)

    def queue():
        return [Request(0, prompts[0], 6, features=feats[0],
                        temperature=1.0, top_k=10, seed=42)]

    a = MixtureSlotServer(model, experts, router, n_slots=1,
                          cache_len=40).serve(queue())
    b = MixtureSlotServer(model, experts, router, n_slots=1,
                          cache_len=40).serve(queue())
    assert a == b


# ---------------------------------------------------------------------------
# Sharding: cache-metadata placement
# ---------------------------------------------------------------------------

def test_block_table_pspec_replicated():
    """Block tables (the only device-visible prefix-cache metadata) ride
    replicated so every shard of the block-sharded pool gathers locally."""
    from jax.sharding import Mesh
    from repro.sharding.rules import block_table_pspec, logical_rules
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("pod", "data", "model"))
    rules = logical_rules(multi_pod=True, decentralized=True)
    ns = block_table_pspec(rules, mesh)
    assert tuple(ns.spec) == ()
