"""The online serving API.

* ``EngineConfig.validate()`` owns the whole feature-dependency matrix
  (paged/chunked/prefix/token-budget) — bad combinations raise one
  actionable ValueError naming the missing prerequisite.
* ``SamplingParams.stop_token_ids``/``eos_token_id`` retire requests as
  soon as a stop id is GENERATED (finish_reason="stop"), return their
  pool blocks, and never leak the post-stop tail into the prefix cache.
* The incremental surface: ``add_request`` → ``step`` streams per-token
  ``TokenDelta``s with TTFT/ITL stamps; ``abort`` frees the slot, the
  pool blocks, and the prefix-cache references wherever the request is
  in its life (queued / mid-prefill / mid-decode) and is a no-op on
  unknown or finished rids.
* The interleaved add/stream/abort scenario holds on all three engines
  (``SlotServer``, ``MixtureSlotServer``, ``DecentralizedSlotServer``)
  with exact greedy parity for the surviving requests.
"""
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.router import CentroidRouter, RouterConfig
from repro.models import build_model
from repro.serve.api import EngineConfig, RequestOutput, SamplingParams
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import (DecentralizedSlotServer,
                                   MixtureSlotServer, Request, SlotServer,
                                   make_engine)

CACHE_LEN = 64


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def ref_greedy(model, params, tokens, n_new, cache_len=CACHE_LEN):
    """Solo per-request greedy decode — the parity oracle."""
    engine = ServeEngine(model, cache_len)
    batch = {"tokens": jnp.asarray(np.asarray(tokens)[None, :]),
             "labels": jnp.zeros((1, len(tokens)), jnp.int32)}
    toks = engine.generate(params, batch, n_new, jax.random.PRNGKey(1),
                           temperature=0.0)
    return np.asarray(toks)[0].tolist()


def prompt_of(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab, size=n).astype(np.int32)


# ---------------------------------------------------------------------------
# SamplingParams / EngineConfig validation
# ---------------------------------------------------------------------------

def test_sampling_params_validation_and_stop_set():
    sp = SamplingParams(stop_token_ids=(3, 5), eos_token_id=9)
    assert sp.stop_set == {3, 5, 9}
    assert SamplingParams().stop_set == frozenset()
    with pytest.raises(ValueError, match="max_new"):
        SamplingParams(max_new=0)
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=-1)


@pytest.mark.parametrize("kwargs,match", [
    (dict(n_slots=0), "n_slots"),
    (dict(cache_len=1), "cache_len"),
    (dict(paged=True, page_block=0), "page_block"),
    (dict(pool_blocks=8), "pool_blocks"),
    (dict(paged=True, pool_blocks=1), "scratch block"),
    (dict(chunked_prefill=True, chunk=0), "chunk"),
    (dict(token_budget=-1), "token_budget"),
    (dict(paged=True, chunked_prefill=True, token_budget=32),
     None),                               # valid: no raise
    (dict(token_budget=32), "chunked_prefill"),
    (dict(prefix_cache=True), "chunked prefill"),
    (dict(paged=True, prefix_cache=True), "chunked prefill"),
    (dict(strategy="both"), "strategy"),
])
def test_engine_config_flag_matrix(kwargs, match):
    cfg = EngineConfig(**kwargs)
    if match is None:
        cfg.validate()
    else:
        with pytest.raises(ValueError, match=match):
            cfg.validate()


def test_engine_config_model_checks(dense_setup):
    """The model-dependent fences (formerly _validate_chunked and the
    _SlotTable constructor) live in the same validate()."""
    cfg, model, _ = dense_setup
    # attention families must page their chunked-prefill writes
    with pytest.raises(ValueError, match="paged pool"):
        EngineConfig(chunked_prefill=True, chunk=8).validate(model)
    # recurrent chunk misalignment
    zcfg = get_smoke_config("zamba2_2_7b").reduced(vocab=64)
    with pytest.raises(ValueError, match="chunkwise-scan"):
        EngineConfig(paged=True, page_block=8, chunked_prefill=True,
                     chunk=6).validate(build_model(zcfg))
    # sliding-window rings can't chunk yet
    wcfg = get_smoke_config("qwen3_8b").reduced(vocab=64, sliding_window=8)
    with pytest.raises(ValueError, match="sliding-window"):
        EngineConfig(paged=True, page_block=4, chunked_prefill=True,
                     chunk=4).validate(build_model(wcfg))
    # config-only checks pass without a model; full check passes with one
    good = EngineConfig(paged=True, page_block=8, chunked_prefill=True,
                        chunk=8, prefix_cache=True)
    good.validate()
    good.validate(model)


def test_make_engine_builds_the_right_engine(dense_setup):
    cfg, model, params = dense_setup
    ecfg = EngineConfig(n_slots=2, cache_len=CACHE_LEN)
    eng = make_engine(model, params, config=ecfg)
    assert isinstance(eng, SlotServer) and eng.config is ecfg

    experts = [params, params]
    router = CentroidRouter(
        jnp.asarray(np.eye(2, 16, dtype=np.float32)), RouterConfig())
    top1 = make_engine(model, experts=experts, router=router, config=ecfg)
    assert isinstance(top1, DecentralizedSlotServer)
    assert top1.strategy == "top1" and len(top1.pods) == 2
    mix = make_engine(model, experts=experts, router=router,
                      config=EngineConfig(n_slots=2, cache_len=CACHE_LEN,
                                          strategy="mixture"))
    assert isinstance(mix.core, MixtureSlotServer)

    with pytest.raises(ValueError, match="router"):
        make_engine(model, experts=experts, config=ecfg)
    with pytest.raises(ValueError, match="params"):
        make_engine(model, config=ecfg)


# ---------------------------------------------------------------------------
# Stop-token / eos termination
# ---------------------------------------------------------------------------

def test_stop_token_retires_early_and_frees_blocks(dense_setup):
    """Regression: requests used to always decode exactly max_new tokens.
    A generated stop id must retire the request right there (the stop
    token stays in the output), with finish_reason='stop' and its pool
    blocks returned."""
    cfg, model, params = dense_setup
    prompt = prompt_of(cfg, 9, seed=3)
    ref = ref_greedy(model, params, prompt, 12)
    stop = ref[3]
    want = ref[:ref.index(stop) + 1]          # up to AND including the stop

    srv = SlotServer(model, params, n_slots=2, cache_len=CACHE_LEN,
                     page_block=8)
    free0 = srv.allocator.n_free
    rid = srv.add_request(prompt, SamplingParams(max_new=12,
                                                 stop_token_ids=(stop,)))
    outs = []
    while srv.has_unfinished():
        outs += [o for o in srv.step() if o.finished]
    assert len(outs) == 1 and outs[0].rid == rid
    assert outs[0].token_ids == want
    assert outs[0].finish_reason == "stop"
    assert srv.allocator.n_free == free0      # blocks returned
    st = srv.stats()
    assert st["stopped"] == 1 and st["aborted"] == 0

    # eos_token_id is folded into the same stop set
    got = SlotServer(model, params, n_slots=2, cache_len=CACHE_LEN).serve(
        [Request(0, prompt, 12,
                 params=SamplingParams(max_new=12, eos_token_id=stop))])
    assert got[0] == want

    # a stop id occurring in the PROMPT never triggers (output only)
    absent = next(t for t in range(cfg.vocab) if t not in ref)
    with_stop_in_prompt = np.concatenate(
        [prompt[:-1], np.asarray([absent], np.int32)])
    n = len(SlotServer(model, params, n_slots=1, cache_len=CACHE_LEN).serve(
        [Request(0, with_stop_in_prompt, 6,
                 params=SamplingParams(max_new=6,
                                       stop_token_ids=(absent,)))])[0])
    assert n == 6                              # full budget: never stopped


def test_first_token_stop_monolithic_and_chunked(dense_setup):
    """A stop id as the very first (prefill) token retires at admission /
    prefill completion with a single-token output."""
    cfg, model, params = dense_setup
    prompt = prompt_of(cfg, 16, seed=4)
    first = ref_greedy(model, params, prompt, 1)[0]
    sp = SamplingParams(max_new=8, stop_token_ids=(first,))
    for kw in (dict(), dict(page_block=8, chunk=8)):
        srv = SlotServer(model, params, n_slots=1, cache_len=CACHE_LEN,
                         **kw)
        got = srv.serve([Request(0, prompt, 8, params=sp)])
        assert got[0] == [first]
        assert srv.n_stopped == 1 and srv.active == []


def test_stop_tail_never_enters_prefix_cache(dense_setup):
    """Regression: only the PROMPT's full blocks are inserted into the
    prefix cache — a stop-retired request's decode tail must not be
    shareable, while its prompt still is."""
    cfg, model, params = dense_setup
    prompt = prompt_of(cfg, 16, seed=5)       # exactly 2 full blocks
    ref = ref_greedy(model, params, prompt, 10)
    stop = ref[2]
    want = ref[:ref.index(stop) + 1]

    srv = SlotServer(model, params, n_slots=2, cache_len=CACHE_LEN,
                     page_block=8, chunk=8, prefix_cache=True)
    got = srv.serve([Request(0, prompt, 10,
                             params=SamplingParams(max_new=10,
                                                   stop_token_ids=(stop,)))])
    assert got[0] == want
    st = srv.stats()
    # the prompt's 2 full blocks and nothing else — no post-stop tail
    assert st["prefix_cached_blocks"] == len(prompt) // 8
    assert st["pool_free_blocks"] == st["pool_blocks"] - 1 - \
        st["prefix_cached_blocks"]
    # an identical prompt reuses the cached prefix and agrees exactly
    got2 = srv.serve([Request(1, prompt, 10,
                              params=SamplingParams(
                                  max_new=10, stop_token_ids=(stop,)))])
    assert got2[1] == want
    assert srv.stats()["prefix_skipped_tokens"] > 0


# ---------------------------------------------------------------------------
# Abort: queued / mid-prefill / mid-decode resource accounting
# ---------------------------------------------------------------------------

def test_abort_mid_decode_frees_exactly_the_reserved_blocks(dense_setup):
    cfg, model, params = dense_setup
    srv = SlotServer(model, params, n_slots=2, cache_len=CACHE_LEN,
                     page_block=8)
    free0 = srv.allocator.n_free
    p0, p1 = prompt_of(cfg, 10, seed=6), prompt_of(cfg, 7, seed=7)
    r0 = srv.add_request(p0, SamplingParams(max_new=20))
    r1 = srv.add_request(p1, SamplingParams(max_new=20))
    srv.step(), srv.step()                    # both admitted and decoding
    assert len(srv.decoding) == 2

    slot1 = next(s for s, r in enumerate(srv.slot_req) if r.rid == r1)
    held1 = int(srv.n_alloc[slot1])
    out = srv.abort(r0)
    assert isinstance(out, RequestOutput) and out.finished
    assert out.finish_reason == "aborted" and out.rid == r0
    # the pool holds exactly the survivor's blocks again
    assert srv.allocator.n_free == free0 - held1
    assert srv.abort(r0) is None              # already finished: no-op
    assert srv.abort(12345) is None           # unknown rid: no-op
    assert srv.stats()["aborted"] == 1

    # the survivor is unperturbed: exact greedy parity
    done = {}
    while srv.has_unfinished():
        for o in srv.step():
            if o.finished:
                done[o.rid] = o.token_ids
    assert done[r1] == ref_greedy(model, params, p1, 20)
    assert srv.allocator.n_free == free0      # full round-trip


def test_abort_mid_prefill_frees_blocks(dense_setup):
    cfg, model, params = dense_setup
    srv = SlotServer(model, params, n_slots=2, cache_len=CACHE_LEN,
                     page_block=8, chunk=8)
    free0 = srv.allocator.n_free
    rid = srv.add_request(prompt_of(cfg, 24, seed=8),
                          SamplingParams(max_new=4))
    srv.step()                                # one chunk of three consumed
    slot = next(s for s, r in enumerate(srv.slot_req) if r is not None)
    assert srv.prefilling[slot] and srv.allocator.n_free < free0
    out = srv.abort(rid)
    assert out.finished and out.finish_reason == "aborted"
    assert srv.allocator.n_free == free0      # whole reservation returned
    assert not any(srv.prefilling) and srv.prefill_order == []
    assert not srv.has_unfinished() and srv.step() == []


def test_abort_waiting_request_never_admits(dense_setup):
    cfg, model, params = dense_setup
    srv = SlotServer(model, params, n_slots=1, cache_len=CACHE_LEN)
    p0 = prompt_of(cfg, 8, seed=9)
    r0 = srv.add_request(p0, SamplingParams(max_new=6))
    r1 = srv.add_request(prompt_of(cfg, 8, seed=10),
                         SamplingParams(max_new=6))
    srv.step()                                # r0 takes the only slot
    assert [r.rid for r in srv.waiting] == [r1]
    out = srv.abort(r1)
    assert out.finish_reason == "aborted" and out.token_ids == []
    assert srv.waiting == []
    done = {}
    while srv.has_unfinished():
        for o in srv.step():
            if o.finished:
                done[o.rid] = o.token_ids
    assert done[r0] == ref_greedy(model, params, p0, 6)


def test_abort_decrements_prefix_refcounts(dense_setup):
    """Aborting a request that mapped shared cached blocks mid-prefill
    releases its references (blocks stay cached for others) and returns
    only its private blocks to the pool."""
    cfg, model, params = dense_setup
    prompt = prompt_of(cfg, 24, seed=11)      # 3 full blocks at block 8
    srv = SlotServer(model, params, n_slots=2, cache_len=CACHE_LEN,
                     page_block=8, chunk=4, prefix_cache=True)
    srv.serve([Request(0, prompt, 3)])        # warm the cache
    st0 = srv.stats()
    evict0, free0 = st0["prefix_evictable_blocks"], st0["pool_free_blocks"]
    assert st0["prefix_cached_blocks"] == 3 and evict0 == 3

    rid = srv.add_request(prompt, SamplingParams(max_new=3))
    srv.step()                                # matched 2 blocks, chunking
    slot = next(s for s, r in enumerate(srv.slot_req) if r is not None)
    assert srv.prefilling[slot]
    st = srv.stats()
    assert st["prefix_evictable_blocks"] == evict0 - 2   # 2 acquired
    assert st["pool_free_blocks"] == free0 - 1           # 1 private block

    srv.abort(rid)
    st = srv.stats()
    assert st["prefix_evictable_blocks"] == evict0       # refs released
    assert st["pool_free_blocks"] == free0               # private returned
    assert st["prefix_cached_blocks"] == 3               # cache intact


# ---------------------------------------------------------------------------
# Streaming: per-token deltas, timestamps, drain-loop parity
# ---------------------------------------------------------------------------

def test_streaming_deltas_reassemble_and_stamp(dense_setup):
    cfg, model, params = dense_setup
    prompts = [prompt_of(cfg, n, seed=20 + n) for n in (7, 12, 5)]
    budgets = [6, 4, 8]
    srv = SlotServer(model, params, n_slots=2, cache_len=CACHE_LEN)
    for p, m in zip(prompts, budgets):
        srv.add_request(p, SamplingParams(max_new=m))
    streamed = {rid: [] for rid in range(3)}
    stamps = {rid: [] for rid in range(3)}
    final = {}
    while srv.has_unfinished():
        for o in srv.step():
            assert [d.index for d in o.deltas] == \
                list(range(len(streamed[o.rid]),
                           len(streamed[o.rid]) + len(o.deltas)))
            streamed[o.rid] += [d.token for d in o.deltas]
            stamps[o.rid] += [d.t for d in o.deltas]
            if o.finished:
                final[o.rid] = o

    for rid, (p, m) in enumerate(zip(prompts, budgets)):
        o = final[rid]
        assert streamed[rid] == o.token_ids == ref_greedy(model, params,
                                                          p, m)
        assert o.finish_reason == "length" and not o.deltas == []
        assert o.ttft > 0 and o.t_done >= o.t_first >= o.t_submit
        assert stamps[rid] == sorted(stamps[rid])        # monotone ITL


def test_serve_wrapper_logs_finish_reasons(dense_setup, caplog):
    cfg, model, params = dense_setup
    srv = SlotServer(model, params, n_slots=2, cache_len=CACHE_LEN)
    with caplog.at_level(logging.INFO, logger="repro.serve.scheduler"):
        srv.serve([Request(0, prompt_of(cfg, 6, seed=30), 3)])
    msg = "".join(r.getMessage() for r in caplog.records)
    assert "finish_reasons" in msg and "length" in msg


# ---------------------------------------------------------------------------
# The interleaved add/stream/abort scenario on all three engines
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def trio_setup(dense_setup):
    cfg, model, params = dense_setup
    K, Df = 2, 16
    experts = [model.init(jax.random.PRNGKey(k)) for k in range(K)]
    rng = np.random.default_rng(2)
    router = CentroidRouter(
        jnp.asarray(rng.normal(size=(K, Df)), jnp.float32),
        RouterConfig(top_k=K))
    feats = rng.normal(size=(Df,)).astype(np.float32)   # one shared vector:
    return cfg, model, params, experts, router, feats   # all → same pod


def _trio_engine(which, setup):
    cfg, model, params, experts, router, feats = setup
    ecfg = EngineConfig(n_slots=2, cache_len=CACHE_LEN, paged=True,
                        page_block=8, chunked_prefill=True, chunk=8)
    if which == "slot":
        return SlotServer(model, params, config=ecfg)
    if which == "mixture":
        return MixtureSlotServer(model, experts, router, config=ecfg)
    return DecentralizedSlotServer(model, experts, router, config=ecfg)


@pytest.mark.parametrize("which", ["slot", "mixture", "decentralized"])
def test_interleaved_add_stream_abort(which, trio_setup):
    """Submit, stream, submit more, abort mid-flight — the surviving
    requests must match a fresh engine serving only them (greedy outputs
    are schedule-independent), and the accounting must come back clean."""
    cfg, model, params, experts, router, feats = trio_setup
    p0, p2 = prompt_of(cfg, 7, seed=40), prompt_of(cfg, 9, seed=41)
    p1 = prompt_of(cfg, 24, seed=42)          # 3 chunks: aborts mid-prefill

    def req(rid, p, m):
        return Request(rid, p, m, features=feats,
                       params=SamplingParams(max_new=m))

    eng = _trio_engine(which, trio_setup)
    streamed = {}

    def drain_once():
        for o in eng.step():
            streamed.setdefault(o.rid, [])
            streamed[o.rid] += [d.token for d in o.deltas]

    eng.add_request(req(0, p0, 10))
    drain_once(), drain_once()                # r0 decoding
    eng.add_request(req(1, p1, 4))            # long prompt → chunked
    eng.add_request(req(2, p2, 6))            # waits for a slot
    drain_once()                              # r1 mid-prefill
    out = eng.abort(1)
    assert out is not None and out.finish_reason == "aborted"
    assert eng.abort(1) is None               # no-op on finished
    while eng.has_unfinished():
        drain_once()
    assert not eng.has_unfinished()

    # surviving outputs: exact parity with a fresh engine serving them
    want = _trio_engine(which, trio_setup).serve(
        [req(0, p0, 10), req(2, p2, 6)])
    assert streamed[0] == want[0] and streamed[2] == want[2]

    stats = eng.occupancy() if which == "decentralized" else [eng.stats()]
    assert sum(s["aborted"] for s in stats) == 1
    assert all(s["active"] == 0 and s["waiting"] == 0 for s in stats)
    # every pool block came home
    assert all(s["pool_free_blocks"] == s["pool_blocks"] - 1
               for s in stats)


def test_decentralized_add_request_requires_features(trio_setup):
    cfg, model, params, experts, router, feats = trio_setup
    eng = DecentralizedSlotServer(
        model, experts, router,
        config=EngineConfig(n_slots=2, cache_len=CACHE_LEN))
    with pytest.raises(ValueError, match="features"):
        eng.add_request(prompt_of(cfg, 6, seed=50), SamplingParams())
    mix = MixtureSlotServer(
        model, experts, router,
        config=EngineConfig(n_slots=2, cache_len=CACHE_LEN))
    with pytest.raises(ValueError, match="features"):
        mix.add_request(prompt_of(cfg, 6, seed=51), SamplingParams())
