"""End-to-end system tests: decentralized training → routing → ensemble
serving, plus the trainer/vmap-expert machinery. Small sizes, real training.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.router import RouterConfig
from repro.data.partition import partition_dataset
from repro.data.pipeline import LoaderConfig, ShardLoader
from repro.data.synthetic import SyntheticConfig, SyntheticMultimodal
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.serve.engine import ServeEngine
from repro.serve.ensemble_engine import DecentralizedServer
from repro.train.trainer import (TrainConfig, init_train_state,
                                 make_decentralized_train_step,
                                 make_train_step, stack_expert_states,
                                 train_host_loop, unstack_expert_states)

VOCAB, SEQ = 64, 32


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=VOCAB)
    model = build_model(cfg)
    corpus = SyntheticMultimodal(SyntheticConfig(
        vocab=VOCAB, seq_len=SEQ, n_samples=512, n_latent=2,
        cluster_sep=6.0, seed=0))
    return cfg, model, corpus


def test_train_loss_decreases(setup):
    cfg, model, corpus = setup
    opt = AdamWConfig(lr=1e-3, warmup_steps=3, total_steps=40)
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    loader = ShardLoader(corpus, LoaderConfig(batch_size=8))
    state, hist = train_host_loop(model, state, loader, 40,
                                  TrainConfig(opt=opt), log_every=5)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.2
    assert np.isfinite(hist[-1]["grad_norm"])


def test_vmapped_expert_step_equals_independent_steps(setup):
    """The decentralized (vmapped) train step must be EXACTLY K independent
    train steps — the mechanized form of 'experts never communicate'."""
    cfg, model, corpus = setup
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                      schedule="constant")
    tc = TrainConfig(opt=opt)
    K = 2
    states = [init_train_state(model, jax.random.PRNGKey(k), opt)
              for k in range(K)]
    batches = [corpus.sample_batch(4, step=k) for k in range(K)]
    jb = [{n: jnp.asarray(b[n]) for n in ("tokens", "labels")}
          for b in batches]

    single = jax.jit(make_train_step(model, tc))
    expected = [single(states[k], jb[k]) for k in range(K)]

    stacked_state = stack_expert_states(states)
    stacked_batch = jax.tree.map(lambda *x: jnp.stack(x), *jb)
    dec = jax.jit(make_decentralized_train_step(model, tc))
    new_state, metrics = dec(stacked_state, stacked_batch)
    unstacked = unstack_expert_states(new_state, K)
    for k in range(K):
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=2e-5, atol=2e-5),
            expected[k][0], unstacked[k])
        assert np.allclose(float(metrics["loss"][k]),
                           float(expected[k][1]["loss"]), rtol=1e-5)


def test_decentralized_specialization_and_parity(setup):
    """Experts specialize on their shard; the routed ensemble matches the
    compute-matched dense baseline on the mixed eval set (paper's headline
    empirical claim, at test scale)."""
    cfg, model, corpus = setup
    steps = 80
    opt = AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=steps)
    tc = TrainConfig(opt=opt)

    def train(subset, batch, seed, offset=0):
        st = init_train_state(model, jax.random.PRNGKey(seed), opt)
        loader = ShardLoader(corpus, LoaderConfig(batch_size=batch),
                             subset=subset, offset=offset)
        st, _ = train_host_loop(model, st, loader, steps, tc, log_every=100)
        return st["params"]

    dense = train(None, 8, 0)
    part = partition_dataset(corpus.all_features(), 2,
                             router_config=RouterConfig(top_k=1), seed=0)
    experts = [train(part.shards[k], 4, 100 + k, offset=10_000 * k)
               for k in range(2)]

    def nll(params_or_server, batch):
        if isinstance(params_or_server, DecentralizedServer):
            return float(params_or_server.ensemble_eval_nll(batch))
        logits = model.forward(params_or_server,
                               {k: batch[k] for k in ("tokens", "labels")})
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        return float(-jnp.take_along_axis(
            lp[:, :-1], batch["labels"][:, 1:, None], -1).mean())

    raw = corpus.sample_batch(64, step=555_000)
    batch = {k: jnp.asarray(v) for k, v in raw.items()
             if k in ("tokens", "labels", "features")}
    server = DecentralizedServer(model, experts, part.router,
                                 cache_len=SEQ + 4)
    d, e = nll(dense, batch), nll(server, batch)
    # parity: routed ensemble within 15% of dense on the mixed eval set
    assert e < d * 1.15, (d, e)

    # specialization: expert k beats expert j≠k on its own shard's data
    own = other = 0.0
    for k in range(2):
        sel = np.isin(raw["cluster"],
                      np.unique(corpus.labels[part.shards[k]]))
        if sel.sum() < 4:
            continue
        sub = {n: batch[n][np.where(sel)[0]] for n in ("tokens", "labels")}
        own += nll(experts[k], sub)
        other += nll(experts[1 - k], sub)
    assert own < other, (own, other)


def test_serve_engine_generate(setup):
    cfg, model, corpus = setup
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, cache_len=SEQ + 16)
    raw = corpus.sample_batch(4, step=1)
    batch = {k: jnp.asarray(v) for k, v in raw.items()
             if k in ("tokens", "labels")}
    toks = engine.generate(params, batch, 8, jax.random.PRNGKey(2))
    assert toks.shape == (4, 8)
    assert int(toks.max()) < VOCAB and int(toks.min()) >= 0
    # greedy decoding is deterministic
    t1 = engine.generate(params, batch, 5, jax.random.PRNGKey(3),
                         temperature=0.0)
    t2 = engine.generate(params, batch, 5, jax.random.PRNGKey(4),
                         temperature=0.0)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_mixture_equals_single_expert_when_topk1_onehot(setup):
    """With one-hot router weights the Eq. 27 mixture must equal running
    only the selected expert — the compute-matching identity of §5.2."""
    cfg, model, corpus = setup
    experts = [model.init(jax.random.PRNGKey(k)) for k in range(2)]
    raw = corpus.sample_batch(6, step=2)
    batch = {k: jnp.asarray(v) for k, v in raw.items()
             if k in ("tokens", "labels", "features")}

    class OneHotRouter:
        config = RouterConfig(top_k=1)

        def route(self, feats):
            B = feats.shape[0]
            w = np.zeros((B, 2), np.float32)
            w[:, 1] = 1.0
            return jnp.asarray(w)

        def top1(self, feats):
            return jnp.ones((feats.shape[0],), jnp.int32)

    server = DecentralizedServer(model, experts, OneHotRouter(),
                                 cache_len=SEQ + 4)
    mix = server.mixture_next_probs(batch)
    logits, _ = server.engine.prefill(experts[1],
                                      {k: batch[k]
                                       for k in ("tokens", "labels")})
    single = jax.nn.softmax(logits[:, -1].astype(jnp.float32), -1)
    np.testing.assert_allclose(np.asarray(mix), np.asarray(single),
                               rtol=1e-5, atol=1e-6)
