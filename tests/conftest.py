import jax

# Theory checks (continuity equation, decomposition theorem) must be exact to
# machine precision — enable float64. Production model code pins its own
# dtypes explicitly so this does not change its semantics.
jax.config.update("jax_enable_x64", True)
