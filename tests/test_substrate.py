"""Substrate tests: optimizer, data pipeline, checkpointing, sharding rules,
roofline HLO parsing."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data.partition import partition_dataset
from repro.data.pipeline import LoaderConfig, ShardLoader, expert_loaders
from repro.data.synthetic import SyntheticConfig, SyntheticMultimodal
from repro.optim.adamw import AdamWConfig, apply_updates, init_state, lr_at


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0, clip_norm=0.0, schedule="constant")
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_state(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, m = apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_clip_and_schedule():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      clip_norm=1.0)
    assert float(lr_at(cfg, jnp.asarray(0))) == 0.0
    assert float(lr_at(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr_at(cfg, jnp.asarray(100))) == pytest.approx(
        cfg.min_lr_ratio, rel=1e-5)
    params = {"w": jnp.zeros(3)}
    state = init_state(params)
    big = {"w": jnp.full(3, 1e6)}
    _, _, m = apply_updates(params, big, state, cfg)
    assert float(m["grad_norm"]) > 1e6  # reported pre-clip


def test_adamw_master_weights_bf16():
    cfg = AdamWConfig(lr=1e-2, warmup_steps=0, schedule="constant",
                      weight_decay=0.0, clip_norm=0.0)
    params = {"w": jnp.ones(4, jnp.bfloat16)}
    state = init_state(params)
    assert "master" in state and state["master"]["w"].dtype == jnp.float32
    g = {"w": jnp.full(4, 1e-3, jnp.bfloat16)}
    p1, s1, _ = apply_updates(params, g, state, cfg)
    # master accumulates sub-bf16 steps; params stay bf16
    assert p1["w"].dtype == jnp.bfloat16
    assert float(jnp.abs(s1["master"]["w"] - 1.0).max()) > 0


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_synthetic_determinism_and_cluster_gap():
    cfg = SyntheticConfig(vocab=32, seq_len=24, n_samples=256, n_latent=3,
                          seed=3)
    c1, c2 = SyntheticMultimodal(cfg), SyntheticMultimodal(cfg)
    b1 = c1.sample_batch(8, step=5)
    b2 = c2.sample_batch(8, step=5)
    for k in b1:
        np.testing.assert_array_equal(b1[k], b2[k])
    # per-cluster chains differ: oracle NLL under own cluster < other
    toks = c1.tokens(np.where(c1.labels == 0)[0][:16],
                     np.random.default_rng(0))
    own = c1.oracle_nll(toks, 0)
    other = c1.oracle_nll(toks, 1)
    assert own < other


def test_loader_process_slicing_and_isolation():
    cfg = SyntheticConfig(vocab=32, seq_len=16, n_samples=128, seed=1)
    corpus = SyntheticMultimodal(cfg)
    full = ShardLoader(corpus, LoaderConfig(batch_size=8))
    p0 = ShardLoader(corpus, LoaderConfig(batch_size=8, process_index=0,
                                          process_count=2))
    p1 = ShardLoader(corpus, LoaderConfig(batch_size=8, process_index=1,
                                          process_count=2))
    bf, b0, b1 = next(full), next(p0), next(p1)
    np.testing.assert_array_equal(bf["tokens"][:4], b0["tokens"])
    np.testing.assert_array_equal(bf["tokens"][4:], b1["tokens"])
    # expert shards are disjoint and exhaustive
    part = partition_dataset(corpus.all_features(), 4, seed=0)
    allidx = np.concatenate(part.shards)
    assert len(allidx) == cfg.n_samples
    assert len(np.unique(allidx)) == cfg.n_samples
    loaders = expert_loaders(corpus, part.shards, 4)
    for k, ld in enumerate(loaders):
        batch = next(ld)
        assert set(np.unique(batch["cluster"])) <= \
            set(np.unique(corpus.labels[part.shards[k]]))


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    base = str(tmp_path)
    tree = {"a": {"b": jnp.arange(6).reshape(2, 3)},
            "t": (jnp.ones(2), [jnp.zeros(1), jnp.full(3, 7.0)]),
            "count": jnp.asarray(5)}
    ckpt.save_expert(base, 1, 40, tree)
    ckpt.save_expert(base, 1, 80, tree)
    assert ckpt.latest_step(base, 1) == 80
    restored, step = ckpt.restore_expert(base, 1)
    assert step == 80
    assert isinstance(restored["t"], tuple) and isinstance(restored["t"][1],
                                                           list)
    np.testing.assert_array_equal(np.asarray(restored["a"]["b"]),
                                  np.arange(6).reshape(2, 3))
    # experts are isolated: expert 0 has no checkpoints
    assert ckpt.latest_step(base, 0) is None
    ckpt.save_router(base, np.eye(2), 10.0, 1)
    c, tau, k = ckpt.load_router(base)
    assert tau == 10.0 and k == 1 and c.shape == (2, 2)


# ---------------------------------------------------------------------------
# sharding rules + roofline HLO parsing
# ---------------------------------------------------------------------------

def test_logical_rules_modes():
    from repro.sharding.rules import logical_rules
    dense_mp = logical_rules(multi_pod=True, decentralized=False)
    dec_mp = logical_rules(multi_pod=True, decentralized=True)
    assert dense_mp["embed"] == ("pod", "data")       # FSDP crosses pods
    assert dense_mp["dexpert"] is None
    assert dec_mp["embed"] == ("data",)               # FSDP inside a pod
    assert dec_mp["dexpert"] == "pod"                 # expert axis = pod
    assert dec_mp["act_batch"] == ("data",)


def test_roofline_collective_parsing():
    from repro.launch.roofline import collective_summary, parse_collectives
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(%p0), channel_id=1, replica_groups=[16,16]<=[16,16]T(1,0), dimensions={0}
  %ar = f32[128]{0} all-reduce(%x), replica_groups={{0,1},{2,3}}, to_apply=%sum
  %cp = f32[2,4]{1,0} collective-permute(%y), source_target_pairs={{0,1}}
  %other = f32[8]{0} add(%a, %b)
"""
    ops = parse_collectives(hlo, pod_size=2)
    assert len(ops) == 3
    assert ops[0].op == "all-gather"
    assert ops[0].bytes == 16 * 1024 * 2
    assert ops[1].bytes == 128 * 4
    # pod_size=2 → group {0,1} inside pod0, {2,3} inside pod1: no crossing
    assert ops[1].crosses_pod is False
    summary = collective_summary(hlo, pod_size=2)
    assert summary["n_collectives"] == 3
    # iota groups [16,16]<=[256]T(1,0): rows stride 16 → cross "pods" of 2
    assert ops[0].crosses_pod is True


def test_param_spec_sharding_divisibility():
    from repro.models.params import ParamSpec, spec_pspec
    from jax.sharding import PartitionSpec as P

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    rules = {"embed": ("data",), "mlp": "model"}
    s = ParamSpec((100, 160), ("embed", "mlp"))
    # 100 % 16 != 0 → embed rule dropped; 160 % 16 == 0 → kept
    assert spec_pspec(s, rules, FakeMesh()) == P(None, "model")
    s2 = ParamSpec((128, 160), ("embed", "mlp"))
    assert spec_pspec(s2, rules, FakeMesh()) == P(("data",), "model")
