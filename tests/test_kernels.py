"""Pallas kernel validation: shape/dtype sweeps in interpret mode against
the pure-jnp oracles in repro/kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.chunk_scan import chunk_scan
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.router_scores import router_scores


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,dh", [
    (1, 128, 4, 4, 64),      # MHA
    (2, 256, 8, 2, 64),      # GQA 4:1
    (1, 128, 4, 1, 128),     # MQA, MXU-aligned head dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(B, S, H, KV, dh, dtype, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (B, S, H, dh), dtype)
    k = rand(ks[1], (B, S, KV, dh), dtype)
    v = rand(ks[2], (B, S, KV, dh), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_flash_attention_sliding_window():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, KV, dh, w = 1, 256, 4, 2, 64, 64
    q = rand(ks[0], (B, S, H, dh), jnp.float32)
    k = rand(ks[1], (B, S, KV, dh), jnp.float32)
    v = rand(ks[2], (B, S, KV, dh), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=w, block_q=64,
                          block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,dh", [
    (2, 128, 4, 4, 64),
    (3, 256, 8, 2, 64),
    (1, 512, 4, 1, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, S, H, KV, dh, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = rand(ks[0], (B, H, dh), dtype)
    k = rand(ks[1], (B, S, KV, dh), dtype)
    v = rand(ks[2], (B, S, KV, dh), dtype)
    pos = jax.random.randint(ks[3], (B,), 0, S)
    out = decode_attention(q, k, v, pos, block_k=64, interpret=True)
    want = ref.decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_decode_attention_ring_buffer():
    """window > 0: every slot valid once pos ≥ S_cache."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    B, S, H, KV, dh = 2, 128, 4, 2, 64
    q = rand(ks[0], (B, H, dh), jnp.float32)
    k = rand(ks[1], (B, S, KV, dh), jnp.float32)
    v = rand(ks[2], (B, S, KV, dh), jnp.float32)
    pos = jnp.asarray([40, 4000])          # one pre-wrap, one post-wrap
    out = decode_attention(q, k, v, pos, window=S, block_k=64, interpret=True)
    want = ref.decode_attention_ref(q, k, v, pos, window=S)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("pos_val", [126, 127, 128, 129, 255, 256])
def test_decode_attention_ring_wrap_boundary(pos_val):
    """The ``pos >= s_cache`` validity flip in ``_decode_kernel`` at the
    exact wrap boundary: pos = S-1 is the last masked step (slots > pos
    still invalid), pos = S is the first fully-valid step, and every later
    position stays fully valid. Checked against the jnp oracle so a fence
    error on either side of the flip fails loudly."""
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    B, S, H, KV, dh = 2, 128, 4, 2, 64
    q = rand(ks[0], (B, H, dh), jnp.float32)
    k = rand(ks[1], (B, S, KV, dh), jnp.float32)
    v = rand(ks[2], (B, S, KV, dh), jnp.float32)
    pos = jnp.asarray([pos_val, max(pos_val - 1, 0)])
    out = decode_attention(q, k, v, pos, window=S, block_k=64,
                           interpret=True)
    want = ref.decode_attention_ref(q, k, v, pos, window=S)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    if pos_val >= S:
        # post-wrap the ring is position-independent: every slot attends
        full = ref.decode_attention_ref(q, k, v,
                                        jnp.full((B,), 10 * S), window=S)
        np.testing.assert_allclose(np.asarray(out)[:1],
                                   np.asarray(full)[:1],
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("pos_val", [0, 63, 64, 127])
def test_decode_attention_full_cache_boundary(pos_val):
    """window == 0 (full cache): validity is strictly ``idx <= pos`` — in
    particular the final position S-1 attends over the whole cache and
    block boundaries (block_k=64) introduce no fence error."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    B, S, H, KV, dh = 2, 128, 4, 2, 64
    q = rand(ks[0], (B, H, dh), jnp.float32)
    k = rand(ks[1], (B, S, KV, dh), jnp.float32)
    v = rand(ks[2], (B, S, KV, dh), jnp.float32)
    pos = jnp.asarray([pos_val, S - 1 - pos_val])
    out = decode_attention(q, k, v, pos, block_k=64, interpret=True)
    want = ref.decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# router scores
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,K,D", [(8, 2, 32), (100, 6, 64), (256, 16, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("tau", [1.0, 10.0])
def test_router_scores(B, K, D, dtype, tau):
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    x = rand(ks[0], (B, D), dtype)
    c = rand(ks[1], (K, D), dtype)
    out = router_scores(x, c, tau, block_b=64, interpret=True)
    want = ref.router_scores_ref(x, c, tau)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])
    np.testing.assert_allclose(np.asarray(out, np.float32).sum(-1), 1.0,
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5)


# ---------------------------------------------------------------------------
# chunk scan (mLSTM / SSD intra-chunk)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,NC,L,H,dk,dv", [
    (1, 2, 64, 2, 32, 32),
    (2, 4, 32, 4, 16, 48),   # dk != dv (Mamba2: N != P)
    (1, 1, 128, 2, 64, 65),  # odd dv (mLSTM normalizer channel)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chunk_scan(B, NC, L, H, dk, dv, dtype):
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    qc = rand(ks[0], (B, NC, L, H, dk), dtype)
    kc = rand(ks[1], (B, NC, L, H, dk), dtype)
    vc = rand(ks[2], (B, NC, L, H, dv), dtype)
    # realistic decays: cumulative sums of negative log-gates
    logg = -jnp.abs(jax.random.normal(ks[3], (B, NC, L, H))) * 0.1
    cum = jnp.cumsum(logg, axis=2)
    intra, kv = chunk_scan(qc, kc, vc, cum, interpret=True)
    intra_ref, kv_ref = ref.chunk_scan_ref(qc, kc, vc, cum)
    tol = TOL[dtype]
    np.testing.assert_allclose(np.asarray(intra), np.asarray(intra_ref), **tol)
    np.testing.assert_allclose(np.asarray(kv), np.asarray(kv_ref), **tol)


# ---------------------------------------------------------------------------
# end-to-end: model forward with kernels == model forward without
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen3_8b", "xlstm_125m", "zamba2_2_7b"])
def test_model_with_kernels_matches_jnp(arch):
    from repro.configs.base import get_smoke_config
    from repro.models import build_model
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 64
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab),
             "labels": jnp.zeros((B, S), jnp.int32)}
    ref_logits = model.forward(params, batch, use_kernel=False)
    k_logits = model.forward(params, batch, use_kernel=True)
    np.testing.assert_allclose(np.asarray(k_logits), np.asarray(ref_logits),
                               rtol=2e-4, atol=2e-4)
