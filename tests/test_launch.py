"""Launch-layer unit tests that don't need the 512-device dry-run env.

NOTE: importing repro.launch.dryrun sets XLA_FLAGS, but the jax backend is
already initialized (1 CPU device) by earlier tests, so the flag is inert
here — these tests only exercise pure helpers.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# force backend init BEFORE importing dryrun so the 512-device flag is inert
_ = jax.devices()

from repro.configs.base import INPUT_SHAPES, get_config
from repro.launch import dryrun
from repro.launch.roofline import RooflineReport, model_flops


class FakeMesh:
    shape = {"data": 16, "model": 16}


def test_if_divisible():
    m = FakeMesh()
    assert dryrun._if_divisible(m, ("data",), 256) == ("data",)
    assert dryrun._if_divisible(m, ("data",), 1) is None
    assert dryrun._if_divisible(m, "model", 92_553) is None
    assert dryrun._if_divisible(m, "model", 92_672) == "model"
    assert dryrun._if_divisible(m, ("data", "model"), 512) == ("data", "model")
    assert dryrun._if_divisible(m, None, 64) is None


def test_shape_cfg_long_decode_window():
    cfg = get_config("qwen3_8b")
    assert cfg.sliding_window == 0
    long = dryrun.shape_cfg(cfg, INPUT_SHAPES["long_500k"])
    assert long.sliding_window == dryrun.LONG_DECODE_WINDOW
    # recurrent archs keep native state (no window forced)
    x = dryrun.shape_cfg(get_config("xlstm_125m"), INPUT_SHAPES["long_500k"])
    assert x.sliding_window == 0
    # other shapes unchanged
    t = dryrun.shape_cfg(cfg, INPUT_SHAPES["train_4k"])
    assert t.sliding_window == 0


def test_skip_matrix():
    assert dryrun.is_skipped("whisper_small", INPUT_SHAPES["long_500k"])
    assert not dryrun.is_skipped("whisper_small", INPUT_SHAPES["decode_32k"])
    for arch in ("llama3_405b", "xlstm_125m", "zamba2_2_7b"):
        for shape in INPUT_SHAPES.values():
            assert dryrun.is_skipped(arch, shape) is None


@pytest.mark.parametrize("arch,G,expect_layers", [
    ("qwen3_8b", 2, 2),
    ("xlstm_125m", 2, 8),        # slstm_every=4 → 4 layers per group
    ("zamba2_2_7b", 2, 12),      # shared_attn_every=6
    ("whisper_small", 1, 1),
])
def test_probe_cfg_depth_mapping(arch, G, expect_layers):
    cfg = dryrun.probe_cfg(get_config(arch), G)
    assert cfg.unroll
    assert cfg.n_layers == expect_layers
    if arch == "whisper_small":
        assert cfg.n_enc_layers == G
    # group count must equal G so the linear depth fit is valid
    from repro.models import build_model
    assert build_model(cfg).n_groups == G


def test_unrolled_forward_matches_scanned():
    """cfg.unroll must be a pure compile-strategy change."""
    from repro.configs.base import get_smoke_config
    from repro.models import build_model
    cfg = get_smoke_config("zamba2_2_7b")
    m_scan = build_model(cfg)
    m_unroll = build_model(cfg.reduced(unroll=True))
    params = m_scan.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.arange(2 * 16).reshape(2, 16) % cfg.vocab,
             "labels": jnp.zeros((2, 16), jnp.int32)}
    a = m_scan.forward(params, batch)
    b = m_unroll.forward(params, batch)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_model_flops_and_report():
    cfg = get_config("qwen3_8b")
    f = model_flops(cfg, 8_000_000_000, 1_000_000, "train")
    assert f == pytest.approx(6 * 8e9 * 1e6)
    r = RooflineReport(arch="a", shape="s", mesh="m", mode="d",
                       flops_per_device=197e12, bytes_per_device=819e9,
                       collective_bytes=25e9,
                       model_flops_per_device=98.5e12).finalize()
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.collective_s == pytest.approx(0.5)
    assert r.useful_flops_ratio == pytest.approx(0.5)
    assert r.bottleneck in ("compute", "memory")


def test_moe_active_params():
    from repro.launch.roofline import active_params
    from repro.models import build_model
    from repro.models.params import count_params
    cfg = get_config("qwen3_moe_235b_a22b")
    model = build_model(cfg)
    total = count_params(model.param_specs())
    active = active_params(cfg, total, model)
    # 128 experts, top-8 → active well under total, above dense part
    assert active < 0.25 * total
    assert active > 0.02 * total
