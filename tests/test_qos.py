"""Multi-tenant QoS: fairness, preemption, admission control.

* DRR tenant arbitration: weighted shares, refunds, idle-tenant pruning.
* Head-of-line fix: a pool-starved large prompt at the queue head no
  longer blocks smaller admissible requests behind it.
* Preempt/resume parity: with preemption forced on (tiny pool + mixed
  priorities) every request's output — greedy AND seeded — must match
  the run with preemption off and no pool pressure, token for token,
  across paged / chunked / prefix-cache / speculative configs and on
  the mixture + decentralized servers.
* Resource exactness: aborting a parked request frees its swap payload
  and pinned prefix references exactly; the PoolSanitizer stays clean
  across preempt/resume churn.
* SLO admission control: queue-depth and predicted-TTFT rejections
  retire with ``finish_reason == "rejected"`` and zero tokens.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.router import CentroidRouter, RouterConfig
from repro.models import build_model
from repro.serve.api import EngineConfig, QoSConfig, SamplingParams
from repro.serve.qos import TenantScheduler, predict_ttft
from repro.serve.scheduler import (DecentralizedSlotServer,
                                   MixtureSlotServer, Request, SlotServer)


@pytest.fixture(scope="module")
def small_model():
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def mixed_queue(cfg, *, lens, budgets, priorities, tenants=None,
                temperatures=None, seed=3):
    """Requests with mixed priorities/tenants; even ids greedy, odd ids
    seeded sampling unless ``temperatures`` overrides — one queue covers
    both parity regimes."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i, (n, m, p) in enumerate(zip(lens, budgets, priorities)):
        temp = temperatures[i] if temperatures is not None \
            else (0.0 if i % 2 == 0 else 0.8)
        sp = SamplingParams(
            max_new=m, temperature=temp, seed=100 + i, priority=p,
            tenant=tenants[i] if tenants is not None else "default")
        reqs.append(Request(i, rng.integers(0, cfg.vocab, size=n)
                            .astype(np.int32), m, params=sp))
    return reqs


# ---------------------------------------------------------------------------
# Policy objects (no engine)
# ---------------------------------------------------------------------------

def test_qos_config_validation():
    with pytest.raises(ValueError, match="weights must be > 0"):
        QoSConfig(tenant_weights=(("a", 0.0),))
    with pytest.raises(ValueError, match="quantum"):
        QoSConfig(quantum=-1)
    with pytest.raises(ValueError, match="admit_lookahead"):
        QoSConfig(admit_lookahead=0)
    assert QoSConfig(tenant_weights=(("a", 2.0),)).weight("a") == 2.0
    assert QoSConfig().weight("anyone") == 1.0


def test_engine_config_preemption_dependencies():
    with pytest.raises(ValueError, match="paging"):
        EngineConfig(preemption="swap").validate()
    with pytest.raises(ValueError, match="chunked"):
        EngineConfig(paged=True, preemption="recompute").validate()
    with pytest.raises(ValueError, match="chunked_prefill"):
        EngineConfig(qos=QoSConfig(max_predicted_ttft_s=1.0)).validate()
    # max_waiting alone needs neither paging nor chunking
    EngineConfig(qos=QoSConfig(max_waiting=4)).validate()


def test_drr_weighted_fairness():
    ts = TenantScheduler(QoSConfig(tenant_weights=(("a", 2.0), ("b", 1.0))),
                         quantum=8)
    counts = {"a": 0, "b": 0}
    for _ in range(30):
        counts[ts.pick({"a": 8, "b": 8})] += 1
    # equal costs, 2:1 weights -> a is served twice as often
    assert counts["a"] + counts["b"] == 30
    assert 1.5 <= counts["a"] / counts["b"] <= 2.5, counts


def test_drr_within_cost_proportionality():
    # with equal weights but unequal costs, token share (picks x cost)
    # equalizes: the cheap tenant is picked ~4x as often
    ts = TenantScheduler(QoSConfig(), quantum=4)
    served = {"cheap": 0, "dear": 0}
    for _ in range(50):
        t = ts.pick({"cheap": 4, "dear": 16})
        served[t] += {"cheap": 4, "dear": 16}[t]
    ratio = served["cheap"] / served["dear"]
    assert 0.5 <= ratio <= 2.0, served


def test_drr_refund_and_idle_pruning():
    ts = TenantScheduler(QoSConfig(), quantum=10)
    t = ts.pick({"a": 10, "b": 10})
    d0 = ts._deficit[t]
    ts.refund(t, 10)
    assert ts._deficit[t] == d0 + 10
    # an idle tenant drops out of the rotation and loses its deficit
    assert ts.pick({"b": 10}) == "b"
    assert "a" not in ts._deficit


def test_predict_ttft_monotone():
    assert predict_ttft(0, 16, 0.01) == pytest.approx(0.01)
    assert predict_ttft(160, 16, 0.01) == pytest.approx(0.11)
    assert predict_ttft(320, 16, 0.01) > predict_ttft(160, 16, 0.01)
    assert predict_ttft(100, 16, 0.0) == 0.0


# ---------------------------------------------------------------------------
# Head-of-line fix (no QoSConfig: the default bounded skip-ahead)
# ---------------------------------------------------------------------------

def test_admission_skip_ahead_past_starved_head(small_model):
    cfg, model, params = small_model
    server = SlotServer(model, params, config=EngineConfig(
        n_slots=2, cache_len=32, paged=True, page_block=4, pool_blocks=6))
    rng = np.random.default_rng(0)

    def prompt(n):
        return rng.integers(0, cfg.vocab, size=n).astype(np.int32)

    r0 = server.add_request(prompt(8), SamplingParams(max_new=4))
    server.step()                       # r0 decoding, holds 2 of 5 blocks
    big = server.add_request(prompt(16), SamplingParams(max_new=2))
    small = server.add_request(prompt(4), SamplingParams(max_new=6))
    server.step()
    in_slots = {r.rid for r in server.slot_req if r is not None}
    # the 4-block head request cannot fit (3 free) — the 1-block request
    # behind it must NOT be blocked by it
    assert small in in_slots and big not in in_slots
    assert [r.rid for r in server.waiting] == [big]
    # ...and the starved head still completes once blocks free up
    outs = {}
    for _ in range(200):
        for o in server.step():
            if o.finished:
                outs[o.rid] = o
        if not server.has_unfinished():
            break
    assert set(outs) == {r0, big, small}
    assert all(o.finish_reason == "length" for o in outs.values())


# ---------------------------------------------------------------------------
# Preempt/resume parity (the core invariant)
# ---------------------------------------------------------------------------

PARITY_CONFIGS = [
    ("swap_paged", dict(paged=True, page_block=4)),
    ("recompute_chunked", dict(paged=True, page_block=4,
                               chunked_prefill=True, chunk=8)),
    ("recompute_prefix", dict(paged=True, page_block=4,
                              chunked_prefill=True, chunk=8,
                              prefix_cache=True)),
    ("swap_speculative", dict(paged=True, page_block=4,
                              chunked_prefill=True, chunk=8,
                              speculative="ngram", spec_len=3)),
]


def parity_queue(cfg):
    # two low-priority requests fill both slots; the high-priority
    # arrival must preempt to get in (pool_blocks=7 -> 6 usable; each
    # low request peaks at ceil(14/4)=4 blocks)
    return mixed_queue(cfg, lens=(8, 8, 8), budgets=(6, 6, 4),
                       priorities=(0, 0, 2))


@pytest.mark.parametrize("name,knobs",
                         PARITY_CONFIGS, ids=[c[0] for c in PARITY_CONFIGS])
def test_preempt_resume_parity_slot_server(small_model, name, knobs):
    cfg, model, params = small_model
    mode = "swap" if name.startswith("swap") else "recompute"

    base = EngineConfig(n_slots=2, cache_len=32, **knobs)
    want = SlotServer(model, params, config=base).serve(parity_queue(cfg))

    tight = EngineConfig(n_slots=2, cache_len=32, pool_blocks=7,
                         preemption=mode, **knobs)
    queue = parity_queue(cfg)
    got = SlotServer(model, params, config=tight).serve(queue)

    assert sum(r.preemptions for r in queue) > 0, \
        "config did not force a preemption — the parity check is vacuous"
    assert got == want, (name, got, want)


def test_preempt_resume_parity_speculative_fallback(small_model):
    """A preemption landing mid-speculative-decode must degrade the span
    growth to vanilla cleanly (span growth never preempts) and still
    stream identical tokens."""
    cfg, model, params = small_model
    knobs = dict(paged=True, page_block=4, chunked_prefill=True, chunk=8,
                 speculative="ngram", spec_len=4)
    # repetitive prompts make the n-gram drafter actually propose spans
    toks = np.tile(np.arange(4, dtype=np.int32), 3)
    queue = [Request(i, toks.copy(), 6, params=SamplingParams(
        max_new=6, priority=p, seed=50 + i,
        temperature=0.0 if i % 2 == 0 else 0.7))
        for i, p in enumerate((0, 0, 3))]
    want = SlotServer(model, params, config=EngineConfig(
        n_slots=2, cache_len=32, **knobs)).serve(
            [Request(r.rid, r.tokens.copy(), r.max_new, params=r.params)
             for r in queue])
    srv = SlotServer(model, params, config=EngineConfig(
        n_slots=2, cache_len=32, pool_blocks=7, preemption="swap", **knobs))
    got = srv.serve(queue)
    assert sum(r.preemptions for r in queue) > 0
    assert got == want


def test_preempt_resume_parity_mixture(small_model):
    cfg, model, params = small_model
    K, Df = 2, 8
    experts = [model.init(jax.random.PRNGKey(k)) for k in range(K)]
    rng = np.random.default_rng(5)
    router = CentroidRouter(
        jnp.asarray(rng.normal(size=(K, Df)), jnp.float32),
        RouterConfig(top_k=2))
    knobs = dict(paged=True, page_block=4, chunked_prefill=True, chunk=8,
                 strategy="mixture")

    def queue():
        reqs = parity_queue(cfg)
        feats = rng.spawn(1)[0]  # unused; deterministic features below
        for i, r in enumerate(reqs):
            r.features = np.linspace(-1.0, 1.0, Df).astype(np.float32) \
                * (i + 1)
        return reqs

    want = MixtureSlotServer(model, experts, router, config=EngineConfig(
        n_slots=2, cache_len=32, **knobs)).serve(queue())
    reqs = queue()
    got = MixtureSlotServer(model, experts, router, config=EngineConfig(
        n_slots=2, cache_len=32, pool_blocks=7, preemption="recompute",
        **knobs)).serve(reqs)
    assert sum(r.preemptions for r in reqs) > 0
    assert got == want


def test_preempt_resume_parity_decentralized_top1(small_model):
    cfg, model, params = small_model
    K, Df = 2, 8
    experts = [model.init(jax.random.PRNGKey(k)) for k in range(K)]
    rng = np.random.default_rng(6)
    router = CentroidRouter(
        jnp.asarray(rng.normal(size=(K, Df)), jnp.float32),
        RouterConfig(top_k=1))
    knobs = dict(paged=True, page_block=4, chunked_prefill=True, chunk=8,
                 strategy="top1")
    feats = np.ones((3, Df), np.float32)   # all land on one pod -> pressure

    def queue():
        reqs = parity_queue(cfg)
        for i, r in enumerate(reqs):
            r.features = feats[i]
        return reqs

    want = DecentralizedSlotServer(
        model, experts, router, config=EngineConfig(
            n_slots=2, cache_len=32, **knobs)).serve(queue())
    reqs = queue()
    got = DecentralizedSlotServer(
        model, experts, router, config=EngineConfig(
            n_slots=2, cache_len=32, pool_blocks=7,
            preemption="recompute", **knobs)).serve(reqs)
    assert sum(r.preemptions for r in reqs) > 0
    assert got == want


# ---------------------------------------------------------------------------
# Resource exactness around parks
# ---------------------------------------------------------------------------

def drive_until(server, pred, max_steps=200):
    outs = []
    for _ in range(max_steps):
        outs += server.step()
        if pred():
            return outs
    raise AssertionError("condition never reached")


def test_abort_parked_frees_swapped_state_exactly(small_model):
    cfg, model, params = small_model
    server = SlotServer(model, params, config=EngineConfig(
        n_slots=2, cache_len=32, paged=True, page_block=4, pool_blocks=7,
        chunked_prefill=True, chunk=8, prefix_cache=True,
        preemption="swap", sanitize=True))
    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg.vocab, size=8).astype(np.int32)

    def prompt():
        return np.concatenate(
            [shared, rng.integers(0, cfg.vocab, size=4).astype(np.int32)])

    free0 = server.allocator.n_free
    low = server.add_request(prompt(), SamplingParams(max_new=8, priority=0))
    drive_until(server, lambda: low in
                {r.rid for s, r in enumerate(server.slot_req)
                 if r is not None and not server.prefilling[s]})
    # two high-priority arrivals force the low one out
    his = [server.add_request(prompt(),
                              SamplingParams(max_new=4, priority=2))
           for _ in range(2)]
    drive_until(server, lambda: low in server._parked)
    st = server._parked[low]
    assert st.mode == "swap" and st.payload is not None
    held_before = server.allocator.n_free
    out = server.abort(low)                  # abort() runs check_pool()
    assert out.finish_reason == "aborted"
    assert low not in server._parked and st.pinned == ()
    assert st.payload is None
    # the park itself held no pool blocks beyond its pins — aborting it
    # must not free pool blocks directly (pins return refs, not blocks)
    assert server.allocator.n_free >= held_before
    drive_until(server, lambda: not server.has_unfinished())
    stats = server.stats()
    assert stats["pool_free_blocks"] == \
        stats["pool_blocks"] - 1 - stats["prefix_cached_blocks"]
    assert server.allocator.n_free == free0 - stats["prefix_cached_blocks"]
    server.sanitizer.check_pool()            # and the full scan agrees


def test_sanitizer_clean_across_preempt_resume_churn(small_model):
    cfg, model, params = small_model
    qos = QoSConfig(tenant_weights=(("a", 2.0), ("b", 1.0)))
    server = SlotServer(model, params, config=EngineConfig(
        n_slots=2, cache_len=32, paged=True, page_block=4, pool_blocks=7,
        chunked_prefill=True, chunk=8, prefix_cache=True,
        preemption="recompute", qos=qos, sanitize=True))
    queue = mixed_queue(
        cfg, lens=(8, 8, 8, 8, 8, 8), budgets=(6, 6, 4, 4, 5, 5),
        priorities=(0, 0, 2, 2, 1, 0),
        tenants=("a", "b", "a", "b", "a", "b"))
    out = server.serve(queue)               # sanitizer raises on any drift
    assert len(out) == 6
    assert sum(r.preemptions for r in queue) > 0
    assert server.sanitizer.violations == 0
    assert server.sanitizer.checked_steps > 0


# ---------------------------------------------------------------------------
# Admission control + tenant accounting
# ---------------------------------------------------------------------------

def test_admission_rejects_on_queue_depth(small_model):
    cfg, model, params = small_model
    server = SlotServer(model, params, config=EngineConfig(
        n_slots=1, cache_len=32, qos=QoSConfig(max_waiting=2)))
    rng = np.random.default_rng(9)
    rids = [server.add_request(
        rng.integers(0, cfg.vocab, size=6).astype(np.int32),
        SamplingParams(max_new=2, tenant="t")) for _ in range(3)]
    assert len(server.waiting) == 2          # the third was shed
    outs = {}
    for _ in range(100):
        for o in server.step():
            if o.finished:
                outs[o.rid] = o
        if not server.has_unfinished():
            break
    assert outs[rids[2]].finish_reason == "rejected"
    assert outs[rids[2]].token_ids == []
    assert outs[rids[0]].finish_reason == "length"
    assert outs[rids[1]].finish_reason == "length"
    assert server.stats()["tenants"]["t"]["rejections"] == 1


def test_admission_rejects_on_predicted_ttft(small_model):
    cfg, model, params = small_model
    server = SlotServer(model, params, config=EngineConfig(
        n_slots=1, cache_len=32, paged=True, page_block=4,
        chunked_prefill=True, chunk=4,
        qos=QoSConfig(max_predicted_ttft_s=0.05)))
    rng = np.random.default_rng(10)
    # before any step the EWMA is cold: accepted unconditionally
    ok = server.add_request(
        rng.integers(0, cfg.vocab, size=8).astype(np.int32),
        SamplingParams(max_new=2))
    server._step_ewma = 10.0                 # force a saturated backlog ETA
    shed = server.add_request(
        rng.integers(0, cfg.vocab, size=8).astype(np.int32),
        SamplingParams(max_new=2))
    server._step_ewma = 0.0                  # let the real run proceed
    outs = {}
    for _ in range(100):
        for o in server.step():
            if o.finished:
                outs[o.rid] = o
        if not server.has_unfinished():
            break
    assert outs[shed].finish_reason == "rejected"
    assert outs[ok].finish_reason == "length"


def test_stats_tenant_breakdown(small_model):
    cfg, model, params = small_model
    qos = QoSConfig(tenant_weights=(("a", 3.0),))
    server = SlotServer(model, params, config=EngineConfig(
        n_slots=2, cache_len=32, paged=True, page_block=4, pool_blocks=7,
        chunked_prefill=True, chunk=8, preemption="recompute", qos=qos))
    queue = mixed_queue(cfg, lens=(8, 8, 8, 8), budgets=(6, 6, 6, 6),
                        priorities=(0, 0, 2, 2),
                        tenants=("a", "b", "a", "b"))
    out = server.serve(queue)
    st = server.stats()
    assert set(st["tenants"]) == {"a", "b"}
    for t in ("a", "b"):
        emitted = sum(len(out[r.rid]) for r in queue
                      if r.params.tenant == t)
        assert st["tenants"][t]["tokens"] == emitted
        assert st["tenants"][t]["active_slots"] == 0
        assert st["tenants"][t]["pool_blocks"] == 0
    total_preempts = sum(st["tenants"][t]["preemptions"]
                         for t in ("a", "b"))
    assert total_preempts == sum(r.preemptions for r in queue) > 0
    assert st["parked"] == 0
