"""PoolSanitizer dynamic checks: clean runs, fault injection, and the
BlockAllocator generation counters.

Fault-injection tests corrupt a live server's block bookkeeping the same
way the historical bugs did (PR 4's refcount-0 eviction aliasing, leaked
blocks at abort, write-aliasing across slots) and assert the sanitizer
names the offending slot/block. The clean-run test doubles as the
observation-only contract: sanitized serving must be token-for-token
identical to plain serving.

All tests carry the ``sanitize`` marker — the CI analysis job runs them
with ``pytest -m sanitize``.
"""
import jax
import numpy as np
import pytest

from repro.analysis.sanitizer import PoolSanitizer, PoolSanitizerError
from repro.configs.base import get_smoke_config
from repro.models import build_model
from repro.serve.api import EngineConfig, SamplingParams
from repro.serve.scheduler import (BlockAllocator, Request, SlotServer,
                                   make_chunk_fns, make_fused_fns,
                                   make_serve_fns)

pytestmark = pytest.mark.sanitize

CACHE_LEN, BLOCK, CHUNK = 32, 8, 8


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    fns = {
        "serve_fns": make_serve_fns(model, CACHE_LEN, paged=True),
        "fused_fns": make_fused_fns(model, CACHE_LEN, paged=True),
    }
    cfns = {
        "serve_fns": fns["serve_fns"],
        "chunk_fns": make_chunk_fns(model, CACHE_LEN, CHUNK, paged=True),
        "fused_fns": make_fused_fns(model, CACHE_LEN, CHUNK, paged=True),
    }
    return cfg, model, params, fns, cfns


def prompt(cfg, n, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab, size=n).astype(np.int32)


def paged_server(model, params, fns, *, sanitize=True, n_slots=2):
    return SlotServer(model, params, **fns, config=EngineConfig(
        n_slots=n_slots, cache_len=CACHE_LEN, paged=True, page_block=BLOCK,
        sanitize=sanitize))


def prefix_server(model, params, cfns, *, sanitize=True, n_slots=3):
    return SlotServer(model, params, **cfns, config=EngineConfig(
        n_slots=n_slots, cache_len=CACHE_LEN, paged=True, page_block=BLOCK,
        chunked_prefill=True, chunk=CHUNK, prefix_cache=True,
        sanitize=sanitize))


def steps_until(srv, pred, limit=30):
    for _ in range(limit):
        if pred():
            return
        srv.step()
    raise AssertionError("server never reached the expected state")


# ---------------------------------------------------------------------------
# clean runs: observation-only, counters exposed
# ---------------------------------------------------------------------------

def test_clean_run_parity_and_counters(setup):
    cfg, model, params, _, cfns = setup
    queue = lambda: [Request(i, prompt(cfg, n, i), m) for i, (n, m)
                     in enumerate(zip((7, 12, 16, 9), (6, 4, 8, 5)))]
    plain = prefix_server(model, params, cfns, sanitize=False)
    assert plain.sanitizer is None
    want = plain.serve(queue())

    san = prefix_server(model, params, cfns, sanitize=True)
    assert isinstance(san.sanitizer, PoolSanitizer)
    got = san.serve(queue())
    assert got == want, "sanitized serving diverged from plain"

    st = san.stats()
    assert st["sanitize_checked_steps"] > 0
    assert st["sanitize_violations"] == 0
    # after full retirement the only non-free blocks are the cache-
    # resident (refcount-0, LRU-evictable) prefix blocks
    assert st["sanitize_owned_blocks"] == len(san.prefix._ref)
    # the stats surface is additive: the usual serving counters remain
    assert "pool_free_blocks" in st and "active" in st


def test_sanitize_requires_paged(setup):
    with pytest.raises(ValueError, match="paging"):
        EngineConfig(n_slots=2, cache_len=32, sanitize=True).validate()

    class NotPaged:
        paged = False
    with pytest.raises(ValueError, match="paged"):
        PoolSanitizer(NotPaged())


# ---------------------------------------------------------------------------
# fault injection: each historical bug shape must be named
# ---------------------------------------------------------------------------

def test_duplicate_block_across_slots(setup):
    """The write-aliasing shape: one physical block mapped writable into
    two slots without a prefix-cache refcount."""
    cfg, model, params, fns, _ = setup
    srv = paged_server(model, params, fns)
    srv.add_request(prompt(cfg, 12, 1), SamplingParams(max_new=8), rid=0)
    srv.add_request(prompt(cfg, 12, 2), SamplingParams(max_new=8), rid=1)
    steps_until(srv, lambda: len(srv.decoding) == 2)

    s1, s2 = sorted(srv.decoding)[:2]
    pb = int(srv.block_tables[s1, 0])
    srv.block_tables[s2, 0] = pb
    srv.block_gens[s2, 0] = srv.allocator.gen[pb]
    with pytest.raises(PoolSanitizerError,
                       match=f"block {pb} mapped writable into 2 slots"):
        srv.sanitizer.check_pool()


def test_decode_write_into_cached_block(setup):
    """Cached blocks are immutable — a decode write re-routed into one
    would corrupt every future prefix hit."""
    cfg, model, params, _, cfns = setup
    srv = prefix_server(model, params, cfns)
    warm = prompt(cfg, 16, 3)
    srv.serve([Request(100, warm, 1)])            # 2 full blocks cached
    tracked_pb = next(iter(srv.prefix._ref))

    srv.add_request(prompt(cfg, 12, 4), SamplingParams(max_new=8), rid=0)
    steps_until(srv, lambda: 0 in srv.decoding
                and int(srv.pos[0]) % BLOCK not in (0,))
    slot = 0
    lb = srv.sanitizer._logical_block(int(srv.pos[slot]))
    assert lb < int(srv.n_alloc[slot])
    srv.block_tables[slot, lb] = tracked_pb
    srv.block_gens[slot, lb] = srv.allocator.gen[tracked_pb]

    srv.sanitizer.begin_step()
    with pytest.raises(PoolSanitizerError,
                       match=f"cache-tracked block {tracked_pb}"):
        srv.sanitizer.check_step()


def test_chunk_write_into_shared_prefix_block(setup):
    """A prefill chunk steered into a refcount>1 block: the matched run is
    read-only; prefill must start past it."""
    cfg, model, params, _, cfns = setup
    srv = prefix_server(model, params, cfns)
    shared = prompt(cfg, 16, 5)
    srv.serve([Request(100, shared, 1)])          # warm the radix tree
    srv.add_request(shared, SamplingParams(max_new=16), rid=0)
    srv.add_request(shared, SamplingParams(max_new=16), rid=1)
    steps_until(srv, lambda: len(srv.decoding) == 2)
    pb = next(b for b, r in srv.prefix._ref.items() if r >= 2)

    # a third request mid-prefill on a DIFFERENT prompt; fake its next
    # chunk's block reservation as the shared block
    srv.add_request(prompt(cfg, 16, 6), SamplingParams(max_new=4), rid=2)
    steps_until(srv, lambda: bool(srv.prefill_order)
                and int(srv.prefill_pos[srv.prefill_order[0]]) >= CHUNK)
    slot = srv.prefill_order[0]
    lb = int(srv.prefill_pos[slot]) // BLOCK
    srv.block_tables[slot, lb] = pb
    srv.block_gens[slot, lb] = srv.allocator.gen[pb]
    srv.n_alloc[slot] = max(int(srv.n_alloc[slot]), lb + 1)
    srv.prefix.acquire([pb])                      # keep refcount == holders

    srv.sanitizer.begin_step()
    with pytest.raises(PoolSanitizerError,
                       match=f"shared prefix block {pb}"):
        srv.sanitizer.check_step()


def test_leak_at_abort(setup):
    """A slot whose accounting forgets its blocks leaks them from the pool
    — caught at the abort boundary, with the block ids named."""
    cfg, model, params, fns, _ = setup
    srv = paged_server(model, params, fns)
    srv.add_request(prompt(cfg, 12, 7), SamplingParams(max_new=8), rid=0)
    steps_until(srv, lambda: 0 in srv.decoding)
    held = srv.block_tables[0, :int(srv.n_alloc[0])].tolist()
    srv.n_alloc[0] = 0                            # "forget" the reservation
    with pytest.raises(PoolSanitizerError, match="leaked block"):
        srv.abort(0)
    msg_blocks = held
    assert msg_blocks                              # blocks really were held


def test_pr4_refcount0_eviction_aliasing(setup):
    """The PR 4 regression fixture: a cached block a live request still
    maps must never sit on the LRU list, where pool pressure could evict
    and reissue it."""
    cfg, model, params, _, cfns = setup
    srv = prefix_server(model, params, cfns)
    shared = prompt(cfg, 16, 8)
    srv.serve([Request(100, shared, 1)])
    srv.add_request(shared, SamplingParams(max_new=8), rid=0)
    steps_until(srv, lambda: 0 in srv.decoding)
    pb = next(b for b, r in srv.prefix._ref.items() if r >= 1)
    assert pb not in srv.prefix._lru               # invariant before injection
    srv.prefix._lru[pb] = None                     # re-create the PR 4 state
    with pytest.raises(PoolSanitizerError, match="PR 4 aliasing bug"):
        srv.sanitizer.check_pool()


# ---------------------------------------------------------------------------
# BlockAllocator generation counters (use-after-free)
# ---------------------------------------------------------------------------

def test_allocator_generation_counters():
    alloc = BlockAllocator(8)
    (b,) = alloc.alloc(1)
    g = alloc.gen[b]
    alloc.assert_live(b, g)                        # live: no raise
    alloc.free([b])
    with pytest.raises(ValueError, match=f"use-after-free: block {b}"):
        alloc.assert_live(b, g, owner="slot 0 entry 0")
    # reissue: the new holder stamps the bumped generation and is live
    (b2,) = alloc.alloc(1)
    assert b2 == b and alloc.gen[b2] == g + 1
    alloc.assert_live(b2, alloc.gen[b2])


def test_use_after_free_caught_at_release(setup):
    """The production guard (independent of sanitize=True): releasing a
    slot whose block was freed behind the table's back raises instead of
    double-freeing / aliasing the block's new owner."""
    cfg, model, params, fns, _ = setup
    srv = paged_server(model, params, fns, sanitize=False)
    srv.add_request(prompt(cfg, 12, 9), SamplingParams(max_new=8), rid=0)
    steps_until(srv, lambda: 0 in srv.decoding)
    b = int(srv.block_tables[0, 0])
    srv.allocator.free([b])                        # stale table reference
    with pytest.raises(ValueError, match=f"use-after-free: block {b}"):
        srv.abort(0)


def test_use_after_free_caught_by_sanitizer(setup):
    cfg, model, params, fns, _ = setup
    srv = paged_server(model, params, fns)
    srv.add_request(prompt(cfg, 12, 10), SamplingParams(max_new=8), rid=0)
    steps_until(srv, lambda: 0 in srv.decoding)
    b = int(srv.block_tables[0, 0])
    srv.allocator.free([b])
    with pytest.raises(PoolSanitizerError, match="use-after-free"):
        srv.sanitizer.check_pool()
