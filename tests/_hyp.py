"""Hypothesis import shim: property tests degrade to deterministic
pseudo-random sampling when ``hypothesis`` is not installed (the seed
container ships without it; ``pip install -e .[test]`` restores the real
thing).

The fallback implements exactly the surface the test modules use —
``@settings(max_examples=..., deadline=None)`` over ``@given(**strategies)``
with ``st.integers`` / ``st.sampled_from`` — drawing each example from a
fixed-seed ``random.Random`` so failures reproduce.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 — mirrors `strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def given(**strategies):
        def deco(fn):
            # NOTE: no functools.wraps — pytest must see a zero-argument
            # signature, not the strategy parameters (it would look for
            # fixtures named after them).
            def run():
                n = getattr(run, "_max_examples", 10)
                rng = random.Random(0)
                for _ in range(n):
                    drawn = {name: s.draw(rng)
                             for name, s in strategies.items()}
                    fn(**drawn)
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run.hypothesis_fallback = True
            return run
        return deco

    def settings(max_examples: int = 10, deadline=None, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco
