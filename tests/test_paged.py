"""Paged KV cache correctness.

* The paged Pallas decode kernel must match the jnp paged oracle (which is
  itself defined as gather-then-contiguous-oracle).
* A paged ``SlotServer`` must produce greedy outputs identical to the
  contiguous-cache path for every attention family — and a request whose
  output exceeds its initial block reservation must complete un-truncated
  (impossible with fixed cache rows).
* The block allocator must recycle blocks across requests, block admission
  (not drop requests) when the pool is momentarily full, and fail loudly
  when a growing request exhausts it.
* Capacity retirement is exact (position cache_len - 1 decodable) and
  marks ``Request.truncated`` instead of masquerading as completion.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.router import CentroidRouter, RouterConfig
from repro.kernels import ref
from repro.kernels.decode_attention import paged_decode_attention
from repro.models import build_model
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import (BlockAllocator, MixtureSlotServer,
                                   Request, SlotServer)

from test_scheduler import engine_greedy, make_requests

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# Paged decode kernel vs jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,NB,block,H,KV,dh", [
    (2, 4, 32, 4, 4, 64),     # MHA
    (3, 8, 16, 8, 2, 64),     # GQA 4:1
    (1, 4, 64, 4, 1, 128),    # MQA, MXU-aligned head dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_kernel(B, NB, block, H, KV, dh, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    P = B * NB + 3                        # pool bigger than needed
    q = rand(ks[0], (B, H, dh), dtype)
    kp = rand(ks[1], (P, block, KV, dh), dtype)
    vp = rand(ks[2], (P, block, KV, dh), dtype)
    rng = np.random.default_rng(0)
    # distinct physical blocks per slot; block 0 reserved (scratch)
    bt = jnp.asarray(rng.permutation(np.arange(1, P))[:B * NB]
                     .reshape(B, NB), jnp.int32)
    pos = jax.random.randint(ks[3], (B,), 0, NB * block)
    out = paged_decode_attention(q, kp, vp, pos, bt, interpret=True)
    want = ref.paged_decode_attention_ref(q, kp, vp, pos, bt)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("pos_vals", [(3, 60), (64, 200), (63, 64)])
def test_paged_decode_kernel_ring(pos_vals):
    """window > 0: the slot's logical span NB·block is a ring buffer."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, NB, block, H, KV, dh = 2, 4, 16, 4, 2, 64
    P = B * NB + 1
    q = rand(ks[0], (B, H, dh), jnp.float32)
    kp = rand(ks[1], (P, block, KV, dh), jnp.float32)
    vp = rand(ks[2], (P, block, KV, dh), jnp.float32)
    rng = np.random.default_rng(1)
    bt = jnp.asarray(rng.permutation(np.arange(1, P)).reshape(B, NB),
                     jnp.int32)
    pos = jnp.asarray(pos_vals, jnp.int32)
    out = paged_decode_attention(q, kp, vp, pos, bt, window=NB * block,
                                 interpret=True)
    want = ref.paged_decode_attention_ref(q, kp, vp, pos, bt,
                                          window=NB * block)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("block,NB", [(8, 6), (16, 4), (32, 3), (64, 2)])
@pytest.mark.parametrize("bps", [2, 3, 4])
def test_paged_decode_kernel_blocks_per_step(block, NB, bps):
    """Multi-block grid steps (wider KV tiles over the scalar-prefetched
    table) must be bit-identical to bps=1: sub-tiles accumulate in
    ascending logical order, past-the-horizon sub-tiles are skipped via
    the pos-derived ``live`` bound, and the padded tail when bps does not
    divide NB is killed by the ``ki < nb`` guard."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    B, H, KV, dh = 3, 8, 4, 32
    P = B * NB + 2
    q = rand(ks[0], (B, H, dh), jnp.float32)
    kp = rand(ks[1], (P, block, KV, dh), jnp.float32)
    vp = rand(ks[2], (P, block, KV, dh), jnp.float32)
    rng = np.random.default_rng(3)
    bt = jnp.asarray(rng.permutation(np.arange(1, P))[:B * NB]
                     .reshape(B, NB), jnp.int32)
    # cover empty, mid-block, block-boundary and full horizons
    pos = jnp.asarray([0, block * (NB // 2), NB * block - 1][:B], jnp.int32)
    base = paged_decode_attention(q, kp, vp, pos, bt, interpret=True)
    want = ref.paged_decode_attention_ref(q, kp, vp, pos, bt)
    out = paged_decode_attention(q, kp, vp, pos, bt, blocks_per_step=bps,
                                 interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(base))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # ring-window variant keeps the whole span live once wrapped
    outw = paged_decode_attention(q, kp, vp, pos, bt, window=NB * block,
                                  blocks_per_step=bps, interpret=True)
    wantw = ref.paged_decode_attention_ref(q, kp, vp, pos, bt,
                                           window=NB * block)
    np.testing.assert_allclose(np.asarray(outw), np.asarray(wantw),
                               rtol=2e-5, atol=2e-5)


def test_paged_ref_equals_contiguous_gather():
    """The paged oracle over an identity block table IS the contiguous
    oracle — the indirection is pure layout."""
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    B, NB, block, H, KV, dh = 2, 4, 16, 4, 2, 32
    q = rand(ks[0], (B, H, dh), jnp.float32)
    k = rand(ks[1], (B, NB * block, KV, dh), jnp.float32)
    v = rand(ks[2], (B, NB * block, KV, dh), jnp.float32)
    pos = jax.random.randint(ks[3], (B,), 0, NB * block)
    kp = k.reshape(B * NB, block, KV, dh)
    vp = v.reshape(B * NB, block, KV, dh)
    bt = jnp.arange(B * NB, dtype=jnp.int32).reshape(B, NB)
    got = ref.paged_decode_attention_ref(q, kp, vp, pos, bt)
    want = ref.decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Block allocator
# ---------------------------------------------------------------------------

def test_block_allocator_recycles_and_reserves_scratch():
    alloc = BlockAllocator(6)             # blocks 1..5 allocatable
    a = alloc.alloc(3)
    assert a is not None and len(set(a)) == 3 and 0 not in a
    assert alloc.alloc(3) is None         # only 2 left: all-or-nothing
    assert alloc.n_free == 2              # the failed alloc took nothing
    b = alloc.alloc(2)
    assert alloc.n_free == 0
    alloc.free(a)
    c = alloc.alloc(3)
    assert sorted(c) == sorted(a)         # recycled
    assert 0 not in set(b) | set(c)
    with pytest.raises(ValueError):
        BlockAllocator(1)                 # scratch block alone is no pool


# ---------------------------------------------------------------------------
# Paged SlotServer == contiguous SlotServer (per family)
# ---------------------------------------------------------------------------

PAGED_FAMILY_ARCHS = [
    ("qwen3_8b", "dense"),
    ("deepseek_moe_16b", "moe"),
    ("internvl2_2b", "vlm"),
    ("whisper_small", "audio"),
    ("zamba2_2_7b", "hybrid"),
    ("xlstm_125m", "ssm"),      # no pageable leaves: must degrade cleanly
]


@pytest.mark.parametrize("arch,family", PAGED_FAMILY_ARCHS)
def test_paged_slot_server_matches_contiguous(arch, family):
    cfg = get_smoke_config(arch).reduced(vocab=256)
    assert cfg.family == family
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache_len = 40
    lens, budgets = (7, 11, 5), (4, 3, 5)

    ref_srv = SlotServer(model, params, n_slots=2, cache_len=cache_len)
    want = ref_srv.serve(make_requests(cfg, lens, budgets))

    paged_q = make_requests(cfg, lens, budgets)
    paged = SlotServer(model, params, n_slots=2, cache_len=cache_len,
                       page_block=8)
    got = paged.serve(paged_q)
    assert set(got) == set(want)
    for rid in want:
        assert got[rid] == want[rid], (arch, rid, got[rid], want[rid])
    assert paged.active == []
    assert not any(r.truncated for r in paged_q)
    if paged.paged:
        assert paged.allocator.n_free == paged.allocator.n_blocks - 1


def test_paged_slot_server_use_kernel_parity():
    """The Pallas paged decode kernel (interpret mode on CPU) must be
    reachable from continuous batching and agree with both jnp paths."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def queue():
        return make_requests(cfg, (8, 8), (3, 3), seed=7)

    want = SlotServer(model, params, n_slots=2, cache_len=16).serve(queue())
    jnp_paged = SlotServer(model, params, n_slots=2, cache_len=16,
                           page_block=8).serve(queue())
    ker_paged = SlotServer(model, params, n_slots=2, cache_len=16,
                           page_block=8, use_kernel=True).serve(queue())
    assert want == jnp_paged == ker_paged


def test_paged_sliding_window_ring_parity():
    """Windowed configs page the ring: the slot's bounded span is fully
    reserved at admission and wraps exactly like the contiguous ring."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=128, sliding_window=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def queue():
        return make_requests(cfg, (6, 4), (12, 14), seed=3)

    want = SlotServer(model, params, n_slots=2, cache_len=32).serve(queue())
    got = SlotServer(model, params, n_slots=2, cache_len=32,
                     page_block=4).serve(queue())
    assert want == got
    assert any(len(v) > 8 for v in got.values())   # decoded past the window


# ---------------------------------------------------------------------------
# The tentpole property: decode past the initial reservation
# ---------------------------------------------------------------------------

def test_paged_request_grows_past_initial_reservation():
    """A request whose output exceeds its admission-time block reservation
    completes un-truncated — the lazy allocator grows it block by block."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.random.default_rng(5).integers(0, cfg.vocab, size=4) \
        .astype(np.int32)
    req = Request(0, prompt, max_new=20)
    srv = SlotServer(model, params, n_slots=1, cache_len=32, page_block=8,
                     pool_blocks=5)
    assert srv.admit(req)
    assert int(srv.n_alloc[0]) == 1       # prompt fits one block
    peak = 1
    while srv.active:
        srv.step()
        peak = max(peak, int(srv.n_alloc[0]) or peak)
    assert peak == 3                      # grew to cover positions 4..23
    assert len(req.out) == 20 and not req.truncated
    want = SlotServer(model, params, n_slots=1, cache_len=32).serve(
        [Request(0, prompt, max_new=20)])
    assert req.out == want[0]


def test_paged_admission_waits_for_free_blocks():
    """A momentarily-full pool delays admission (continuous admission picks
    the request up when retirements free blocks) — it never drops it."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab, size=5).astype(np.int32)
               for _ in range(3)]

    def queue():
        return [Request(i, p, max_new=3) for i, p in enumerate(prompts)]

    want = SlotServer(model, params, n_slots=2, cache_len=16).serve(queue())
    # 1 usable block (pool=2 incl. scratch): strictly one request in flight
    srv = SlotServer(model, params, n_slots=2, cache_len=16, page_block=8,
                     pool_blocks=2)
    got = srv.serve(queue())
    assert got == want
    assert srv.allocator.n_free == 1


def test_paged_pool_exhaustion_raises():
    """Growth past what the pool can hold fails loudly (preemption is the
    roadmap answer), never silently truncates."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.random.default_rng(2).integers(0, cfg.vocab, size=5) \
        .astype(np.int32)
    srv = SlotServer(model, params, n_slots=1, cache_len=32, page_block=8,
                     pool_blocks=2)
    with pytest.raises(RuntimeError, match="pool exhausted"):
        srv.serve([Request(0, prompt, max_new=20)])


# ---------------------------------------------------------------------------
# Capacity-exact truncation semantics (contiguous AND paged)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("page_block", [0, 8])
def test_capacity_retirement_is_exact_and_flagged(page_block):
    """cache_len=12, prompt=8 → exactly 5 tokens fit (1 prefill + writes at
    positions 8..11). The seed's off-by-one stopped at 4; and a capacity
    retirement must be distinguishable from completion."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.random.default_rng(3).integers(0, cfg.vocab, size=8) \
        .astype(np.int32)
    cache_len = 12

    trunc = Request(0, prompt, max_new=10)
    srv = SlotServer(model, params, n_slots=1, cache_len=cache_len,
                     page_block=page_block)
    out = srv.serve([trunc])
    assert len(out[0]) == 5 and trunc.truncated

    # greedy reference: the truncated output is an exact prefix
    engine = ServeEngine(model, cache_len)
    want = engine_greedy(engine, params, Request(1, prompt, max_new=5))
    assert out[0] == want

    # a request that finishes exactly at capacity is NOT truncated
    exact = Request(2, prompt, max_new=5)
    out2 = SlotServer(model, params, n_slots=1, cache_len=cache_len,
                      page_block=page_block).serve([exact])
    assert out2[2] == want and not exact.truncated


@pytest.mark.parametrize("page_block", [0, 8])
def test_prompt_exceeding_context_rejected_before_prefill(page_block):
    """W > cache_len cannot even prefill into a cache row: admission must
    reject it with a clear error, not crash inside jnp.pad mid-queue."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.random.default_rng(6).integers(0, cfg.vocab, size=20) \
        .astype(np.int32)
    srv = SlotServer(model, params, n_slots=1, cache_len=16,
                     page_block=page_block)
    with pytest.raises(ValueError, match="serving context"):
        srv.serve([Request(0, prompt, max_new=4)])


def test_paged_degrades_to_direct_for_recurrent_family():
    """ssm has no pageable cache leaves: page_block must not spin up pool
    accounting that backs no memory (a tiny pool used to raise 'pool
    exhausted' here even though nothing was paged)."""
    cfg = get_smoke_config("xlstm_125m").reduced(vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    srv = SlotServer(model, params, n_slots=2, cache_len=32, page_block=8,
                     pool_blocks=2)
    assert not srv.paged
    got = srv.serve(make_requests(cfg, (6, 9), (8, 5)))
    want = SlotServer(model, params, n_slots=2, cache_len=32).serve(
        make_requests(cfg, (6, 9), (8, 5)))
    assert got == want


@pytest.mark.parametrize("page_block", [0, 8])
def test_prompt_filling_context_retires_at_admission(page_block):
    """prompt_len == cache_len: the request keeps its single prefill token
    and retires truncated without ever occupying a slot."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.random.default_rng(4).integers(0, cfg.vocab, size=16) \
        .astype(np.int32)
    req = Request(0, prompt, max_new=4)
    srv = SlotServer(model, params, n_slots=1, cache_len=16,
                     page_block=page_block)
    out = srv.serve([req])
    assert len(out[0]) == 1 and req.truncated
    assert srv.active == []
    engine = ServeEngine(model, 16)
    assert out[0] == engine_greedy(engine, params,
                                   Request(1, prompt, max_new=1))


# ---------------------------------------------------------------------------
# Paged mixture core (stacked dexpert dim shares one block table per slot)
# ---------------------------------------------------------------------------

def test_paged_mixture_matches_contiguous_mixture():
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=128)
    model = build_model(cfg)
    K, Df, B = 3, 16, 4
    experts = [model.init(jax.random.PRNGKey(k)) for k in range(K)]
    rng = np.random.default_rng(1)
    router = CentroidRouter(
        jnp.asarray(rng.normal(size=(K, Df)), jnp.float32),
        RouterConfig(top_k=2))
    toks = rng.integers(0, cfg.vocab, size=(B, 10)).astype(np.int32)
    feats = rng.normal(size=(B, Df)).astype(np.float32)

    def queue():
        return [Request(i, toks[i], 5, features=feats[i]) for i in range(B)]

    want = MixtureSlotServer(model, experts, router, n_slots=2,
                             cache_len=24).serve(queue())
    got = MixtureSlotServer(model, experts, router, n_slots=2, cache_len=24,
                            page_block=8).serve(queue())
    assert got == want


# ---------------------------------------------------------------------------
# Sharding: block-pool placement
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen3_8b", "zamba2_2_7b"])
def test_paged_pool_pspec_layout(arch):
    """Pool leaves shard the physical-block axis over the kv-cache batch
    axes and kv-heads over model; direct leaves keep their contiguous
    placement; the stacked variant carries ``dexpert`` (pod) at axis 1."""
    from jax.sharding import Mesh
    from repro.sharding.rules import (cache_pspec_tree, logical_rules,
                                      paged_pool_pspec_tree,
                                      stacked_cache_pspec_tree)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("pod", "data", "model"))
    rules = logical_rules(multi_pod=True, decentralized=True)
    model = build_model(get_smoke_config(arch))
    spec = model.cache_spec(8)
    shapes = model.paged_cache_shapes(4, 16, 8, 32)
    specs = paged_pool_pspec_tree(shapes, rules, mesh, spec.paged.seq_axes)
    plain = cache_pspec_tree(model.cache_shapes(4, 32), rules, mesh)

    def check(ns, leaf, s_ax, plain_ns):
        pspec = tuple(ns.spec) + (None,) * (len(leaf.shape) - len(ns.spec))
        if s_ax < 0:       # direct leaf: contiguous placement preserved
            want = tuple(plain_ns.spec)
            want += (None,) * (len(leaf.shape) - len(want))
            assert pspec == want, (leaf.shape, pspec, want)
        else:              # pool leaf (scan, P, block, KV, dh)
            assert pspec[s_ax - 1] == rules["kv_cache_batch"], \
                (leaf.shape, pspec)
            assert pspec[s_ax] is None          # block interior never cut

    jax.tree.map(check, specs, shapes, spec.paged.seq_axes, plain)

    K = 2
    stacked = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape[:1] + (K,) + s.shape[1:],
                                       s.dtype), shapes)
    sspecs = stacked_cache_pspec_tree(stacked, rules, mesh,
                                      spec.paged.seq_axes)
    jax.tree.map(
        lambda ns, leaf: np.testing.assert_equal(
            (tuple(ns.spec) + (None,) * len(leaf.shape))[1], "pod"),
        sspecs, stacked)
