"""Chunked-prefill continuous batching correctness.

* The prefix-aware chunked-prefill Pallas kernel must match the jnp paged
  oracle (gather-then-contiguous, one query row per chunk position).
* A chunked ``SlotServer`` must produce greedy outputs identical to the
  monolithic-prefill path for EVERY model family — including chunk
  boundaries that straddle page blocks and final chunks shorter than the
  chunk size.
* The token-budget step loop must never starve decode: every decoding slot
  makes progress on every step while a long prompt prefills, and a budget
  too small to co-schedule defers the chunk (not the decode).
* Exhausting ``serve(max_steps=…)`` with a request still mid-prefill
  reports it as dropped WITH its partial position (the regression this PR
  fixes: such a request was neither queued nor decoding).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.router import CentroidRouter, RouterConfig
from repro.kernels import ref
from repro.kernels.decode_attention import chunk_prefill_attention
from repro.models import build_model
from repro.serve.scheduler import (MixtureSlotServer, Request, SlotServer)

from test_scheduler import make_requests

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# Chunked-prefill kernel vs jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("C,NB,block,H,KV,dh,start", [
    (8, 4, 16, 4, 4, 64, 0),      # MHA, chunk 0
    (8, 4, 16, 4, 4, 64, 24),     # MHA, mid-prompt chunk
    (6, 8, 8, 8, 2, 64, 34),      # GQA 4:1, chunk straddles a block
    (16, 4, 32, 4, 1, 128, 112),  # MQA, final chunk ends at capacity
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chunk_prefill_kernel(C, NB, block, H, KV, dh, start, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    P = NB + 3                            # pool bigger than needed
    q = rand(ks[0], (C, H, dh), dtype)
    kp = rand(ks[1], (P, block, KV, dh), dtype)
    vp = rand(ks[2], (P, block, KV, dh), dtype)
    rng = np.random.default_rng(0)
    bt = jnp.asarray(rng.permutation(np.arange(1, P))[:NB], jnp.int32)
    out = chunk_prefill_attention(q, kp, vp, jnp.int32(start), bt,
                                  interpret=True)
    want = ref.chunk_prefill_attention_ref(q, kp, vp, jnp.int32(start), bt)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("block,NB,start", [(8, 6, 0), (8, 6, 19),
                                            (16, 4, 33), (32, 3, 5)])
@pytest.mark.parametrize("bps", [2, 3, 4])
def test_chunk_prefill_kernel_blocks_per_step(block, NB, start, bps):
    """Multi-block grid steps must be bit-identical to bps=1 — the horizon
    here is the last query position's block (start + C - 1), and the
    padded tail when bps does not divide NB is killed by ``ki < nb``."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    C, H, KV, dh = 5, 8, 4, 32
    P = NB + 2
    q = rand(ks[0], (C, H, dh), jnp.float32)
    kp = rand(ks[1], (P, block, KV, dh), jnp.float32)
    vp = rand(ks[2], (P, block, KV, dh), jnp.float32)
    rng = np.random.default_rng(2)
    bt = jnp.asarray(rng.permutation(np.arange(1, P))[:NB], jnp.int32)
    base = chunk_prefill_attention(q, kp, vp, jnp.int32(start), bt,
                                   interpret=True)
    want = ref.chunk_prefill_attention_ref(q, kp, vp, jnp.int32(start), bt)
    out = chunk_prefill_attention(q, kp, vp, jnp.int32(start), bt,
                                  blocks_per_step=bps, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(base))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_chunk_prefill_ref_row0_is_decode_ref():
    """A one-row chunk IS a single decode query: the chunk oracle must
    degenerate to the paged decode oracle."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    NB, block, H, KV, dh = 4, 8, 4, 2, 32
    P = NB + 1
    q = rand(ks[0], (1, H, dh), jnp.float32)
    kp = rand(ks[1], (P, block, KV, dh), jnp.float32)
    vp = rand(ks[2], (P, block, KV, dh), jnp.float32)
    bt = jnp.arange(1, NB + 1, dtype=jnp.int32)
    start = jnp.int32(13)
    got = ref.chunk_prefill_attention_ref(q, kp, vp, start, bt)
    want = ref.paged_decode_attention_ref(q, kp, vp, start[None], bt[None])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Chunked == monolithic greedy, per family
# ---------------------------------------------------------------------------

# chunk straddles page_block=8 for attention families; ssm/hybrid need the
# chunk aligned to the chunkwise-scan length (16 on the smoke configs)
CHUNKED_FAMILY_ARCHS = [
    ("qwen3_8b", "dense", 6),
    ("deepseek_moe_16b", "moe", 6),
    ("internvl2_2b", "vlm", 8),
    ("whisper_small", "audio", 6),
    ("zamba2_2_7b", "hybrid", 16),
    ("xlstm_125m", "ssm", 16),    # no pageable leaves: carry-only chunks
]


@pytest.mark.parametrize("arch,family,chunk", CHUNKED_FAMILY_ARCHS)
def test_chunked_slot_server_matches_monolithic(arch, family, chunk):
    """Prompt lengths straddle chunk boundaries both ways (shorter than one
    chunk, non-multiples) and the queue overcommits the slots."""
    cfg = get_smoke_config(arch).reduced(vocab=256)
    assert cfg.family == family
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache_len = 48
    lens, budgets = (7, 11, 5), (4, 3, 5)

    ref_srv = SlotServer(model, params, n_slots=2, cache_len=cache_len,
                         page_block=8)
    want = ref_srv.serve(make_requests(cfg, lens, budgets))

    srv = SlotServer(model, params, n_slots=2, cache_len=cache_len,
                     page_block=8, chunk=chunk)
    chunked_q = make_requests(cfg, lens, budgets)
    got = srv.serve(chunked_q)
    assert set(got) == set(want)
    for rid in want:
        assert got[rid] == want[rid], (arch, rid, got[rid], want[rid])
    assert srv.active == []
    if srv.paged:     # every block returned at retirement
        assert srv.allocator.n_free == srv.allocator.n_blocks - 1
    # TTFT / completion stamps populated by the scheduler
    assert all(0 < r.t_first <= r.t_done for r in chunked_q)


def test_chunk_boundaries_straddle_page_blocks():
    """chunk=6 over page_block=4: every chunk write crosses a physical
    block boundary, and the final chunk is a partial one."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    for n in (3, 6, 10, 13):              # <1 chunk, exact, straddling
        q = [Request(0, np.random.default_rng(n).integers(
            0, cfg.vocab, size=n).astype(np.int32), 5)]
        want = SlotServer(model, params, n_slots=1, cache_len=32,
                          page_block=4).serve(list(q))
        got = SlotServer(model, params, n_slots=1, cache_len=32,
                         page_block=4, chunk=6).serve(
            [Request(0, q[0].tokens, 5)])
        assert got == want, (n, got, want)


def test_chunked_use_kernel_parity():
    """The prefix-aware chunk kernel (interpret mode on CPU) must be
    reachable from continuous batching and agree with both jnp paths."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def queue():
        return make_requests(cfg, (8, 8), (3, 3), seed=7)

    want = SlotServer(model, params, n_slots=2, cache_len=16,
                      page_block=8).serve(queue())
    jnp_c = SlotServer(model, params, n_slots=2, cache_len=16, page_block=8,
                       chunk=4).serve(queue())
    ker_c = SlotServer(model, params, n_slots=2, cache_len=16, page_block=8,
                       chunk=4, use_kernel=True).serve(queue())
    assert want == jnp_c == ker_c


def test_chunked_edge_budgets_and_context_fill():
    """max_new == 1 retires straight out of the prefill transition, and a
    prompt that fills the context keeps its single token and retires
    truncated without decoding — matching monolithic semantics."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.random.default_rng(4).integers(0, cfg.vocab, size=16) \
        .astype(np.int32)

    one = Request(0, prompt, max_new=1)
    out = SlotServer(model, params, n_slots=1, cache_len=32, page_block=8,
                     chunk=6).serve([one])
    want = SlotServer(model, params, n_slots=1, cache_len=32,
                      page_block=8).serve([Request(0, prompt, max_new=1)])
    # the budget is exactly the prefill token (the monolithic path used to
    # decode one token PAST the budget here)
    assert out == want and len(out[0]) == 1 and not one.truncated

    fill = Request(1, prompt, max_new=4)
    srv = SlotServer(model, params, n_slots=1, cache_len=16, page_block=8,
                     chunk=6)
    out2 = srv.serve([fill])
    wref = SlotServer(model, params, n_slots=1, cache_len=16,
                      page_block=8).serve([Request(1, prompt, max_new=4)])
    assert out2 == wref
    assert len(out2[1]) == 1 and fill.truncated
    assert srv.active == []
    assert srv.allocator.n_free == srv.allocator.n_blocks - 1


# ---------------------------------------------------------------------------
# Token budget: decode never starves while a long prompt prefills
# ---------------------------------------------------------------------------

def test_token_budget_starvation_freedom():
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    srv = SlotServer(model, params, n_slots=3, cache_len=64, page_block=8,
                     chunk=8)
    # two short requests reach decode first
    for rid in (0, 1):
        assert srv.admit(Request(
            rid, rng.integers(0, cfg.vocab, size=4).astype(np.int32), 40))
    while srv.prefill_order:
        srv.step()
    assert len(srv.decoding) == 2
    # a long prompt starts chunked prefill alongside them
    assert srv.admit(Request(
        2, rng.integers(0, cfg.vocab, size=48).astype(np.int32), 4))
    long_slot = srv.prefill_order[0]
    steps_to_finish_prefill = 0
    while srv.prefilling[long_slot]:
        dec = list(srv.decoding)
        pos_before = srv.pos[dec].copy()
        pf_before = int(srv.prefill_pos[long_slot])
        srv.step()
        # every decoding slot advanced this step (no stop-the-world)
        assert (srv.pos[dec] == pos_before + 1).all()
        assert int(srv.prefill_pos[long_slot]) == pf_before + srv.chunk \
            or not srv.prefilling[long_slot]
        steps_to_finish_prefill += 1
    assert steps_to_finish_prefill == 6          # ceil(48 / 8)


def test_small_token_budget_defers_chunk_not_decode():
    """budget < decoding + chunk ⇒ the chunk waits, decode still runs;
    the queue still completes with the right outputs."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(12)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (4, 4, 20)]

    def queue():
        return [Request(i, p, m) for i, (p, m)
                in enumerate(zip(prompts, (6, 6, 3)))]

    want = SlotServer(model, params, n_slots=3, cache_len=40,
                      page_block=8).serve(queue())
    srv = SlotServer(model, params, n_slots=3, cache_len=40, page_block=8,
                     chunk=8, token_budget=9)
    # with 2 slots decoding, 2 + 8 > 9: the long prompt's chunks only run
    # once a decoder retires — but decode is never paused
    got = srv.serve(queue())
    assert got == want


# ---------------------------------------------------------------------------
# max_steps exhaustion: mid-prefill requests are dropped WITH position
# ---------------------------------------------------------------------------

def test_midprefill_request_reported_dropped_with_partial_position():
    """Regression: a request still chunk-prefilling at max_steps exhaustion
    was neither 'queued' nor decoding — it must be counted as dropped and
    report its partial prefill position."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.random.default_rng(13).integers(0, cfg.vocab, size=40) \
        .astype(np.int32)
    srv = SlotServer(model, params, n_slots=1, cache_len=64, page_block=8,
                     chunk=8)
    with pytest.raises(RuntimeError, match=r"prefill 16/40"):
        srv.serve([Request(7, prompt, max_new=4)], max_steps=2)


# ---------------------------------------------------------------------------
# Config fences
# ---------------------------------------------------------------------------

def test_chunked_requires_paged_for_attention_families():
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="paged pool"):
        SlotServer(model, params, n_slots=1, cache_len=16, chunk=4)


def test_chunked_rejects_misaligned_recurrent_chunk():
    cfg = get_smoke_config("zamba2_2_7b").reduced(vocab=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="chunkwise-scan"):
        SlotServer(model, params, n_slots=1, cache_len=32, page_block=8,
                   chunk=6)


def test_chunked_rejects_sliding_window():
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=64, sliding_window=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="sliding-window"):
        SlotServer(model, params, n_slots=1, cache_len=32, page_block=4,
                   chunk=4)


# ---------------------------------------------------------------------------
# Sharding: chunk-carry placement
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen3_8b", "whisper_small",
                                  "zamba2_2_7b"])
def test_chunk_carry_pspec_layout(arch):
    """A chunked-prefill carry is batch-extent-1 state: everything is
    replicated except full per-layer cross-attention KV rows, whose kv-head
    axis follows the model axis when divisible."""
    from jax.sharding import Mesh
    from repro.sharding.rules import chunk_carry_pspec_tree, logical_rules

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("pod", "data", "model"))
    rules = logical_rules(multi_pod=True, decentralized=True)
    cfg = get_smoke_config(arch).reduced(vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b = make_requests(cfg, (6,), (2,))[0].batch()
    carry = model.init_chunk_carry(params, b, 32)
    specs = chunk_carry_pspec_tree(
        jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                     carry), rules, mesh)

    def check(ns, leaf):
        pspec = tuple(ns.spec) + (None,) * (len(leaf.shape) - len(ns.spec))
        if len(leaf.shape) == 5 and leaf.shape[-2] > 1 and \
                leaf.shape[-2] % mesh.shape["model"] == 0:
            assert pspec[-2] == rules["kv_cache_heads"], (leaf.shape, pspec)
            pspec = pspec[:-2] + (None,) + pspec[-1:]
        assert all(p is None for p in pspec), (leaf.shape, pspec)

    jax.tree.map(check, specs, carry)


# ---------------------------------------------------------------------------
# Stacked mixture core: chunked == monolithic (shared block table over K)
# ---------------------------------------------------------------------------

def test_chunked_mixture_matches_monolithic():
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=128)
    model = build_model(cfg)
    K, Df, B = 3, 16, 4
    experts = [model.init(jax.random.PRNGKey(k)) for k in range(K)]
    rng = np.random.default_rng(1)
    router = CentroidRouter(
        jnp.asarray(rng.normal(size=(K, Df)), jnp.float32),
        RouterConfig(top_k=2))
    toks = rng.integers(0, cfg.vocab, size=(B, 10)).astype(np.int32)
    feats = rng.normal(size=(B, Df)).astype(np.float32)

    def queue():
        return [Request(i, toks[i], 5, features=feats[i]) for i in range(B)]

    want = MixtureSlotServer(model, experts, router, n_slots=2,
                             cache_len=24, page_block=8).serve(queue())
    got = MixtureSlotServer(model, experts, router, n_slots=2, cache_len=24,
                            page_block=8, chunk=4).serve(queue())
    assert got == want
