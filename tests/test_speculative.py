"""Speculative decoding over the paged pool: draft + multi-token verify.

The hard invariant under test: speculation is a LATENCY lever only —
seeded sampled and greedy requests produce token-for-token identical
outputs (and identical finish reasons) with speculation on and off,
across every capable cache family, both draft sources, and every
scheduler interaction (chunked co-scheduling, pool pressure, stop tokens
landing at every offset of a span, the sanitizer's span-write plan).
Families that cannot roll a span back (ssm/hybrid — recurrent state has
no positional rollback) must degrade silently to vanilla decode.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.router import CentroidRouter, RouterConfig
from repro.kernels import ref
from repro.kernels.decode_attention import paged_verify_attention
from repro.models import build_model
from repro.serve.api import EngineConfig, SamplingParams
from repro.serve.fused import verify_epilogue
from repro.serve.scheduler import (DecentralizedSlotServer,
                                   MixtureSlotServer, Request, SlotServer)
from repro.serve.speculate import NGramProposer

FAMILY_ARCHS = [
    ("qwen3_8b", "dense"),
    ("deepseek_moe_16b", "moe"),
    ("internvl2_2b", "vlm"),
    ("whisper_small", "audio"),
    ("xlstm_125m", "ssm"),
    ("zamba2_2_7b", "hybrid"),
]

PROMPT_LENS = (7, 11, 5, 9)
SPEC_LEN = 4


def _extras(cfg, rng):
    extras = {}
    if cfg.family == "vlm":
        extras["patches"] = rng.normal(
            size=(cfg.n_patches, cfg.vision_dim)).astype(np.float32)
    if cfg.family == "audio":
        extras["frames"] = rng.normal(
            size=(cfg.n_audio_frames, cfg.audio_dim)).astype(np.float32)
    return extras


def _prompts(cfg, seed=42):
    """Period-4 repetitive prompts (the workload n-gram lookup targets)
    plus the per-family modality extras, rebuilt identically per call."""
    rng = np.random.default_rng(seed)
    ps = []
    for n in PROMPT_LENS:
        base = rng.integers(1, cfg.vocab, size=4)
        ps.append(np.tile(base, n // 4 + 2)[:n].astype(np.int32))
    ex = [_extras(cfg, rng) for _ in PROMPT_LENS]
    return ps, ex


def _queue(cfg, feats=None, stop_id=None, max_new=12):
    """Greedy + seeded-sampled requests in one queue (and, with a probed
    ``stop_id``, a mid-stream stop) — the parity comparison surface."""
    ps, ex = _prompts(cfg)
    f = (lambda i: feats[i]) if feats is not None else (lambda i: None)
    q = [Request(0, ps[0], max_new, extras=ex[0], features=f(0)),
         Request(1, ps[1], max_new, extras=ex[1], features=f(1),
                 params=SamplingParams(max_new=max_new, temperature=0.8,
                                       top_k=8, seed=123)),
         Request(2, ps[2], max_new, extras=ex[2], features=f(2),
                 params=SamplingParams(max_new=max_new, temperature=0.6,
                                       top_k=4, seed=7))]
    if stop_id is not None:
        q.append(Request(3, ps[3], max_new, extras=ex[3], features=f(3),
                         params=SamplingParams(
                             max_new=max_new, stop_token_ids=(stop_id,))))
    return q


def _dense_setup(vocab=256):
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=vocab)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _cfg(speculative=None, spec_len=SPEC_LEN, **kw):
    base = dict(n_slots=4, cache_len=64, paged=True, page_block=8,
                fused_step=True)
    base.update(kw)
    return EngineConfig(speculative=speculative, spec_len=spec_len, **base)


def _parity(cfg, model, mk_vanilla, mk_spec, feats=None, stop_id=None):
    """Drive identical queues through both servers; assert identical
    tokens AND identical finish reasons for every request."""
    qv = _queue(cfg, feats, stop_id)
    srv_v = mk_vanilla()
    got_v = srv_v.serve(qv)
    qs = _queue(cfg, feats, stop_id)
    srv_s = mk_spec()
    got_s = srv_s.serve(qs)
    assert got_v == got_s, (got_v, got_s)
    for rv, rs in zip(qv, qs):
        assert rv.finish_reason == rs.finish_reason, \
            (rv.rid, rv.finish_reason, rs.finish_reason)
    return srv_v, srv_s


# ---------------------------------------------------------------------
# Parity across the cache families (greedy AND seeded-sampled per queue)
# ---------------------------------------------------------------------

@pytest.mark.parametrize("arch,family", FAMILY_ARCHS)
def test_spec_family_parity(arch, family):
    cfg = get_smoke_config(arch).reduced(vocab=256)
    assert cfg.family == family
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache_len = 96 if family == "vlm" else 64   # room for the image prefix

    def mk(spec):
        return SlotServer(model, params, config=_cfg(
            speculative="ngram" if spec else None, cache_len=cache_len))

    _, srv_s = _parity(cfg, model, lambda: mk(False), lambda: mk(True))
    if model.speculative_capable:
        assert srv_s._can_spec and srv_s.stats()["spec_steps"] > 0
    else:
        # recurrent / sliding-window state can't roll a span back: the
        # server must degrade to vanilla decode, silently
        assert not srv_s._can_spec
        assert srv_s.stats().get("spec_steps") == 0


def test_spec_len_one_is_vanilla():
    """spec_len == 1 IS vanilla decode: no drafts, no verify dispatch."""
    cfg, model, params = _dense_setup()

    def mk(spec_len):
        return SlotServer(model, params,
                          config=_cfg("ngram", spec_len=spec_len))

    srv_v = SlotServer(model, params, config=_cfg(None))
    got_v = srv_v.serve(_queue(cfg))
    srv_1 = mk(1)
    assert not srv_1._can_spec
    assert srv_1.serve(_queue(cfg)) == got_v
    assert srv_1.stats()["spec_steps"] == 0


# ---------------------------------------------------------------------
# Accept rule: forward progress and the deterministic token match
# ---------------------------------------------------------------------

def test_all_reject_span_still_progresses():
    """Drafts that never match still emit >= 1 token per speculative
    step (the verify's position-0 score IS the vanilla next token), and
    the trajectory is untouched."""
    cfg, model, params = _dense_setup()
    srv_v = SlotServer(model, params, config=_cfg(None))
    got_v = srv_v.serve(_queue(cfg))

    srv = SlotServer(model, params, config=_cfg("ngram"))
    # worst-case proposer: every draft is a token the model can never
    # pick (ids are sampled from [0, vocab))
    srv._draft_tokens = lambda dec: jnp.full(
        (srv.n_slots, SPEC_LEN - 1), cfg.vocab - 1, jnp.int32)
    assert srv.serve(_queue(cfg)) == got_v
    st = srv.stats()
    assert st["spec_steps"] > 0
    assert st["spec_tokens"] >= st["spec_steps"]   # >= 1 token per step


def test_verify_epilogue_all_reject_and_full_accept():
    """Unit-level accept rule: a fully-matching draft row advances by the
    whole span; a fully-mismatching one advances by exactly 1 — and both
    emit the greedy-argmax (vanilla) tokens."""
    B, L, V = 2, 3, 16
    rng = np.random.default_rng(0)
    scores = jnp.asarray(rng.normal(size=(B, L, V)).astype(np.float32))
    true = np.asarray(jnp.argmax(scores, axis=-1))          # greedy rows
    drafts = np.stack([true[0, :L - 1],                     # full accept
                       (true[1, :L - 1] + 1) % V])          # full reject
    state = {"tok": jnp.zeros(B, jnp.int32),
             "pos": jnp.asarray([5, 5], jnp.int32),
             "active": jnp.ones(B, bool),
             "temps": jnp.zeros(B, jnp.float32),
             "top_ks": jnp.zeros(B, jnp.int32),
             "seeds": jnp.zeros(B, jnp.uint32),
             "counts": jnp.zeros(B, jnp.int32),
             "max_new": jnp.full(B, 100, jnp.int32),
             "stop_ids": jnp.full((B, 1), -1, jnp.int32)}
    new, toks, n_emit, done = verify_epilogue(
        scores, jnp.asarray(drafts), state, cache_len=1000)
    assert n_emit.tolist() == [L, 1]
    assert done.tolist() == [0, 0]
    assert np.array_equal(np.asarray(toks)[0], true[0])
    assert int(np.asarray(toks)[1, 0]) == int(true[1, 0])
    assert new["pos"].tolist() == [5 + L, 6]
    assert new["counts"].tolist() == [L, 1]


# ---------------------------------------------------------------------
# Stop tokens at every span offset: retire once, emit nothing past it
# ---------------------------------------------------------------------

@pytest.mark.parametrize("offset", range(SPEC_LEN))
def test_spec_stop_at_every_span_offset(offset):
    """A stop token accepted at span offset 0..L-1 must truncate the span
    on device: no tokens recorded past it, finish_reason == 'stop', and
    ``stats()['stopped']`` counts the request ONCE (the regression was a
    speculatively-finished request retiring twice)."""
    cfg, model, params = _dense_setup()
    ps, ex = _prompts(cfg)
    solo = SlotServer(model, params, config=_cfg(None))
    traj = solo.serve([Request(0, ps[0], 16)])[0]
    # token 0 comes from the prefill pick; the first decode span covers
    # traj[1..L], so traj[1 + offset] is span offset ``offset``
    stop_id = traj[1 + offset]
    first_hit = traj.index(stop_id)
    want = traj[:first_hit + 1]

    srv = SlotServer(model, params, config=_cfg("ngram"))
    # oracle drafts (the known greedy trajectory) force full-accept
    # spans, so the stop genuinely lands mid-span at the probed offset
    def oracle(dec):
        drafts = np.zeros((srv.n_slots, SPEC_LEN - 1), np.int32)
        for s in dec:
            done_n = len(srv.slot_req[s].out)
            fut = traj[done_n:done_n + SPEC_LEN - 1]
            drafts[s, :len(fut)] = fut
        return jnp.asarray(drafts)
    srv._draft_tokens = oracle
    q = [Request(0, ps[0], 16,
                 params=SamplingParams(max_new=16,
                                       stop_token_ids=(stop_id,)))]
    got = srv.serve(q)
    assert got[0] == want, (offset, got[0], want)
    assert q[0].finish_reason == "stop"
    st = srv.stats()
    assert st["stopped"] == 1          # retired exactly once
    if first_hit >= 1:     # hit at token 0 retires at admission instead
        assert st["spec_steps"] > 0


# ---------------------------------------------------------------------
# Scheduler interactions: chunked co-scheduling, pool pressure, sanitize
# ---------------------------------------------------------------------

def test_spec_parity_under_chunked_prefill():
    """Chunk co-scheduled steps fall back to vanilla decode that step;
    the trajectory must be unchanged and speculation must still engage on
    the pure-decode steps."""
    cfg, model, params = _dense_setup()

    def mk(spec):
        return SlotServer(model, params, config=_cfg(
            "ngram" if spec else None, chunked_prefill=True, chunk=8))

    _, srv_s = _parity(cfg, model, lambda: mk(False), lambda: mk(True))
    assert srv_s.stats()["spec_steps"] > 0


def test_spec_pool_pressure_falls_back_to_vanilla():
    """A pool too tight to reserve any span up front must degrade to
    vanilla steps (never deadlock, never raise) and keep parity; blocks
    freed by retirements let later spans speculate."""
    cfg, model, params = _dense_setup()
    # nb_slot = ceil(64/8) = 8; 4 slots want 32 blocks at full depth —
    # 18 usable blocks forces span-reservation failures mid-flight
    def mk(spec):
        return SlotServer(model, params, config=_cfg(
            "ngram" if spec else None, pool_blocks=19))

    _parity(cfg, model, lambda: mk(False), lambda: mk(True))


def test_spec_pool_conservation_with_sanitizer():
    """The PoolSanitizer's span-aware write plan passes every step, and
    the pool conserves: all blocks return to the free list at drain."""
    cfg, model, params = _dense_setup()
    srv = SlotServer(model, params, config=_cfg("ngram", sanitize=True))
    srv.serve(_queue(cfg))
    st = srv.stats()
    assert st["spec_steps"] > 0
    assert st["sanitize_violations"] == 0
    assert st["sanitize_checked_steps"] > 0
    assert st["pool_free_blocks"] == st["pool_blocks"] - 1  # scratch stays


# ---------------------------------------------------------------------
# Mixture core: expert-0 drafting and the decentralized deployment
# ---------------------------------------------------------------------

def _mixture_setup():
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=128)
    model = build_model(cfg)
    K, Df = 3, 16
    experts = [model.init(jax.random.PRNGKey(k)) for k in range(K)]
    rng = np.random.default_rng(1)
    router = CentroidRouter(
        jnp.asarray(rng.normal(size=(K, Df)), jnp.float32),
        RouterConfig(top_k=2))
    feats = rng.normal(size=(len(PROMPT_LENS), Df)).astype(np.float32)
    return cfg, model, experts, router, feats


@pytest.mark.parametrize("mode", ["ngram", "expert"])
def test_spec_mixture_parity(mode):
    cfg, model, experts, router, feats = _mixture_setup()

    def mk(spec):
        return MixtureSlotServer(model, experts, router, config=_cfg(
            mode if spec else None, cache_len=48, strategy="mixture"))

    _, srv_s = _parity(cfg, model, lambda: mk(False), lambda: mk(True),
                       feats=feats)
    assert srv_s.stats()["spec_steps"] > 0


def test_spec_decentralized_top1_parity():
    cfg, model, experts, router, feats = _mixture_setup()

    def mk(spec):
        return DecentralizedSlotServer(model, experts, router, config=_cfg(
            "ngram" if spec else None, cache_len=48, strategy="top1"))

    qv = _queue(cfg, feats)
    got_v = mk(False).serve(qv)
    srv_s = mk(True)
    assert srv_s.serve(_queue(cfg, feats)) == got_v
    assert sum(p["spec_steps"] for p in srv_s.occupancy()
               if "spec_steps" in p) > 0


# ---------------------------------------------------------------------
# The Pallas verify kernel
# ---------------------------------------------------------------------

@pytest.mark.parametrize("B,NB,block,H,KV,dh,L", [
    (2, 4, 16, 4, 4, 64, 3),     # MHA
    (3, 8, 16, 8, 2, 64, 4),     # GQA 4:1
])
@pytest.mark.parametrize("bps", [1, 2])
def test_paged_verify_kernel_matches_decode_ref(B, NB, block, H, KV, dh,
                                                L, bps):
    """Verify row j IS decode attention at position pos + j (the per-row
    causal fence), so the existing paged-decode oracle checks every row
    of the one-launch span kernel."""
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    P = B * NB + 3
    dt = jnp.float32
    q = jax.random.normal(ks[0], (B, L, H, dh), dt)
    kp = jax.random.normal(ks[1], (P, block, KV, dh), dt)
    vp = jax.random.normal(ks[2], (P, block, KV, dh), dt)
    rng = np.random.default_rng(0)
    bt = jnp.asarray(rng.permutation(np.arange(1, P))[:B * NB]
                     .reshape(B, NB), jnp.int32)
    # span must fit the logical horizon: pos + L - 1 < NB * block
    pos = jax.random.randint(ks[3], (B,), 0, NB * block - L + 1)
    out = paged_verify_attention(q, kp, vp, pos, bt, blocks_per_step=bps,
                                 interpret=True)
    assert out.shape == (B, L, H, dh)
    for j in range(L):
        want = ref.paged_decode_attention_ref(q[:, j], kp, vp, pos + j, bt)
        np.testing.assert_allclose(np.asarray(out[:, j], np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=2e-5, atol=2e-5)


def test_spec_use_kernel_parity():
    """The whole speculative stack through the Pallas kernels matches the
    jnp path token-for-token."""
    cfg, model, params = _dense_setup()

    def mk(uk):
        return SlotServer(model, params,
                          config=_cfg("ngram", use_kernel=uk))

    got_jnp = mk(False).serve(_queue(cfg, max_new=8))
    srv_k = mk(True)
    assert srv_k.serve(_queue(cfg, max_new=8)) == got_jnp
    assert srv_k.stats()["spec_steps"] > 0


# ---------------------------------------------------------------------
# Config validation and the proposer
# ---------------------------------------------------------------------

def test_spec_config_validation():
    with pytest.raises(ValueError, match="ngram"):
        EngineConfig(paged=True, speculative="bogus").validate()
    with pytest.raises(ValueError, match="paged"):
        EngineConfig(paged=False, speculative="ngram").validate()
    with pytest.raises(ValueError, match="fused"):
        EngineConfig(paged=True, fused_step=False,
                     speculative="ngram").validate()
    with pytest.raises(ValueError, match="mixture"):
        EngineConfig(paged=True, strategy="top1",
                     speculative="expert").validate()
    with pytest.raises(ValueError, match="spec_len"):
        EngineConfig(paged=True, speculative="ngram",
                     spec_len=0).validate()
    # legal combinations
    EngineConfig(paged=True, speculative="ngram").validate()
    EngineConfig(paged=True, strategy="mixture",
                 speculative="expert").validate()


def test_ngram_proposer():
    p = NGramProposer(spec_len=4, n=2)
    # the continuation of the most recent earlier (7, 8) occurrence
    hist = [1, 2, 3, 7, 8, 9, 4, 5, 7, 8]
    assert p.propose(hist).tolist() == [9, 4, 5]
    # no earlier occurrence: pad with the last token
    assert p.propose([1, 2, 3, 4]).tolist() == [4, 4, 4]
    # short history pads too
    assert p.propose([6]).tolist() == [6, 6, 6]
    assert p.propose([]).tolist() == [0, 0, 0]
    # continuation shorter than the span right-pads with its last token
    assert p.propose([5, 1, 2, 5, 1, 2]).tolist()[:2] == [5, 1]
    batch = p.propose_batch([hist, [1, 2, 3, 4]])
    assert batch.shape == (2, 3) and batch.dtype == np.int32
    with pytest.raises(ValueError):
        NGramProposer(spec_len=1)
