"""Exact verification of the discrete-time DFM framework (paper §3, §4.1).

Everything is enumerated on [d]^N with small d, N so the Continuity Equation
and the sampling rule can be checked to machine precision.
"""
import numpy as np
import pytest
from _hyp import given, settings, st

import jax.numpy as jnp

from repro.core import dfm
from repro.core.autoregressive import (ar_conditional_velocity,
                                       ar_marginal_velocity, ar_path,
                                       mask_state)
from repro.core.dfm import (apply_sampling_rule, chain_marginals,
                            continuity_residual, encode,
                            enumerate_states, is_one_sparse, n_states,
                            neighbor_table, velocity_is_valid)


def random_q(d, N, rng, sparse=False):
    S = n_states(d, N)
    q = rng.random(S)
    if sparse:
        q[rng.random(S) < 0.5] = 0.0
        if q.sum() == 0:
            q[rng.integers(S)] = 1.0
    return jnp.asarray(q / q.sum())


# ---------------------------------------------------------------------------
# State-space utilities
# ---------------------------------------------------------------------------

def test_encode_decode_roundtrip():
    d, N = 4, 3
    states = enumerate_states(d, N)
    idx = encode(states, d)
    assert np.array_equal(idx, np.arange(d**N))
    assert np.array_equal(dfm.decode(idx, d, N), states)


def test_neighbor_table_hamming():
    d, N = 3, 3
    nbr = neighbor_table(d, N)
    states = enumerate_states(d, N)
    # nbr[z, i, a] must equal z with position i set to a
    for z in range(0, d**N, 5):
        for i in range(N):
            for a in range(d):
                expected = states[z].copy()
                expected[i] = a
                assert np.array_equal(states[nbr[z, i, a]], expected)


# ---------------------------------------------------------------------------
# AR path: Continuity Equation + generation (the §4.2 proofs, numerically)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d,N,P", [(3, 3, 0), (3, 3, 1), (2, 4, 2), (4, 2, 0)])
def test_ar_continuity_equation_and_generation(d, N, P):
    """The marginal velocity of the AR path satisfies Eq. 17 at every step,
    and the sampling rule (Eq. 13) pushes p_t to exactly p_{t+1}."""
    rng = np.random.default_rng(0)
    mask_id = d - 1
    q = random_q(d, N, rng)
    # mask token must not appear in targets (it is the source alphabet)
    states = enumerate_states(d, N)
    q = jnp.where(jnp.asarray((states == mask_id).any(1)), 0.0, q)
    q = q / q.sum()

    path = ar_path(q, P, d, N, mask_id)
    nbr = neighbor_table(d, N)
    T = N - P
    for t in range(T):
        p_t, p_next = path.marginal(t), path.marginal(t + 1)
        u = ar_marginal_velocity(q, P, t, d, N, mask_id)
        assert velocity_is_valid(u, p_t)
        assert is_one_sparse(u, p_t)
        res = continuity_residual(p_t, p_next, u, nbr)
        np.testing.assert_allclose(np.asarray(res), 0.0, atol=1e-12)
        pushed = apply_sampling_rule(p_t, u, nbr)
        np.testing.assert_allclose(np.asarray(pushed), np.asarray(p_next),
                                   atol=1e-12)


def test_ar_chain_reaches_target():
    """Rolling the sampling rule from the fully-masked source reproduces the
    target distribution q exactly — 'decentralized ≡ centralized' requires
    this baseline semantics first."""
    d, N, P = 3, 3, 0
    mask_id = d - 1
    rng = np.random.default_rng(1)
    q = random_q(d, N, rng, sparse=True)
    states = enumerate_states(d, N)
    q = jnp.where(jnp.asarray((states == mask_id).any(1)), 0.0, q)
    q = q / q.sum()
    path = ar_path(q, P, d, N, mask_id)
    nbr = neighbor_table(d, N)
    us = [ar_marginal_velocity(q, P, t, d, N, mask_id) for t in range(N - P)]
    ps = chain_marginals(path.marginal(0), us, nbr)
    np.testing.assert_allclose(np.asarray(ps[-1]), np.asarray(q), atol=1e-12)


def test_conditional_velocity_matches_theorem1():
    """Marginalizing the conditional velocities (Eq. 22) through Theorem 1
    (Eq. 9) gives the same velocity as the closed form."""
    d, N, P = 3, 3, 1
    mask_id = d - 1
    rng = np.random.default_rng(2)
    q = random_q(d, N, rng)
    states = enumerate_states(d, N)
    q = jnp.where(jnp.asarray((states == mask_id).any(1)), 0.0, q)
    q = q / q.sum()
    path = ar_path(q, P, d, N, mask_id)
    for t in range(N - P):
        cond_u = ar_conditional_velocity(t, P, d, N, mask_id)
        u_thm = dfm.marginal_velocity(path, t, cond_u)
        u_closed = ar_marginal_velocity(q, P, t, d, N, mask_id)
        # compare on reachable states only
        xt_idx = encode(mask_state(states, P + t, mask_id), d)
        reach = np.unique(xt_idx[np.asarray(q) > 0])
        np.testing.assert_allclose(np.asarray(u_thm)[:, :, reach],
                                   np.asarray(u_closed)[:, :, reach],
                                   atol=1e-12)


# ---------------------------------------------------------------------------
# Necessity of 1-sparsity (paper §4.2's core structural claim)
# ---------------------------------------------------------------------------

def test_non_one_sparse_velocity_breaks_generation():
    """A velocity that moves TWO positions at once can satisfy the Continuity
    Equation yet fail to generate the path — the paper's motivation for the
    1-sparse constraint. We construct one explicitly."""
    d, N = 2, 2
    nbr = neighbor_table(d, N)
    S = n_states(d, N)
    # p_t = delta_{(0,0)}; p_{t+1} = 0.5 delta_{(1,0)} + 0.5 delta_{(0,1)}
    p_t = jnp.zeros(S).at[encode(np.array([0, 0]), d)].set(1.0)
    p_next = (jnp.zeros(S)
              .at[encode(np.array([1, 0]), d)].set(0.5)
              .at[encode(np.array([0, 1]), d)].set(0.5))
    # velocity moving BOTH positions by 0.5 from (0,0)
    u = np.zeros((N, d, S))
    z = int(encode(np.array([0, 0]), d))
    for i in range(N):
        u[i, 1, z] = 0.5
        u[i, 0, z] = -0.5
    u = jnp.asarray(u)
    assert not is_one_sparse(u, p_t)
    res = continuity_residual(p_t, p_next, u, nbr)
    np.testing.assert_allclose(np.asarray(res), 0.0, atol=1e-12)  # CE holds...
    pushed = apply_sampling_rule(p_t, u, nbr)
    # ...but the sampling rule does NOT produce p_{t+1}: the per-position
    # product leaks mass onto (1,1) and keeps mass on (0,0).
    assert np.abs(np.asarray(pushed - p_next)).max() > 0.2


# ---------------------------------------------------------------------------
# Property-based sweep (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(d=st.integers(2, 3), N=st.integers(2, 3), P=st.integers(0, 1),
       seed=st.integers(0, 10_000))
def test_property_ar_path_always_generates(d, N, P, seed):
    d = d + 1                      # room for the mask token
    P = min(P, N - 1)
    mask_id = d - 1
    rng = np.random.default_rng(seed)
    q = random_q(d, N, rng, sparse=True)
    states = enumerate_states(d, N)
    q = jnp.where(jnp.asarray((states == mask_id).any(1)), 0.0, q)
    if float(q.sum()) == 0.0:
        return
    q = q / q.sum()
    path = ar_path(q, P, d, N, mask_id)
    nbr = neighbor_table(d, N)
    us = [ar_marginal_velocity(q, P, t, d, N, mask_id) for t in range(N - P)]
    ps = chain_marginals(path.marginal(0), us, nbr)
    for t in range(N - P + 1):
        np.testing.assert_allclose(np.asarray(ps[t]),
                                   np.asarray(path.marginal(t)), atol=1e-10)
    np.testing.assert_allclose(np.asarray(ps[-1]), np.asarray(q), atol=1e-10)
