"""Long-context decode paths: the sliding-window ring buffer must keep
producing exactly the same logits as full attention restricted to the last
``window`` positions, even after the cache wraps several times — the
correctness condition for the `long_500k` serving shape."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import build_model


def test_ring_buffer_wraps_match_windowed_forward():
    window = 8
    cfg = get_smoke_config("qwen3_8b").reduced(sliding_window=window)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    total = 40                      # 5× the window: several wraps
    toks = rng.integers(0, cfg.vocab, size=(2, total)).astype(np.int32)

    # decode path: prefill the first `window` tokens, then stream the rest
    prompt = {"tokens": jnp.asarray(toks[:, :window]),
              "labels": jnp.zeros((2, window), jnp.int32)}
    logits, cache = model.prefill(params, prompt, window)
    assert cache["k"].shape[2] == window
    decode_logits = []
    for pos in range(window, total):
        logits_t, cache = model.decode_step(
            params, cache, jnp.asarray(toks[:, pos]), jnp.asarray(pos))
        decode_logits.append(np.asarray(logits_t))

    # teacher-forced path with the same window mask
    full = {"tokens": jnp.asarray(toks),
            "labels": jnp.zeros_like(jnp.asarray(toks))}
    tf_logits = np.asarray(model.forward(params, full))

    # decode_step at position p consumed token p, so its logits predict
    # position p+1 — compare against teacher-forced logits at p.
    for i, pos in enumerate(range(window, total - 1)):
        np.testing.assert_allclose(decode_logits[i], tf_logits[:, pos],
                                   rtol=5e-3, atol=5e-3,
                                   err_msg=f"wrap mismatch at pos {pos}")


def test_recurrent_long_decode_state_is_constant_memory():
    """xLSTM decode carries O(1) state regardless of context length."""
    cfg = get_smoke_config("xlstm_125m")
    model = build_model(cfg)
    c_short = model.cache_shapes(4, 1_000)
    c_long = model.cache_shapes(4, 1_000_000)
    short = jax.tree.map(lambda s: s.shape, c_short)
    long = jax.tree.map(lambda s: s.shape, c_long)
    assert jax.tree.all(jax.tree.map(lambda a, b: a == b, short, long))
