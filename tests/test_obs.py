"""Engine telemetry layer: metrics registry, span tracing, Perfetto export.

Three strata:

* **Registry units** — counter/gauge/histogram semantics, get-or-create
  identity, base-label merging, the documented ``reset()`` contract, and
  the Prometheus text exposition format.
* **Recorder units** — ring-buffer bounding (metadata must survive
  wrap), event shapes for every Chrome ``ph`` kind, and the no-op
  recorder's zero-cost contract.
* **Engine integration** — a traced smoke server's exported trace must
  be schema-valid Perfetto JSON whose per-request spans tile the
  request's end-to-end latency EXACTLY (phases share boundary stamps);
  tracing must be observation-only (token parity with tracing off, zero
  events by default); TTFT/queue-delay must be measured from
  *submission* on a deliberately pool-starved queue; repeated
  ``serve()`` calls must not accumulate stale ``aborted``/``stopped``;
  the decentralized server's merged export must keep one ``pid`` per
  pod; speculative serving must populate the draft-source and
  accept-length diagnostics.
"""
import json
import math

import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models import build_model
from repro.obs.engine import EngineObs
from repro.obs.metrics import (MetricsRegistry, log_buckets, prometheus,
                               snapshot)
from repro.obs.trace import (ADMIT_TID, SLOT_TID0, STEP_TID, NullRecorder,
                             TraceRecorder, merge_chrome, us)
from repro.serve.api import EngineConfig, SamplingParams
from repro.serve.scheduler import (DecentralizedSlotServer, Request,
                                   SlotServer)

CACHE_LEN = 48


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def prompts_of(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
            for n in lens]


def chunked_config(**kw):
    base = dict(n_slots=2, cache_len=CACHE_LEN, paged=True, page_block=8,
                chunked_prefill=True, chunk=8)
    base.update(kw)
    return EngineConfig(**base)


# ---------------------------------------------------------------------------
# Metrics registry units
# ---------------------------------------------------------------------------

def test_counter_gauge_semantics():
    r = MetricsRegistry()
    c = r.counter("c_total", "help")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = r.gauge("g")
    g.set(7)
    g.inc(-2)
    assert g.value == 5.0


def test_histogram_buckets_and_mean():
    r = MetricsRegistry()
    h = r.histogram("h_seconds", bounds=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 3.0, 100.0):
        h.observe(v)
    assert h.counts == (1, 1, 1, 1)          # last = overflow (+Inf)
    assert h.count == 4 and h.sum == 105.0
    assert h.value == pytest.approx(105.0 / 4)
    with pytest.raises(ValueError):
        r.histogram("h_bad", bounds=(2.0, 1.0))
    # empty histogram's scalar summary is NaN, not a crash
    assert math.isnan(r.histogram("h_empty").value)


def test_log_buckets_span_and_monotonicity():
    b = log_buckets()
    assert b[0] == pytest.approx(1e-5) and b[-1] >= 10.0
    assert list(b) == sorted(b) and len(set(b)) == len(b)
    with pytest.raises(ValueError):
        log_buckets(lo=0)


def test_registry_get_or_create_and_type_conflict():
    r = MetricsRegistry(base_labels={"pod": "3"})
    c1 = r.counter("x_total", "first help")
    c2 = r.counter("x_total")
    assert c1 is c2 and c1.label_dict == {"pod": "3"}
    # same name, different labels → a distinct series of the same type
    c3 = r.counter("x_total", labels={"reason": "stop"})
    assert c3 is not c1
    assert c3.label_dict == {"pod": "3", "reason": "stop"}
    with pytest.raises(ValueError):
        r.gauge("x_total")
    assert r.get("x_total") is c1
    assert r.get("x_total", {"reason": "stop"}) is c3
    assert r.get("nope") is None


def test_registry_reset_keeps_handles_valid():
    r = MetricsRegistry()
    c = r.counter("c_total")
    h = r.histogram("h_seconds")
    c.inc(5)
    h.observe(1.0)
    r.reset()
    assert c.value == 0.0 and h.count == 0
    c.inc()                                   # the old handle still works
    assert r.get("c_total").value == 1.0


def test_prometheus_exposition_format():
    r = MetricsRegistry(base_labels={"pod": "0"})
    r.counter("req_total", "requests").inc(3)
    h = r.histogram("lat_seconds", "latency", bounds=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    text = r.to_prometheus()
    lines = text.splitlines()
    assert "# TYPE req_total counter" in lines
    assert 'req_total{pod="0"} 3.0' in lines
    # cumulative le buckets + the +Inf bucket + _sum/_count expansion
    assert 'lat_seconds_bucket{pod="0",le="0.1"} 1' in lines
    assert 'lat_seconds_bucket{pod="0",le="1.0"} 2' in lines
    assert 'lat_seconds_bucket{pod="0",le="+Inf"} 3' in lines
    assert 'lat_seconds_count{pod="0"} 3' in lines
    # TYPE once per name even across registries (one series per pod)
    r2 = MetricsRegistry(base_labels={"pod": "1"})
    r2.counter("req_total", "requests").inc(1)
    merged = prometheus([r, r2])
    assert merged.count("# TYPE req_total counter") == 1
    assert 'req_total{pod="1"} 1.0' in merged


def test_snapshot_merges_registries():
    r0 = MetricsRegistry(base_labels={"pod": "0"})
    r1 = MetricsRegistry(base_labels={"pod": "1"})
    r0.counter("c_total").inc()
    r1.counter("c_total").inc(2)
    snap = snapshot([r0, r1])
    vals = {m["labels"]["pod"]: m["value"] for m in snap["metrics"]}
    assert vals == {"0": 1.0, "1": 2.0}


# ---------------------------------------------------------------------------
# Trace recorder units
# ---------------------------------------------------------------------------

def test_null_recorder_is_inert():
    tr = NullRecorder(pid=0)
    assert tr.enabled is False
    tr.complete("x", 0.0, 1.0, 0)
    tr.instant("i", 0.5, 0)
    assert tr.events() == []
    assert tr.to_chrome()["traceEvents"] == []


def test_recorder_event_shapes():
    tr = TraceRecorder(capacity=64, pid=5)
    assert tr.enabled is True
    tr.set_process_name("pod 5")
    tr.set_thread_name(STEP_TID, "engine steps")
    tr.complete("span", 1.0, 1.25, SLOT_TID0, args={"rid": 7})
    tr.async_begin("queued", 1.0, 7)
    tr.async_end("queued", 2.0, 7)
    tr.instant("retire", 2.0, SLOT_TID0)
    tr.counter("engine", 2.0, {"active": 1})
    evs = tr.events()
    by_ph = {e["ph"]: e for e in evs}
    x = by_ph["X"]
    assert x["ts"] == us(1.0) and x["dur"] == us(1.25) - us(1.0)
    assert x["pid"] == 5 and x["tid"] == SLOT_TID0
    assert x["args"]["rid"] == 7
    assert by_ph["b"]["id"] == 7 and by_ph["e"]["id"] == 7
    assert by_ph["b"]["tid"] == ADMIT_TID
    assert by_ph["i"]["name"] == "retire"
    assert by_ph["C"]["args"] == {"active": 1}
    assert by_ph["M"]["ph"] == "M"
    # negative duration is clamped, never emitted
    tr.complete("clamped", 3.0, 2.0, 0)
    assert [e for e in tr.events() if e["name"] == "clamped"][0]["dur"] == 0


def test_ring_bounds_and_metadata_survive_wrap():
    tr = TraceRecorder(capacity=8, pid=0)
    tr.set_process_name("pod 0")
    tr.set_thread_name(0, "steps")
    for i in range(100):
        tr.instant(f"e{i}", float(i), 0)
    evs = tr.events()
    metas = [e for e in evs if e["ph"] == "M"]
    others = [e for e in evs if e["ph"] != "M"]
    assert len(metas) == 2                    # names survive the wrap
    assert len(others) == 8                   # ring holds the newest 8
    assert others[0]["name"] == "e92" and others[-1]["name"] == "e99"
    assert tr.dropped == 92
    with pytest.raises(ValueError):
        TraceRecorder(capacity=0)


def test_merge_chrome_concatenates_pods():
    a, b = TraceRecorder(capacity=8, pid=0), TraceRecorder(capacity=8, pid=1)
    a.instant("x", 1.0, 0)
    b.instant("y", 2.0, 0)
    doc = merge_chrome([a, b])
    assert {e["pid"] for e in doc["traceEvents"]} == {0, 1}


# ---------------------------------------------------------------------------
# Engine integration: schema, span sums, parity, TTFT, hygiene
# ---------------------------------------------------------------------------

REQ_KEYS = {"X": {"name", "ph", "ts", "dur", "pid", "tid"},
            "b": {"name", "ph", "ts", "pid", "tid", "id"},
            "e": {"name", "ph", "ts", "pid", "tid", "id"},
            "i": {"name", "ph", "ts", "pid", "tid"},
            "C": {"name", "ph", "ts", "pid", "args"},
            "M": {"name", "ph", "pid", "args"}}


def validate_chrome(doc, n_slots, pids):
    """Schema-validate a Chrome/Perfetto trace_event document."""
    assert set(doc) >= {"traceEvents", "displayTimeUnit"}
    evs = doc["traceEvents"]
    for e in evs:
        assert e["ph"] in REQ_KEYS, e
        missing = REQ_KEYS[e["ph"]] - set(e)
        assert not missing, (e, missing)
        if e["ph"] in ("X", "b", "e", "i"):
            assert isinstance(e["ts"], int) and e["ts"] >= 0, e
        if e["ph"] == "X":
            assert isinstance(e["dur"], int) and e["dur"] >= 0, e
    # X spans must nest properly per (pid, tid) track: sort by (start,
    # -dur) and check the enclosing-interval stack property
    tracks = {}
    for e in evs:
        if e["ph"] == "X":
            tracks.setdefault((e["pid"], e["tid"]), []).append(e)
    for track, spans in tracks.items():
        spans.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack = []
        for e in spans:
            while stack and e["ts"] >= stack[-1]:
                stack.pop()
            if stack:
                assert e["ts"] + e["dur"] <= stack[-1], \
                    (track, e, "overlaps an enclosing span")
            stack.append(e["ts"] + e["dur"])
    # track naming: one process_name per pod, one thread_name per slot
    # track plus the step + admission tracks
    for pid in pids:
        pmeta = [e for e in evs if e["ph"] == "M" and e["pid"] == pid]
        names = {e["name"]: e for e in pmeta}
        assert "process_name" in names, pid
        tids = {e["tid"] for e in pmeta if e["name"] == "thread_name"}
        assert tids >= {STEP_TID, ADMIT_TID} | \
            {SLOT_TID0 + s for s in range(n_slots)}, (pid, tids)
    return evs


def serve_traced(model, params, prompts, max_new=6, **cfg_kw):
    srv = SlotServer(model, params,
                     config=chunked_config(trace=True, prefix_cache=True,
                                           **cfg_kw))
    reqs = [Request(i, p, max_new) for i, p in enumerate(prompts)]
    out = srv.serve(reqs)
    return srv, reqs, out


def test_trace_schema_and_span_taxonomy(dense_setup):
    cfg, model, params = dense_setup
    srv, reqs, out = serve_traced(model, params,
                                  prompts_of(cfg, (12, 9, 14, 7)))
    doc = srv.export_trace()
    evs = validate_chrome(doc, n_slots=2, pids=[0])
    names = {e["name"] for e in evs if e["ph"] == "X"}
    # the documented span taxonomy (docs/observability.md)
    assert {"admission", "prefix_match", "prefill", "decode",
            "dispatch", "device_get"} <= names
    assert any(n.startswith("prefill_chunk[") for n in names)
    assert any(n.startswith("step:") for n in names)
    # every request retires exactly once, with its finish reason
    retires = [e for e in evs if e["ph"] == "i" and e["name"] == "retire"]
    assert len(retires) == len(reqs)
    assert all(e["args"]["finish_reason"] == "length" for e in retires)
    # queued async spans pair up b/e per rid
    for kind in ("b", "e"):
        assert {e["id"] for e in evs
                if e["ph"] == kind and e["name"] == "queued"} \
            == {r.rid for r in reqs}


def test_spans_tile_end_to_end_latency_exactly(dense_setup):
    """Phases share boundary stamps, so in integer µs each request's
    queued + admission + prefill(+chunks are nested) + decode spans
    telescope to exactly ``us(t_done) - us(t_submit)`` — the acceptance
    criterion's 'spans sum to end-to-end latency within stamp
    granularity', with zero slack because the boundaries are the SAME
    perf_counter values, not re-stamped."""
    cfg, model, params = dense_setup
    srv, reqs, _ = serve_traced(model, params, prompts_of(cfg, (12, 9, 15)))
    evs = srv.export_trace()["traceEvents"]
    for req in reqs:
        rid = req.rid
        phase = [e for e in evs if e["ph"] == "X"
                 and e["name"] in ("admission", "prefill", "decode")
                 and e["args"].get("rid") == rid]
        q_b = next(e for e in evs if e["ph"] == "b" and e["id"] == rid)
        q_e = next(e for e in evs if e["ph"] == "e" and e["id"] == rid)
        total = (q_e["ts"] - q_b["ts"]) + sum(e["dur"] for e in phase)
        assert total == us(req.t_done) - us(req.t_submit), \
            (rid, total, us(req.t_done) - us(req.t_submit))
        # and the phases are contiguous: each span starts where the
        # previous one ended
        phase.sort(key=lambda e: e["ts"])
        assert phase[0]["ts"] == q_e["ts"]
        for a, b in zip(phase, phase[1:]):
            assert a["ts"] + a["dur"] == b["ts"], (rid, a, b)


def test_tracing_is_observation_only(dense_setup):
    """Token-exact parity with tracing off — and the default (no-op
    recorder) path records nothing at all."""
    cfg, model, params = dense_setup
    ps = prompts_of(cfg, (12, 9, 14, 7))
    srv_off = SlotServer(model, params, config=chunked_config())
    out_off = srv_off.serve([Request(i, p, 6) for i, p in enumerate(ps)])
    _, _, out_on = serve_traced(model, params, ps)
    assert out_on == out_off
    assert srv_off.obs.trace.enabled is False
    assert srv_off.export_trace()["traceEvents"] == []
    # metrics are always on regardless of tracing
    assert srv_off.obs.steps.value > 0
    assert srv_off.obs.e2e_s.count == len(ps)


def test_ttft_measured_from_submission_under_pool_starvation(dense_setup):
    """The TTFT satellite: a pool-starved queue (every block in use until
    retirements free them) must report its wait in BOTH ``queued_s`` and
    ``ttft_s`` — TTFT from submission, never from admission."""
    cfg, model, params = dense_setup
    ps = prompts_of(cfg, (16, 16, 16, 16, 16, 16))
    # 2 slots, and a pool of just enough blocks for ~2 live requests:
    # later requests stay queued until a retirement frees blocks
    srv = SlotServer(model, params, config=chunked_config(pool_blocks=7))
    outs = {}
    for i, p in enumerate(ps):
        srv.add_request(p, SamplingParams(max_new=6), rid=i)
    while srv.has_unfinished():
        for o in srv.step():
            if o.finished:
                outs[o.rid] = o
    assert len(outs) == len(ps)
    for o in outs.values():
        assert o.t_admit >= o.t_submit > 0
        assert o.queued_s >= 0 and not math.isnan(o.queued_s)
        # TTFT includes the queue delay: first token can only follow
        # admission
        assert o.ttft_s >= o.queued_s
        assert o.ttft == o.ttft_s            # the explicit-unit alias
    # the starved tail waited on retirements — real, visible queue delay
    tail = sorted(outs.values(), key=lambda o: o.t_admit)[-1]
    head = sorted(outs.values(), key=lambda o: o.t_admit)[0]
    assert tail.queued_s > head.queued_s
    assert tail.queued_s > 1e-4
    # the registry saw every request's latency triple
    assert srv.obs.queued_s.count == len(ps)
    assert srv.obs.ttft_s.count == len(ps)
    assert srv.obs.e2e_s.count == len(ps)


def test_repeated_serve_does_not_accumulate_stats(dense_setup):
    """The stats-hygiene satellite: ``aborted``/``stopped`` in
    ``stats()`` are per-``serve()``-run, not process-lifetime."""
    cfg, model, params = dense_setup
    ps = prompts_of(cfg, (10, 10))
    srv = SlotServer(model, params, config=chunked_config())
    # run 1: force one stop and one abort
    first = srv.serve([Request(0, ps[0], 8)])[0][0]
    srv.add_request(ps[0], SamplingParams(max_new=8,
                                          stop_token_ids=(first,)), rid=10)
    srv.add_request(ps[1], SamplingParams(max_new=8), rid=11)
    srv.abort(11)
    while srv.has_unfinished():
        srv.step()
    st = srv.stats()
    assert st["stopped"] == 1 and st["aborted"] == 1
    # run 2 (plain): a fresh serve() must start the counters at zero
    out = srv.serve([Request(20, ps[1], 4)])
    assert len(out) == 1
    st2 = srv.stats()
    assert st2["stopped"] == 0 and st2["aborted"] == 0
    # ...while cumulative registry series keep counting across runs
    assert srv.obs.admitted.value >= 3
    # full registry reset is the documented wider hammer
    srv.metrics.reset()
    assert srv.obs.admitted.value == 0


def test_decentralized_trace_keeps_one_pid_per_pod(dense_setup):
    cfg, model, params = dense_setup
    K = 2
    from repro.core.router import CentroidRouter, RouterConfig
    rng = np.random.default_rng(0)
    experts = [model.init(jax.random.PRNGKey(k)) for k in range(K)]
    router = CentroidRouter(
        jax.numpy.asarray(rng.normal(size=(K, 8)), jax.numpy.float32),
        RouterConfig())
    srv = DecentralizedSlotServer(
        model, experts, router, config=chunked_config(trace=True))
    ps = prompts_of(cfg, (10, 9, 11, 8))
    feats = rng.normal(size=(len(ps), 8)).astype(np.float32)
    out = srv.serve([Request(i, p, 4, features=feats[i])
                     for i, p in enumerate(ps)])
    assert len(out) == len(ps)
    doc = srv.export_trace()
    pids = {e["pid"] for e in doc["traceEvents"]}
    assert pids == {0, 1}
    validate_chrome(doc, n_slots=2, pids=[0, 1])
    # per-pod labels distinguish the merged metrics series
    snap = srv.export_metrics()
    pods = {m["labels"].get("pod") for m in snap["metrics"]}
    assert pods == {"0", "1"}
    text = srv.prometheus_metrics()
    assert 'pod="0"' in text and 'pod="1"' in text
    # run-scoped reset works across pods too
    srv.reset_stats()
    assert all(p.stats()["stopped"] == 0 for p in srv.pods)


def test_speculative_diagnostics_populate(dense_setup):
    """Accept-length + draft-source diagnostics: a repetitive greedy
    workload through the ngram-speculative server must fill the
    accept-length histogram, the per-request accept-rate histogram, and
    the per-source draft counters — the registry view that makes an
    aggregate accept rate per-workload explainable."""
    cfg, model, params = dense_setup
    rng = np.random.default_rng(0)
    ps = []
    for n in (9, 13, 11):
        base = rng.integers(1, cfg.vocab, size=4)
        ps.append(np.tile(base, n // 4 + 2)[:n].astype(np.int32))
    ecfg = EngineConfig(n_slots=2, cache_len=CACHE_LEN, paged=True,
                        page_block=8, speculative="ngram", spec_len=4)
    srv = SlotServer(model, params, config=ecfg)
    out = srv.serve([Request(i, p, 16) for i, p in enumerate(ps)])
    assert len(out) == len(ps)
    obs = srv.obs
    assert obs.n_spec_steps > 0
    assert obs.accept_len.count == obs.n_spec_steps
    assert obs.accept_len.sum == obs.n_spec_tokens
    assert obs.req_accept_rate.count == len(ps)
    assert 0.0 <= obs.req_accept_rate.value <= 1.0
    proposed = obs.drafts("ngram", "proposed").value
    accepted = obs.drafts("ngram", "accepted").value
    assert proposed == obs.n_spec_steps * (ecfg.spec_len - 1)
    assert accepted == obs.n_spec_tokens - obs.n_spec_steps
    assert 0 <= accepted <= proposed


def test_engine_config_validates_trace_ring():
    with pytest.raises(ValueError):
        EngineConfig(trace=True, trace_ring=0).validate(None)


def test_aborted_from_queue_closes_queued_span(dense_setup):
    """A request aborted while still waiting (never admitted) must still
    appear in the trace — its queued span closes at the abort, keeping
    the trace an honest record of every request the engine saw."""
    cfg, model, params = dense_setup
    srv = SlotServer(model, params,
                     config=chunked_config(n_slots=1, trace=True))
    ps = prompts_of(cfg, (10, 10))
    srv.add_request(ps[0], SamplingParams(max_new=40), rid=0)
    srv.step()                       # rid 0 occupies the only slot
    srv.add_request(ps[1], SamplingParams(max_new=4), rid=1)
    out = srv.abort(1)
    assert out is not None and out.finish_reason == "aborted"
    while srv.has_unfinished():
        srv.step()
    evs = srv.export_trace()["traceEvents"]
    q = [e for e in evs if e["ph"] in ("b", "e") and e["id"] == 1]
    assert {e["ph"] for e in q} == {"b", "e"}
    aborts = [e for e in evs if e["ph"] == "i" and e["name"] == "abort"]
    assert len(aborts) == 1 and aborts[0]["args"]["rid"] == 1
    assert srv.obs.n_aborted == 1
