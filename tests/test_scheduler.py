"""Continuous batching correctness: lockstep slot decoding with mixed
prompt lengths must reproduce per-request sequential greedy decoding."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import build_model
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Request, SlotServer


def test_slot_server_matches_sequential_greedy():
    cfg = get_smoke_config("qwen3_8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache_len = 48
    rng = np.random.default_rng(0)

    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (7, 12, 5, 9, 16)]
    budgets = [6, 4, 8, 5, 3]

    # ground truth: each request decoded alone, greedy
    engine = ServeEngine(model, cache_len)
    want = {}
    for rid, (p, m) in enumerate(zip(prompts, budgets)):
        batch = {"tokens": jnp.asarray(p[None, :]),
                 "labels": jnp.zeros((1, len(p)), jnp.int32)}
        toks = engine.generate(params, batch, m, jax.random.PRNGKey(1),
                               temperature=0.0)
        want[rid] = np.asarray(toks)[0].tolist()

    # continuous batching with only 2 slots for 5 requests
    server = SlotServer(model, params, n_slots=2, cache_len=cache_len)
    queue = [Request(rid, p, m)
             for rid, (p, m) in enumerate(zip(prompts, budgets))]
    got = server.serve(queue)

    assert set(got) == set(want)
    for rid in want:
        assert got[rid] == want[rid], (rid, got[rid], want[rid])


def test_slot_reuse_and_occupancy():
    cfg = get_smoke_config("granite_3_8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    server = SlotServer(model, params, n_slots=3, cache_len=32)
    rng = np.random.default_rng(1)
    reqs = [Request(i, rng.integers(0, cfg.vocab, size=6).astype(np.int32), 3)
            for i in range(7)]
    out = server.serve(reqs)
    assert len(out) == 7
    assert all(len(v) == 3 for v in out.values())
    assert server.active == []            # all slots freed
