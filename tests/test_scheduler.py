"""Continuous batching correctness.

* Lockstep slot decoding with mixed prompt lengths must reproduce
  per-request sequential greedy decoding — for EVERY cache family (the
  model's CacheSpec descriptor drives admission generically).
* The stacked-vmap mixture decode core must match the per-expert-loop
  reference token-for-token / to numerical tolerance.
* The decentralized slot server (router front end) must agree with the
  per-expert engines it composes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.ensemble import mix_expert_logits
from repro.core.router import CentroidRouter, RouterConfig
from repro.models import build_model
from repro.serve.engine import ServeEngine
from repro.serve.ensemble_engine import DecentralizedServer
from repro.serve.scheduler import (DecentralizedSlotServer, Request,
                                   SlotServer)

FAMILY_ARCHS = [
    ("qwen3_8b", "dense"),
    ("deepseek_moe_16b", "moe"),
    ("internvl2_2b", "vlm"),
    ("whisper_small", "audio"),
    ("xlstm_125m", "ssm"),
    ("zamba2_2_7b", "hybrid"),
]


def make_requests(cfg, lens, budgets, seed=42):
    """Deterministic request queue with per-family modality extras."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i, (n, m) in enumerate(zip(lens, budgets)):
        extras = {}
        if cfg.family == "vlm":
            extras["patches"] = rng.normal(
                size=(cfg.n_patches, cfg.vision_dim)).astype(np.float32)
        if cfg.family == "audio":
            extras["frames"] = rng.normal(
                size=(cfg.n_audio_frames, cfg.audio_dim)).astype(np.float32)
        reqs.append(Request(i, rng.integers(0, cfg.vocab, size=n)
                            .astype(np.int32), m, extras=extras))
    return reqs


def engine_greedy(engine, params, req):
    batch = {"tokens": jnp.asarray(req.tokens[None, :]),
             "labels": jnp.zeros((1, len(req.tokens)), jnp.int32)}
    for name, v in req.extras.items():
        batch[name] = jnp.asarray(np.asarray(v)[None])
    toks = engine.generate(params, batch, req.max_new, jax.random.PRNGKey(1),
                           temperature=0.0)
    return np.asarray(toks)[0].tolist()


def test_slot_server_matches_sequential_greedy():
    cfg = get_smoke_config("qwen3_8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache_len = 48
    rng = np.random.default_rng(0)

    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (7, 12, 5, 9, 16)]
    budgets = [6, 4, 8, 5, 3]

    # ground truth: each request decoded alone, greedy
    engine = ServeEngine(model, cache_len)
    want = {}
    for rid, (p, m) in enumerate(zip(prompts, budgets)):
        want[rid] = engine_greedy(engine, params, Request(rid, p, m))

    # continuous batching with only 2 slots for 5 requests
    server = SlotServer(model, params, n_slots=2, cache_len=cache_len)
    queue = [Request(rid, p, m)
             for rid, (p, m) in enumerate(zip(prompts, budgets))]
    got = server.serve(queue)

    assert set(got) == set(want)
    for rid in want:
        assert got[rid] == want[rid], (rid, got[rid], want[rid])


@pytest.mark.parametrize("arch,family", FAMILY_ARCHS)
def test_slot_server_family_parity(arch, family):
    """Greedy SlotServer.serve must equal ServeEngine.generate(temperature=0)
    token-for-token for every supported cache family."""
    cfg = get_smoke_config(arch).reduced(vocab=256)
    assert cfg.family == family
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache_len = 40
    lens, budgets = (7, 11, 5), (4, 3, 5)

    engine = ServeEngine(model, cache_len)
    want = {r.rid: engine_greedy(engine, params, r)
            for r in make_requests(cfg, lens, budgets)}

    server = SlotServer(model, params, n_slots=2, cache_len=cache_len)
    got = server.serve(make_requests(cfg, lens, budgets))
    assert set(got) == set(want)
    for rid in want:
        assert got[rid] == want[rid], (arch, rid, got[rid], want[rid])
    assert server.active == []


def test_slot_reuse_and_occupancy():
    cfg = get_smoke_config("granite_3_8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    server = SlotServer(model, params, n_slots=3, cache_len=32)
    rng = np.random.default_rng(1)
    reqs = [Request(i, rng.integers(0, cfg.vocab, size=6).astype(np.int32), 3)
            for i in range(7)]
    out = server.serve(reqs)
    assert len(out) == 7
    assert all(len(v) == 3 for v in out.values())
    assert server.active == []            # all slots freed
    # contiguous: no pool counters; nothing aborted or stop-retired
    assert server.stats() == {"active": 0, "waiting": 0, "aborted": 0,
                              "stopped": 0}


def test_stats_report_pool_and_prefix_counters():
    """stats() (the payload of DecentralizedSlotServer.occupancy() and the
    serve-completion log) reports the pool free-block count and — with the
    prefix cache on — its hit-rate counters."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(8)
    shared = rng.integers(0, cfg.vocab, size=16).astype(np.int32)
    prompts = [np.concatenate(
        [shared, rng.integers(0, cfg.vocab, size=4).astype(np.int32)])
        for _ in range(3)]

    paged = SlotServer(model, params, n_slots=2, cache_len=32, page_block=8)
    paged.serve([Request(i, p, 3) for i, p in enumerate(prompts)])
    st = paged.stats()
    assert st["active"] == 0 and "prefix_hit_rate" not in st
    assert st["pool_free_blocks"] == st["pool_blocks"] - 1  # all returned

    srv = SlotServer(model, params, n_slots=1, cache_len=32, page_block=8,
                     chunk=8, prefix_cache=True)
    srv.serve([Request(i, p, 3) for i, p in enumerate(prompts)])
    st = srv.stats()
    assert st["prefix_lookups"] == 3
    # requests 1 and 2 each skipped the two full shared blocks
    assert st["prefix_skipped_tokens"] == 2 * 16
    assert st["prefix_hit_rate"] == pytest.approx(32 / 60, abs=1e-4)
    assert st["prefix_cached_blocks"] == st["prefix_evictable_blocks"] > 0
    assert st["pool_free_blocks"] == \
        st["pool_blocks"] - 1 - st["prefix_cached_blocks"]


def test_slot_server_use_kernel_parity():
    """The Pallas decode/prefill kernels (interpret mode on CPU) must be
    reachable from continuous batching and agree with the jnp path."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def queue():
        return make_requests(cfg, (8, 8), (3, 3), seed=7)

    ref = SlotServer(model, params, n_slots=2, cache_len=16).serve(queue())
    ker = SlotServer(model, params, n_slots=2, cache_len=16,
                     use_kernel=True).serve(queue())
    assert ref == ker


# ---------------------------------------------------------------------------
# Stacked-expert mixture core
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mixture_setup():
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=128)
    model = build_model(cfg)
    K, Df, B, S = 3, 16, 4, 10
    experts = [model.init(jax.random.PRNGKey(k)) for k in range(K)]
    rng = np.random.default_rng(1)
    router = CentroidRouter(
        jnp.asarray(rng.normal(size=(K, Df)), jnp.float32),
        RouterConfig(top_k=2))
    toks = rng.integers(0, cfg.vocab, size=(B, S)).astype(np.int32)
    feats = rng.normal(size=(B, Df)).astype(np.float32)
    batch = {"tokens": jnp.asarray(toks),
             "labels": jnp.zeros((B, S), jnp.int32),
             "features": jnp.asarray(feats)}
    return cfg, model, experts, router, toks, feats, batch


def looped_mixture_reference(model, experts, router, batch, n_new,
                             cache_len):
    """The pre-refactor per-expert Python loop, kept as the oracle."""
    engine = ServeEngine(model, cache_len)
    weights = router.route(batch["features"])
    sub = {k: v for k, v in batch.items() if k != "features"}
    states = []
    for p in experts:
        logits, cache = engine.prefill(p, sub)
        states.append((logits[:, -1], cache))
    prompt_len = sub["tokens"].shape[1]
    out = []
    for i in range(n_new):
        probs = mix_expert_logits(jnp.stack([s[0] for s in states]), weights)
        tok = jnp.argmax(probs, axis=-1).astype(jnp.int32)
        out.append(tok)
        if i == n_new - 1:
            break
        states = [engine.decode_step(p, c, tok, prompt_len + i)
                  for p, (_, c) in zip(experts, states)]
    return np.asarray(jnp.stack(out, axis=1))


def test_stacked_mixture_matches_looped_reference(mixture_setup):
    """The single vmapped decode step over stacked expert params (mixing
    fused into the jitted step) must reproduce the sequential per-expert
    loop exactly."""
    cfg, model, experts, router, toks, feats, batch = mixture_setup
    server = DecentralizedServer(model, experts, router, cache_len=24)
    got = np.asarray(server.generate_mixture(
        batch, 6, jax.random.PRNGKey(0), temperature=0.0))
    want = looped_mixture_reference(model, experts, router, batch, 6, 24)
    np.testing.assert_array_equal(got, want)


def test_stacked_mixture_probs_match_loop(mixture_setup):
    cfg, model, experts, router, toks, feats, batch = mixture_setup
    server = DecentralizedServer(model, experts, router, cache_len=24)
    got = np.asarray(server.mixture_next_probs(batch))
    engine = ServeEngine(model, 24)
    sub = {k: v for k, v in batch.items() if k != "features"}
    stacked = jnp.stack([engine.prefill(p, sub)[0][:, -1] for p in experts])
    want = np.asarray(mix_expert_logits(stacked,
                                        router.route(batch["features"])))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def test_decentralized_slot_server_grouped_top1(mixture_setup):
    """Grouped top-1 continuous batching must equal running each request on
    exactly its routed expert."""
    cfg, model, experts, router, toks, feats, batch = mixture_setup
    B = toks.shape[0]

    def queue():
        return [Request(i, toks[i], 5, features=feats[i]) for i in range(B)]

    server = DecentralizedSlotServer(model, experts, router, n_slots=2,
                                     cache_len=24, strategy="top1")
    got = server.serve(queue())
    expert_of = np.asarray(router.top1(batch["features"]))
    engine = ServeEngine(model, 24)
    for i in range(B):
        want = engine_greedy(engine, experts[int(expert_of[i])],
                             Request(i, toks[i], 5))
        assert got[i] == want, (i, got[i], want)


@pytest.mark.parametrize("arch", ["qwen3_8b", "zamba2_2_7b", "xlstm_125m"])
def test_stacked_cache_pspec_layout(arch):
    """The stacked-cache sharding helper must put the ``dexpert`` (pod)
    axis at position 1 of every leaf — matching the decode layout — and
    keep the per-expert remainder's placement."""
    from jax.sharding import Mesh
    from repro.sharding.rules import (cache_pspec_tree, logical_rules,
                                      stacked_cache_pspec_tree)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("pod", "data", "model"))
    rules = logical_rules(multi_pod=True, decentralized=True)
    model = build_model(get_smoke_config(arch))
    K = 2
    shapes = model.cache_shapes(4, 16)
    stacked = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape[:1] + (K,) + s.shape[1:],
                                       s.dtype), shapes)
    specs = stacked_cache_pspec_tree(stacked, rules, mesh)
    inner = cache_pspec_tree(shapes, rules, mesh)

    def check(stacked_ns, inner_ns, leaf):
        spec = tuple(stacked_ns.spec)
        spec += (None,) * (len(leaf.shape) - len(spec))
        assert spec[1] == rules["dexpert"] == "pod", (leaf.shape, spec)
        want = tuple(inner_ns.spec)
        want += (None,) * (len(leaf.shape) - 1 - len(want))
        assert spec[:1] + spec[2:] == want, (leaf.shape, spec, want)

    jax.tree.map(check, specs, inner, stacked)


def test_decentralized_slot_server_mixture_matches_batch(mixture_setup):
    """The stacked mixture slot server (continuous batching) must equal the
    whole-batch mixture generation when every request fits in a slot."""
    cfg, model, experts, router, toks, feats, batch = mixture_setup
    B = toks.shape[0]
    server = DecentralizedSlotServer(model, experts, router, n_slots=B,
                                     cache_len=24, strategy="mixture")
    got = server.serve(
        [Request(i, toks[i], 5, features=feats[i]) for i in range(B)])
    ref = DecentralizedServer(model, experts, router, cache_len=24)
    want = np.asarray(ref.generate_mixture(
        batch, 5, jax.random.PRNGKey(0), temperature=0.0))
    for i in range(B):
        assert got[i] == want[i].tolist(), (i, got[i], want[i])
