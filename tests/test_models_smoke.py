"""Per-architecture smoke tests: reduced config (≤2 layers, d_model ≤ 512,
≤4 experts), one forward + one train-gradient step + prefill/decode
consistency on CPU. Asserts output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_smoke_config
from repro.models import build_model

B, S = 2, 32


def make_batch(cfg, key, seq=S):
    ks = jax.random.split(key, 3)
    n_text = seq - (cfg.n_patches if cfg.family == "vlm" else 0)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, n_text), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (B, n_text), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            ks[2], (B, cfg.n_patches, cfg.vision_dim), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.n_audio_frames, cfg.audio_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_grad(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    logits = jax.jit(lambda p, b: model.forward(p, b))(params, batch)
    seq_out = S if cfg.family != "vlm" else S
    assert logits.shape == (B, seq_out, cfg.vocab), logits.shape
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    loss, grads = jax.jit(
        lambda p, b: jax.value_and_grad(lambda q: model.loss(q, b)[0])(p)
    )(params, batch)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    """serve path consistency: prefill on S tokens then decode_step must
    reproduce the teacher-forced logits at the last position."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    cache_len = S + 8

    logits_tf = model.forward(params, batch)
    logits_pf, cache = model.prefill(params, batch, cache_len)
    np.testing.assert_allclose(np.asarray(logits_pf), np.asarray(logits_tf),
                               rtol=2e-4, atol=2e-4)

    # decode one more token and check shape/finiteness + cross-check: feeding
    # token t_S via decode matches a fresh forward on S+1 tokens.
    next_tok = batch["tokens"][:, -1]
    n_text = batch["tokens"].shape[1]
    pos = jnp.asarray(S)  # position index of the new token in the full seq
    logits_dec, cache2 = model.decode_step(params, cache, next_tok, pos)
    assert logits_dec.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits_dec).all())

    ext = dict(batch)
    ext["tokens"] = jnp.concatenate([batch["tokens"], next_tok[:, None]], 1)
    logits_full = model.forward(params, ext)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, -1]),
                               rtol=5e-3, atol=5e-3)


def test_sliding_window_decode():
    """Ring-buffer cache: decode with window w must match full attention
    restricted to the last w positions."""
    from dataclasses import replace
    cfg = replace(get_smoke_config("qwen3_8b"), sliding_window=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits_pf, cache = model.prefill(params, batch, S)
    assert cache["k"].shape[2] == 8          # cache is the window, not S
    next_tok = batch["tokens"][:, -1]
    logits_dec, _ = model.decode_step(params, cache, next_tok, jnp.asarray(S))
    ext = dict(batch)
    ext["tokens"] = jnp.concatenate([batch["tokens"], next_tok[:, None]], 1)
    logits_full = model.forward(params, ext)   # forward masks by window too
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, -1]),
                               rtol=5e-3, atol=5e-3)


def test_param_counts_full_configs():
    """The full (dry-run-only) configs must hit the advertised scale —
    sanity-check parameter counts via ParamSpec trees (no allocation)."""
    from repro.configs.base import get_config
    from repro.models.params import count_params
    expect = {
        "llama3_405b": (380e9, 430e9),
        "qwen3_moe_235b_a22b": (200e9, 260e9),
        "granite_3_8b": (7e9, 10e9),
        "qwen3_8b": (7e9, 10e9),
        "phi3_medium_14b": (12e9, 16e9),
        "deepseek_moe_16b": (14e9, 20e9),
        "internvl2_2b": (1.5e9, 2.6e9),
        "whisper_small": (0.15e9, 0.5e9),
        "xlstm_125m": (0.08e9, 0.2e9),
        "zamba2_2_7b": (2.0e9, 3.5e9),
    }
    for arch, (lo, hi) in expect.items():
        model = build_model(get_config(arch))
        n = count_params(model.param_specs())
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of range"
