"""Balanced spherical k-means + centroid router (paper §5.1–5.2)."""
import numpy as np
from _hyp import given, settings, st

import jax.numpy as jnp

from repro.core.clustering import (partition_text_only,
                                   spherical_balanced_kmeans,
                                   two_stage_balanced_kmeans)
from repro.core.router import RouterConfig, router_from_clustering


def gaussian_mixture(n, K, D, seed=0, sep=4.0):
    rng = np.random.default_rng(seed)
    means = rng.normal(size=(K, D)) * sep
    labels = np.repeat(np.arange(K), n // K)
    x = means[labels] + rng.normal(size=(len(labels), D))
    return x, labels


@settings(max_examples=10, deadline=None)
@given(n_per=st.integers(8, 40), K=st.integers(2, 5), D=st.integers(4, 32),
       seed=st.integers(0, 1000))
def test_property_balance(n_per, K, D, seed):
    """Cluster sizes differ by at most 1 (exactly equal when K | N)."""
    x, _ = gaussian_mixture(n_per * K, K, D, seed)
    res = spherical_balanced_kmeans(x, K, seed=seed)
    counts = np.bincount(res.assignment, minlength=K)
    assert counts.max() - counts.min() <= 1
    assert counts.sum() == n_per * K
    np.testing.assert_allclose(np.linalg.norm(res.centroids, axis=1), 1.0,
                               atol=1e-9)


def test_recovers_separated_clusters():
    x, labels = gaussian_mixture(120, 3, 16, seed=1, sep=8.0)
    res = spherical_balanced_kmeans(x, 3, seed=1)
    # cluster ids are permuted; check purity
    purity = 0
    for k in range(3):
        members = labels[res.assignment == k]
        purity += np.bincount(members, minlength=3).max()
    assert purity / len(labels) > 0.95


def test_two_stage_variant():
    x, _ = gaussian_mixture(200, 2, 8, seed=2, sep=6.0)
    res = two_stage_balanced_kmeans(x, 2, fine_k=16, seed=2)
    counts = np.bincount(res.assignment, minlength=2)
    # 2-stage balance is approximate (fine-centroid level)
    assert counts.min() > 0.2 * len(x)
    np.testing.assert_allclose(np.linalg.norm(res.centroids, axis=1), 1.0,
                               atol=1e-9)


def test_text_only_partition_balanced():
    a = partition_text_only(103, 4, seed=0)
    counts = np.bincount(a, minlength=4)
    assert counts.max() - counts.min() <= 1


def test_router_mirrors_partitioning():
    """§5.1: the centroid router's top-1 must reproduce the (unbalanced)
    nearest-centroid assignment used at partition time."""
    x, labels = gaussian_mixture(90, 3, 12, seed=3, sep=8.0)
    res = spherical_balanced_kmeans(x, 3, seed=3)
    router = router_from_clustering(res.centroids)
    top1 = np.asarray(router.top1(jnp.asarray(x, dtype=jnp.float32)))
    nearest = res.sims.argmax(1)
    assert (top1 == nearest).mean() > 0.99


def test_router_eq28_softmax():
    """Eq. 28: probabilities = softmax(τ·cos); temperature sharpens."""
    x, _ = gaussian_mixture(30, 2, 8, seed=4)
    res = spherical_balanced_kmeans(x, 2, seed=4)
    xf = jnp.asarray(x, dtype=jnp.float32)
    cold = router_from_clustering(res.centroids, RouterConfig(temperature=1.0))
    hot = router_from_clustering(res.centroids, RouterConfig(temperature=50.0))
    pc, ph = np.asarray(cold.cluster_probs(xf)), np.asarray(hot.cluster_probs(xf))
    np.testing.assert_allclose(pc.sum(-1), 1.0, atol=1e-6)
    np.testing.assert_allclose(ph.sum(-1), 1.0, atol=1e-6)
    assert ph.max(-1).mean() >= pc.max(-1).mean()  # sharper at high τ
    # top-k filter: k=1 puts all mass on one expert
    routed = np.asarray(cold.route(xf))
    np.testing.assert_allclose(routed.max(-1), 1.0, atol=1e-6)
