"""Trainable flash attention: custom-VJP gradients vs autodiff through the
jnp oracle, plus the LSE residual itself."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_with_lse


@pytest.mark.parametrize("B,S,H,KV,dh,causal,window", [
    (1, 128, 4, 2, 32, True, 0),
    (2, 64, 4, 4, 32, False, 0),
    (1, 128, 4, 1, 32, True, 32),
])
def test_flash_gradients_match_reference(B, S, H, KV, dh, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, S, H, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, dh), jnp.float32)
    co = jax.random.normal(ks[3], (B, S, H, dh), jnp.float32)

    def loss_kernel(q, k, v):
        out = ops.flash_attention(q, k, v, causal=causal, window=window,
                                  block_q=32, block_k=32)
        return (out * co).sum()

    def loss_ref(q, k, v):
        out = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
        return (out * co).sum()

    g_kernel = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_kernel, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_lse_matches_reference():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, KV, dh = 1, 64, 2, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, dh), jnp.float32)
    _, lse = flash_attention_with_lse(q, k, v, causal=True, block_q=32,
                                      block_k=32, interpret=True)
    # reference lse
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, jnp.repeat(k, H // KV, 2))
    logits = logits / jnp.sqrt(jnp.float32(dh))
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    logits = jnp.where((j <= i)[None, None], logits, -1e30)
    want = jax.scipy.special.logsumexp(logits, axis=-1)     # (B,H,S)
    np.testing.assert_allclose(np.asarray(lse),
                               np.asarray(jnp.moveaxis(want, 1, 2)),
                               rtol=1e-5, atol=1e-5)


def test_training_step_through_kernel():
    """A full train-gradient step through use_kernel=True stays finite and
    close to the jnp-path gradients."""
    from repro.configs.base import get_smoke_config
    from repro.models import build_model
    cfg = get_smoke_config("qwen3_8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          cfg.vocab),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                                          cfg.vocab)}

    def loss(p, use_kernel):
        logits = model.forward(p, batch, use_kernel=use_kernel)
        lp = jax.nn.log_softmax(logits, -1)
        return -jnp.take_along_axis(lp[:, :-1],
                                    batch["labels"][:, 1:, None], -1).mean()

    gk = jax.grad(lambda p: loss(p, True))(params)
    gr = jax.grad(lambda p: loss(p, False))(params)
    norms = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), gk, gr)
    worst = max(jax.tree.leaves(norms))
    assert np.isfinite(worst) and worst < 5e-3, worst


def test_bwd_kernel_matches_jnp_reference_directly():
    """The blocked backward kernels vs straight autodiff of the oracle,
    across GQA groupings and window masks."""
    from repro.kernels.flash_attention import flash_attention_with_lse
    from repro.kernels.flash_attention_bwd import flash_attention_bwd
    for (KV, causal, window) in [(4, True, 0), (2, True, 16), (1, False, 0)]:
        ks = jax.random.split(jax.random.PRNGKey(KV), 4)
        B, S, H, dh = 1, 64, 4, 32
        q = jax.random.normal(ks[0], (B, S, H, dh), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, KV, dh), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, KV, dh), jnp.float32)
        do = jax.random.normal(ks[3], (B, S, H, dh), jnp.float32)
        out, lse = flash_attention_with_lse(q, k, v, causal=causal,
                                            window=window, block_q=32,
                                            block_k=32, interpret=True)
        dq, dk, dv = flash_attention_bwd(q, k, v, out, lse, do,
                                         causal=causal, window=window,
                                         block_q=32, block_k=32,
                                         interpret=True)
        _, vjp = jax.vjp(lambda a, b, c, causal=causal, window=window:
                         ref.flash_attention_ref(a, b, c, causal=causal,
                                                 window=window), q, k, v)
        rq, rk, rv = vjp(do)
        np.testing.assert_allclose(np.asarray(dq), np.asarray(rq),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(dk), np.asarray(rk),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(dv), np.asarray(rv),
                                   rtol=2e-4, atol=2e-4)
