"""The paper's §1 motivation: one node failure must not disturb the other
experts (vs centralized training, where any failure forces a global
restart). Simulated: kill expert 1 mid-run, restore from ITS checkpoint,
and verify expert 0's trajectory is bit-identical and the final ensemble
is well-defined."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import get_smoke_config
from repro.data.partition import partition_dataset
from repro.data.pipeline import LoaderConfig, ShardLoader
from repro.data.synthetic import SyntheticConfig, SyntheticMultimodal
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import TrainConfig, init_train_state, make_train_step


def test_expert_failure_is_isolated(tmp_path):
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=64)
    model = build_model(cfg)
    corpus = SyntheticMultimodal(SyntheticConfig(vocab=64, seq_len=24,
                                                 n_samples=256, seed=0))
    part = partition_dataset(corpus.all_features(), 2, seed=0)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    step_fn = jax.jit(make_train_step(model, TrainConfig(opt=opt)))
    base = str(tmp_path)

    def batches(k):
        loader = ShardLoader(corpus, LoaderConfig(batch_size=4),
                             subset=part.shards[k], offset=10_000 * k)
        return loader

    # --- run both experts 10 steps, checkpoint at step 5 ------------------
    final_losses = {}
    states = {}
    for k in range(2):
        state = init_train_state(model, jax.random.PRNGKey(100 + k), opt)
        loader = batches(k)
        for step in range(10):
            b = next(loader)
            jb = {n: jnp.asarray(b[n]) for n in ("tokens", "labels")}
            state, m = step_fn(state, jb)
            if step == 4:
                ckpt.save_expert(base, k, 5, state)
        states[k] = state
        final_losses[k] = float(m["loss"])

    # --- expert 1 "fails" at step 5 and restarts from ITS checkpoint ------
    restored, at = ckpt.restore_expert(base, 1, 5)
    assert at == 5
    loader = batches(1)
    for _ in range(5):      # skip the first 5 batches it already consumed
        next(loader)
    state1 = restored
    for step in range(5, 10):
        b = next(loader)
        jb = {n: jnp.asarray(b[n]) for n in ("tokens", "labels")}
        state1, m1 = step_fn(state1, jb)

    # recovery is exact: the replayed expert matches its uninterrupted run
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a, np.float32),
                                                np.asarray(b, np.float32),
                                                rtol=1e-6, atol=1e-6),
        states[1]["params"], state1["params"])
    # and expert 0 never noticed: no shared state exists by construction —
    # its checkpoint dir is untouched by expert 1's failure/restore cycle
    assert ckpt.latest_step(base, 0) == 5
    assert np.isfinite(final_losses[0])


def test_ckpt_roundtrip_preserves_empty_containers(tmp_path):
    """load(save(tree)) must return the SAME pytree structure, including
    empty dicts/lists/tuples (e.g. optimizer extra-state slots) — the seed
    flattener dropped them, silently changing the tree structure."""
    tree = {
        "params": {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
                   "empty": {}},
        "mu": [np.ones(3, np.float32), []],
        "extras": (),
        "nested": {"a": ({"b": []},), "t": (np.int32(3), {})},
        "step": np.int64(7),
    }
    path = str(tmp_path / "rt.npz")
    ckpt.save(path, tree)
    got = jax.device_get(ckpt.load(path))

    assert jax.tree.structure(got) == jax.tree.structure(tree), (
        jax.tree.structure(got), jax.tree.structure(tree))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), got, tree)
    # the exact container types survive too (tuple vs list matters to jit)
    assert isinstance(got["params"]["empty"], dict)
    assert got["mu"][1] == [] and isinstance(got["mu"][1], list)
    assert got["extras"] == () and isinstance(got["extras"], tuple)
    assert isinstance(got["nested"]["a"][0]["b"], list)
    # every empty container is a FRESH object — mutating one restored tree
    # must never leak into other containers or later loads
    assert got["params"]["empty"] is not got["nested"]["t"][1]
    got["params"]["empty"]["x"] = 1
    again = ckpt.load(path)
    assert again["params"]["empty"] == {}
