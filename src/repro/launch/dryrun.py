import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
combination with ShapeDtypeStruct inputs (zero allocation), print
memory/cost analysis, extract the roofline terms and the collective
schedule, and verify the decentralized mode's zero-cross-pod property.

The two lines above MUST stay the first statements in this module: jax
locks the device count at first init, and only the dry-run may see 512
placeholder devices (smoke tests and benches see the 1 real CPU device).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_8b \
        --shape train_4k --mesh multi --mode dense
    PYTHONPATH=src python -m repro.launch.dryrun --all  # full matrix
"""
import argparse
import json
import time
import traceback
from dataclasses import asdict, replace
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ARCH_IDS, INPUT_SHAPES, InputShape,
                                ModelConfig, get_config)
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (RooflineReport, active_params,
                                   collective_summary, model_flops)
from repro.models import build_model
from repro.models.params import count_params, tree_shapes, tree_shardings
from repro.optim.adamw import AdamWConfig
from repro.sharding import rules as R
from repro.train.trainer import (TrainConfig, make_decentralized_train_step,
                                 make_train_step)

LONG_DECODE_WINDOW = 8192      # sliding window applied at long_500k


def _cost_dict(compiled) -> Dict[str, float]:
    """``Compiled.cost_analysis()`` returns a dict on recent JAX but a
    one-element list of dicts on older releases — normalize to a dict."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def shape_cfg(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """long_500k needs sub-quadratic attention: window the attention archs
    (xLSTM has none; whisper is skipped upstream)."""
    if shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm",
                                                    "hybrid"):
        return replace(cfg, sliding_window=LONG_DECODE_WINDOW)
    return cfg


def is_skipped(arch: str, shape: InputShape) -> Optional[str]:
    if arch == "whisper_small" and shape.name == "long_500k":
        return ("enc-dec with a 448-position decoder by construction; "
                "524k-token decode is out of family (DESIGN.md §Shape/skip)")
    return None


def batch_shapes(cfg: ModelConfig, shape: InputShape,
                 decentralized_k: int = 0) -> Dict[str, jax.ShapeDtypeStruct]:
    B = shape.global_batch
    if decentralized_k:
        B = B // decentralized_k
    lead = (decentralized_k,) if decentralized_k else ()
    S = shape.seq_len
    n_text = S - (cfg.n_patches if cfg.family == "vlm" else 0)
    sds = jax.ShapeDtypeStruct
    out = {"tokens": sds(lead + (B, n_text), jnp.int32),
           "labels": sds(lead + (B, n_text), jnp.int32)}
    if cfg.family == "vlm":
        out["patches"] = sds(lead + (B, cfg.n_patches, cfg.vision_dim),
                             jnp.bfloat16)
    if cfg.family == "audio":
        out["frames"] = sds(lead + (B, cfg.n_audio_frames, cfg.audio_dim),
                            jnp.bfloat16)
    return out


def state_struct(model, cfg: ModelConfig, decentralized_k: int = 0):
    """Abstract TrainState: bf16 params; f32 m/v/master; i32 count."""
    lead = (decentralized_k,) if decentralized_k else ()
    specs = model.param_specs()
    p = tree_shapes(specs, cfg.pdtype, extra_leading=lead)
    f = tree_shapes(specs, jnp.float32, extra_leading=lead)
    count = jax.ShapeDtypeStruct(lead, jnp.int32)
    return {"params": p,
            "opt": {"m": f, "v": f, "master": f, "count": count}}


def state_shardings(model, rules, mesh, decentralized_k: int = 0):
    lead = ("dexpert",) if decentralized_k else ()
    ps = tree_shardings(model.param_specs(), rules, mesh,
                        extra_leading_axes=lead)
    scalar = NamedSharding(
        mesh, P(rules["dexpert"]) if decentralized_k else P())
    return {"params": ps,
            "opt": {"m": ps, "v": ps, "master": ps, "count": scalar}}, scalar


def _if_divisible(mesh, axes, dim: int):
    """Return the mesh axes only when they evenly divide the dimension."""
    if axes is None:
        return None
    t = axes if isinstance(axes, tuple) else (axes,)
    ext = int(np.prod([mesh.shape[a] for a in t]))
    return axes if (dim % ext == 0 and dim >= ext) else None


def batch_shardings(rules, mesh, cfg, shapes: Dict, decentralized_k: int = 0):
    lead = (rules["dexpert"],) if decentralized_k else ()
    b = rules["act_batch"]
    out = {}
    for k, v in shapes.items():
        bdim = v.shape[len(lead)]
        trailing = [None] * (len(v.shape) - len(lead) - 1)
        out[k] = NamedSharding(mesh, P(*lead, _if_divisible(mesh, b, bdim),
                                       *trailing))
    return out


OVERRIDES: Dict[str, Any] = {}     # §Perf variants, set by --override
RULE_OVERRIDES: Dict[str, Any] = {}  # sharding-rule variants (--no-fsdp)


def apply_overrides(cfg: ModelConfig) -> ModelConfig:
    return replace(cfg, **OVERRIDES) if OVERRIDES else cfg


def probe_cfg(cfg: ModelConfig, G: int) -> ModelConfig:
    """Depth-G unrolled variant of the config (same widths). Used to fit
    f(G) = outside + G·per_group, correcting XLA cost analysis' once-per-
    while-body counting of scanned stacks."""
    over = {"unroll": True}          # keep the config's remat policy
    if cfg.family == "ssm":
        over["n_layers"] = cfg.ssm.slstm_every * G
    elif cfg.family == "hybrid":
        over["n_layers"] = cfg.ssm.shared_attn_every * G
    else:
        over["n_layers"] = G
    if cfg.family == "audio":
        over["n_enc_layers"] = G
    return replace(cfg, **over)


def build_case(arch: str, shape_name: str, mesh_name: str, mode: str,
               n_experts: int = 2, depth_probe: int = 0):
    """Returns (jitted_fn, example_args) ready to .lower()."""
    shape = INPUT_SHAPES[shape_name]
    multi_pod = mesh_name == "multi"
    decentralized = mode == "decentralized"
    K = n_experts if decentralized else 0
    cfg = apply_overrides(shape_cfg(get_config(arch), shape))
    if depth_probe:
        cfg = probe_cfg(cfg, depth_probe)
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    # §Perf H6: inference has no optimizer state — FSDP weight-sharding over
    # ``data`` only buys per-layer all-gathers (18.7× collective term on
    # qwen3-8b prefill). Serving rules therefore replicate weights over
    # ``data`` (tensor-parallel over ``model`` only); training keeps FSDP
    # (required for 405B-scale optimizer state).
    rule_kw = dict(RULE_OVERRIDES)
    rule_kw.setdefault("fsdp", shape.kind == "train")
    rules = R.logical_rules(multi_pod=multi_pod, decentralized=decentralized,
                            **rule_kw)

    opt = AdamWConfig()
    tc = TrainConfig(opt=opt)

    if shape.kind == "train":
        st_shapes = state_struct(model, cfg, K)
        st_shard, scalar_shard = state_shardings(model, rules, mesh, K)
        b_shapes = batch_shapes(cfg, shape, K)
        b_shard = batch_shardings(rules, mesh, cfg, b_shapes, K)
        fn = (make_decentralized_train_step(model, tc) if decentralized
              else make_train_step(model, tc))
        # metrics subtree: scalar (or per-expert) leaves — prefix sharding
        jfn = jax.jit(fn, in_shardings=(st_shard, b_shard),
                      out_shardings=(st_shard, scalar_shard))
        args = (st_shapes, b_shapes)

    elif shape.kind == "prefill":
        p_shapes = tree_shapes(model.param_specs(), cfg.pdtype)
        p_shard = tree_shardings(model.param_specs(), rules, mesh)
        b_shapes = batch_shapes(cfg, shape)
        b_shard = batch_shardings(rules, mesh, cfg, b_shapes)
        cache_sh = model.cache_shapes(shape.global_batch, shape.seq_len)
        cache_shard = R.cache_pspec_tree(cache_sh, rules, mesh)
        logits_shard = NamedSharding(
            mesh, P(_if_divisible(mesh, rules["act_batch"],
                                  shape.global_batch), None,
                    _if_divisible(mesh, "model", cfg.vocab)))
        fn = lambda p, b: model.prefill(p, b, shape.seq_len)
        jfn = jax.jit(fn, in_shardings=(p_shard, b_shard),
                      out_shardings=(logits_shard, cache_shard))
        args = (p_shapes, b_shapes)

    else:  # decode
        p_shapes = tree_shapes(model.param_specs(), cfg.pdtype)
        p_shard = tree_shardings(model.param_specs(), rules, mesh)
        B = shape.global_batch
        cache_sh = model.cache_shapes(B, shape.seq_len)
        cache_shard = R.cache_pspec_tree(cache_sh, rules, mesh)
        tok_shape = jax.ShapeDtypeStruct((B,), jnp.int32)
        pos_shape = jax.ShapeDtypeStruct((), jnp.int32)
        b_ax = _if_divisible(mesh, rules["act_batch"], B)
        tok_shard = NamedSharding(mesh, P(b_ax))
        pos_shard = NamedSharding(mesh, P())
        logits_shard = NamedSharding(
            mesh, P(b_ax, _if_divisible(mesh, "model", cfg.vocab)))
        fn = lambda p, c, t, pos: model.decode_step(p, c, t, pos)
        # donate the cache: the update is in-place (no fresh HBM allocation
        # + no copy of the untouched slots) — §Perf iteration 3
        jfn = jax.jit(fn, in_shardings=(p_shard, cache_shard, tok_shard,
                                        pos_shard),
                      out_shardings=(logits_shard, cache_shard),
                      donate_argnums=(1,))
        args = (p_shapes, cache_sh, tok_shape, pos_shape)

    return jfn, args, model, cfg, mesh, shape


def run_case(arch: str, shape_name: str, mesh_name: str, mode: str,
             n_experts: int = 2, save_hlo: Optional[str] = None) -> Dict:
    shape = INPUT_SHAPES[shape_name]
    skip = is_skipped(arch, shape)
    case_id = f"{arch}.{shape_name}.{mesh_name}.{mode}"
    if skip:
        return {"case": case_id, "status": "skipped", "reason": skip}

    t0 = time.time()
    jfn, args, model, cfg, mesh, shape = build_case(
        arch, shape_name, mesh_name, mode, n_experts)
    with mesh:
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled)
    hlo = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    csum = collective_summary(hlo, pod_size=256)

    n_dev = int(np.prod(list(mesh.shape.values())))
    total_p = count_params(model.param_specs())
    act_p = active_params(cfg, total_p, model)
    K = n_experts if mode == "decentralized" else 0
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    # decentralized: each expert consumes batch/K → same total tokens; params
    # per device scale by K replicas of the model, but FLOPs per token match.
    mf = model_flops(cfg, act_p, tokens, shape.kind) / n_dev

    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    report = RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, mode=mode,
        flops_per_device=flops, bytes_per_device=bytes_acc,
        collective_bytes=float(csum["total_bytes"]),
        model_flops_per_device=mf).finalize()

    mem_info = {}
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            mem_info[k] = getattr(mem, k, None)

    rec = {
        "case": case_id, "status": "ok",
        "n_devices": n_dev,
        "params_total": total_p, "params_active": act_p,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem_info,
        "cost": {k: cost.get(k) for k in ("flops", "bytes accessed",
                                          "optimal_seconds")
                 if k in cost},
        "collectives": csum,
        "roofline": asdict(report),
    }
    return rec


def run_probe(arch: str, shape_name: str, mesh_name: str, mode: str,
              n_experts: int = 2) -> Dict:
    """Two unrolled shallow compiles (G=1, 2) → per-group + outside costs →
    depth-corrected roofline terms for the FULL config."""
    shape = INPUT_SHAPES[shape_name]
    skip = is_skipped(arch, shape)
    case_id = f"{arch}.{shape_name}.{mesh_name}.{mode}"
    if skip:
        return {"case": case_id, "status": "skipped", "reason": skip}
    meas = {}
    t0 = time.time()
    for G in (1, 2):
        jfn, args, model, cfg, mesh, _ = build_case(
            arch, shape_name, mesh_name, mode, n_experts, depth_probe=G)
        with mesh:
            compiled = jfn.lower(*args).compile()
        cost = _cost_dict(compiled)
        csum = collective_summary(compiled.as_text(), pod_size=256)
        meas[G] = {"flops": float(cost.get("flops", 0.0)),
                   "bytes": float(cost.get("bytes accessed", 0.0)),
                   "coll": float(csum["total_bytes"]),
                   "xpod": float(csum["cross_pod_bytes"])}

    full_model = build_model(shape_cfg(get_config(arch), shape))
    G_full = full_model.n_groups
    mesh_obj = make_production_mesh(multi_pod=mesh_name == "multi")
    n_dev = int(np.prod(list(mesh_obj.shape.values())))

    def fit(key):
        per = meas[2][key] - meas[1][key]
        outside = meas[1][key] - per
        return max(outside, 0.0) + G_full * max(per, 0.0)

    corr = {k: fit(k) for k in ("flops", "bytes", "coll", "xpod")}
    cfg = shape_cfg(get_config(arch), shape)
    total_p = count_params(full_model.param_specs())
    act_p = active_params(cfg, total_p, full_model)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mf = model_flops(cfg, act_p, tokens, shape.kind) / n_dev
    report = RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, mode=mode,
        flops_per_device=corr["flops"], bytes_per_device=corr["bytes"],
        collective_bytes=corr["coll"],
        model_flops_per_device=mf).finalize()
    return {"case": case_id, "status": "ok", "kind": "depth_probe",
            "n_devices": n_dev, "G_full": G_full,
            "measured": meas, "corrected": corr,
            "xpod_corrected": corr["xpod"],
            "wall_s": round(time.time() - t0, 1),
            "roofline": asdict(report)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--mode", choices=["dense", "decentralized"],
                    default="dense")
    ap.add_argument("--experts", type=int, default=2)
    ap.add_argument("--all", action="store_true",
                    help="full 10×4 matrix on the given mesh/mode")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--probe", action="store_true",
                    help="depth-corrected cost probes (2 unrolled shallow "
                         "compiles per case) instead of the full lowering")
    ap.add_argument("--override", default=None,
                    help="JSON dict of ModelConfig field overrides for "
                         "§Perf variants, e.g. '{\"remat\": \"dots\"}'")
    ap.add_argument("--tag", default="",
                    help="suffix for output filenames (perf variants)")
    ap.add_argument("--no-fsdp", action="store_true",
                    help="ZeRO-1 (replicated weights over data axis) "
                         "instead of ZeRO-3 weight sharding")
    args = ap.parse_args()
    if args.override:
        OVERRIDES.update(json.loads(args.override))
    if args.no_fsdp:
        RULE_OVERRIDES["fsdp"] = False

    os.makedirs(args.out, exist_ok=True)
    cases = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in INPUT_SHAPES:
                cases.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cases.append((args.arch, args.shape))

    failures = 0
    for arch, shape in cases:
        cid = f"{arch}.{shape}.{args.mesh}.{args.mode}"
        if args.tag:
            cid += f".{args.tag}"
        if args.probe:
            cid += ".probe"
        out_json = os.path.join(args.out, cid + ".json")
        if args.skip_existing and os.path.exists(out_json):
            try:
                with open(out_json) as f:
                    prev = json.load(f)
                if prev.get("status") in ("ok", "skipped"):
                    print(f"[cached ] {cid}", flush=True)
                    continue
            except Exception:
                pass
        hlo_path = (os.path.join(args.out, cid + ".hlo")
                    if args.save_hlo else None)
        try:
            if args.probe:
                rec = run_probe(arch, shape, args.mesh, args.mode,
                                args.experts)
            else:
                rec = run_case(arch, shape, args.mesh, args.mode,
                               args.experts, save_hlo=hlo_path)
        except Exception as e:
            failures += 1
            rec = {"case": cid, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        with open(out_json, "w") as f:
            json.dump(rec, f, indent=1)
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (f" compile={rec.get('compile_s', rec.get('wall_s'))}s"
                     f" bottleneck={r['bottleneck']}"
                     f" compute={r['compute_s']:.4f}s"
                     f" mem={r['memory_s']:.4f}s"
                     f" coll={r['collective_s']:.4f}s"
                     f" xpod={rec.get('collectives', {}).get('cross_pod_bytes', rec.get('xpod_corrected'))}")
        elif status == "error":
            extra = " " + rec["error"][:200]
        print(f"[{status:7s}] {cid}{extra}", flush=True)
    if failures:
        raise SystemExit(f"{failures} case(s) failed")


if __name__ == "__main__":
    main()
