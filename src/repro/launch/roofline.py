"""Roofline analysis from compiled dry-run artifacts (no real TPU needed).

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

FLOPs/bytes come from ``compiled.cost_analysis()`` (the compiled module is
the per-device SPMD program). Collective bytes are NOT in cost_analysis:
we parse the optimized HLO (``compiled.as_text()``) and sum the result-shape
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute. Replica groups are parsed too (both explicit and iota
form) so we can verify the paper's zero-cross-pod-communication property of
decentralized training directly from the compiled module.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

# TPU v5e hardware constants (targets; this container is CPU-only)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s+(?P<shapes>[^=]*?)\s+(?P<op>all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(?P<start>-start)?\(")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(.*?)\}\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shapes_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _parse_groups(line: str) -> Optional[List[List[int]]]:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        g, n = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(p) for p in m.group(4).split(",")]
            if len(perm) == ids.ndim:       # ignore malformed perms
                ids = ids.transpose(perm)
        return ids.reshape(g, n).tolist()
    m = _GROUPS_LIST_RE.search(line)
    if m:
        inner = m.group(1)
        groups = []
        for grp in re.findall(r"\{([\d,\s]*)\}", "{" + inner + "}}"):
            ids = [int(x) for x in grp.replace(" ", "").split(",") if x]
            if ids:
                groups.append(ids)
        return groups or None
    return None


@dataclass
class CollectiveOp:
    op: str
    bytes: int
    groups: Optional[List[List[int]]]
    crosses_pod: Optional[bool]


def parse_collectives(hlo_text: str, *, pod_size: int = 256
                      ) -> List[CollectiveOp]:
    out = []
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        b = _shape_bytes(m.group("shapes"))
        groups = _parse_groups(line)
        crosses = None
        if groups is not None:
            crosses = any(len({d // pod_size for d in g}) > 1 for g in groups)
        out.append(CollectiveOp(op=m.group("op"), bytes=b, groups=groups,
                                crosses_pod=crosses))
    return out


def collective_summary(hlo_text: str, *, pod_size: int = 256) -> Dict:
    ops = parse_collectives(hlo_text, pod_size=pod_size)
    per_op: Dict[str, int] = {}
    cross_bytes = 0
    for c in ops:
        per_op[c.op] = per_op.get(c.op, 0) + c.bytes
        if c.crosses_pod:
            cross_bytes += c.bytes
    return {
        "n_collectives": len(ops),
        "bytes_per_op": per_op,
        "total_bytes": sum(per_op.values()),
        "cross_pod_bytes": cross_bytes,
        "cross_pod_ops": sum(1 for c in ops if c.crosses_pod),
    }


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    mode: str
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    model_flops_per_device: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_flops_ratio: float = 0.0

    def finalize(self) -> "RooflineReport":
        self.compute_s = self.flops_per_device / PEAK_FLOPS
        self.memory_s = self.bytes_per_device / HBM_BW
        self.collective_s = self.collective_bytes / ICI_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        self.useful_flops_ratio = (
            self.model_flops_per_device / self.flops_per_device
            if self.flops_per_device else 0.0)
        return self


def model_flops(cfg, n_params_active: int, tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (forward-only), N = active
    params (MoE: routed fraction + shared), D = tokens processed."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * tokens


def active_params(cfg, total_params: int, model) -> int:
    """MoE: count routed experts at top_k/n_experts utilization."""
    if cfg.moe.n_experts == 0:
        return total_params
    specs = model.param_specs()
    expert_leaves = 0
    for path, leaf in _iter_specs(specs["blocks"]):
        if "moe" in path and path.split("/")[-1] in ("w_gate", "w_up",
                                                     "w_down"):
            expert_leaves += int(np.prod(leaf.shape))
    dense_part = total_params - expert_leaves
    return int(dense_part +
               expert_leaves * cfg.moe.top_k / cfg.moe.n_experts)


def _iter_specs(tree, prefix=""):
    from repro.models.params import is_spec
    if is_spec(tree):
        yield prefix, tree
        return
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _iter_specs(v, f"{prefix}/{k}")
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            yield from _iter_specs(v, f"{prefix}/{i}")
