"""Serving launcher — decentralized continuous batching (paper §5.2).

Loads the per-expert checkpoints + the centroid router written by
launch/train.py and serves a stream of synthetic multimodal requests through
the ``DecentralizedSlotServer``: the Eq. 28 router runs at the front end on
each request's frozen-encoder features and either dispatches it to its
top-1 expert pod (grouped, compute-matched) or admits it into the stacked-
expert mixture core (one vmapped decode step over all K experts, Eq. 27
mixing fused in). Slots turn over continuously, so short requests never
wait for long ones. Reports routing fidelity and throughput.

    PYTHONPATH=src python -m repro.launch.serve --run /tmp/repro_run \
        --arch qwen3_8b --requests 16 --new-tokens 24 --slots 8

Every serving flag lands in ONE ``EngineConfig`` (validated up front —
bad flag combinations raise a single actionable error) and the engine is
built by ``make_engine``. The drive loop speaks the incremental
``add_request``/``step`` API; ``--stream`` prints each request's token
deltas as they decode, ``--stop-token`` retires requests early with
``finish_reason="stop"``.

``--engine batch`` falls back to the whole-batch ``DecentralizedServer``
(lockstep generation, supports temperature sampling).
"""
from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import ARCH_IDS, get_smoke_config
from repro.core.router import CentroidRouter, RouterConfig
from repro.data.synthetic import SyntheticConfig, SyntheticMultimodal
from repro.models import build_model
from repro.serve.api import EngineConfig, QoSConfig, SamplingParams
from repro.serve.ensemble_engine import DecentralizedServer
from repro.serve.scheduler import make_engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--run", required=True, help="launch.train output dir")
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3_8b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--top-k", type=int, default=1)
    ap.add_argument("--temperature", type=float, default=1.0,
                    help="batch engine only; the slot engine is greedy")
    ap.add_argument("--strategy", choices=["top1", "mixture"],
                    default="top1")
    ap.add_argument("--engine", choices=["slots", "batch"], default="slots")
    ap.add_argument("--slots", type=int, default=8,
                    help="cache slots per pod (slot engine)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: slots hold block tables into a "
                         "shared pool instead of fixed-length cache rows")
    ap.add_argument("--page-block", type=int, default=16,
                    help="positions per KV block (with --paged)")
    ap.add_argument("--pool-blocks", type=int, default=0,
                    help="physical blocks in the pool per pod "
                         "(0 → full capacity: slots × blocks-per-slot + 1)")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="consume prompts in fixed-size chunks written "
                         "through the paged pool, co-scheduled with decode "
                         "steps (no stop-the-world prefill; needs --paged)")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="prompt positions per prefill chunk "
                         "(with --chunked-prefill)")
    ap.add_argument("--token-budget", type=int, default=0,
                    help="per-step token budget: decoding slots count 1 "
                         "each, the chunk counts --prefill-chunk "
                         "(0 → slots + chunk, co-scheduling always fits)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix cache over the paged pool: "
                         "admissions sharing a cached prompt prefix map "
                         "the shared KV blocks read-only and start "
                         "chunked prefill at the first uncached position "
                         "(needs --paged and --chunked-prefill; ssm/"
                         "hybrid fall back to the uncached path)")
    ap.add_argument("--slot-temperature", type=float, default=0.0,
                    help="per-request sampling temperature for the slot "
                         "engine (0 → greedy; sampling is seeded per "
                         "request, deterministic given --seed)")
    ap.add_argument("--slot-top-k", type=int, default=0,
                    help="sample from the k highest-scoring tokens "
                         "(slot engine, 0 → full vocabulary)")
    ap.add_argument("--stop-token", type=int, action="append", default=None,
                    help="stop/eos token id (repeatable): a request retires "
                         "with finish_reason='stop' as soon as it GENERATES "
                         "one (slot engine)")
    ap.add_argument("--stream", action="store_true",
                    help="drive the incremental add_request/step API and "
                         "print per-token deltas as they decode "
                         "(slot engine)")
    ap.add_argument("--preemption", choices=["off", "recompute", "swap"],
                    default="off",
                    help="paged-block preemption: under pool pressure a "
                         "lower-priority decoding request is evicted — "
                         "'recompute' drops its private blocks and replays "
                         "its tokens through chunked prefill at resume "
                         "(needs --chunked-prefill), 'swap' parks their "
                         "contents host-side and scatters them back (needs "
                         "--paged). Resumed output is token-for-token "
                         "identical either way")
    ap.add_argument("--tenant-weight", action="append", default=None,
                    metavar="NAME=W",
                    help="QoS fair-share weight for a tenant (repeatable): "
                         "admission and prefill-chunk bandwidth are split "
                         "across tenants by deficit round robin in "
                         "proportion to these weights (unlisted tenants "
                         "weigh 1.0); FCFS order is kept within a tenant")
    ap.add_argument("--qos-quantum", type=int, default=0,
                    help="DRR credit per round in prompt tokens "
                         "(0 → the prefill chunk size)")
    ap.add_argument("--admit-lookahead", type=int, default=0,
                    help="bounded admission skip-ahead window past an "
                         "unservable queue head (0 → default 8)")
    ap.add_argument("--max-predicted-ttft", type=float, default=0.0,
                    help="SLO admission control: reject a submission "
                         "(finish_reason='rejected') when its predicted "
                         "TTFT from the live token backlog exceeds this "
                         "many seconds (0 → disabled; needs "
                         "--chunked-prefill)")
    ap.add_argument("--max-waiting", type=int, default=0,
                    help="reject submissions once the waiting queue is "
                         "this deep (0 → unbounded)")
    ap.add_argument("--tenants", type=int, default=1,
                    help="assign synthetic requests round-robin to this "
                         "many tenants (tenant-0, tenant-1, …) to exercise "
                         "the QoS fair-share path")
    ap.add_argument("--priorities", type=int, action="append", default=None,
                    help="request priority cycle (repeatable): request i "
                         "gets the i-th value mod the list length — higher "
                         "preempts lower under pool pressure")
    ap.add_argument("--sanitize", action="store_true",
                    help="debug mode: run the PoolSanitizer — a per-step "
                         "ownership scan over the paged block pool "
                         "(aliasing, refcount drift, leaks, use-after-"
                         "free raise immediately; needs --paged)")
    ap.add_argument("--no-fused-step", action="store_true",
                    help="run the legacy host epilogue instead of the fused "
                         "single-dispatch decode step (parity escape hatch; "
                         "slot engine)")
    ap.add_argument("--speculative", choices=["ngram", "expert"],
                    default=None,
                    help="speculative decoding: draft spec_len-1 tokens "
                         "(host n-gram prompt lookup, or the mixture's "
                         "expert 0 on device) and verify the span in one "
                         "dispatch — outputs stay token-for-token "
                         "identical to vanilla decode (needs --paged; "
                         "'expert' needs --strategy mixture)")
    ap.add_argument("--spec-len", type=int, default=4,
                    help="speculative span length L: one committed token "
                         "+ L-1 drafts verified per step (1 = vanilla)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace of the run to PATH "
                         "(slot engine; enables span tracing — load the "
                         "file in ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a JSON metrics snapshot of every pod's "
                         "registry to PATH at exit (slot engine)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="route attention through the Pallas decode kernel")
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    centroids, tau, _ = ckpt.load_router(args.run)
    router = CentroidRouter(jnp.asarray(centroids, jnp.float32),
                            RouterConfig(temperature=tau, top_k=args.top_k))
    cfg = get_smoke_config(args.arch).reduced(vocab=args.vocab)
    model = build_model(cfg)

    experts = []
    k = 0
    while True:
        state, step = ckpt.restore_expert(args.run, k)
        if state is None:
            break
        experts.append(state["params"])
        k += 1
    assert experts, f"no expert checkpoints under {args.run}"
    print(f"loaded {len(experts)} experts (router τ={tau})")

    corpus = SyntheticMultimodal(SyntheticConfig(
        vocab=args.vocab, seq_len=args.prompt_len, seed=args.seed + 7))
    batch_np = corpus.sample_batch(args.requests, step=123)
    cache_len = args.prompt_len + args.new_tokens + 1
    routed = np.asarray(router.top1(jnp.asarray(batch_np["features"])))

    t0 = time.time()
    if args.engine == "slots":
        # every flag lands in ONE validated config — bad combinations
        # raise a single actionable ValueError before any compilation
        qos = None
        if (args.tenant_weight or args.qos_quantum or args.admit_lookahead
                or args.max_predicted_ttft or args.max_waiting):
            weights = tuple(
                (name, float(w)) for name, _, w in
                (s.partition("=") for s in (args.tenant_weight or ())))
            qos = QoSConfig(
                tenant_weights=weights, quantum=args.qos_quantum,
                admit_lookahead=args.admit_lookahead or 8,
                max_predicted_ttft_s=args.max_predicted_ttft,
                max_waiting=args.max_waiting)
        ecfg = EngineConfig(
            n_slots=args.slots, cache_len=cache_len, paged=args.paged,
            page_block=args.page_block, pool_blocks=args.pool_blocks,
            chunked_prefill=args.chunked_prefill, chunk=args.prefill_chunk,
            token_budget=args.token_budget, prefix_cache=args.prefix_cache,
            fused_step=not args.no_fused_step, sanitize=args.sanitize,
            qos=qos, preemption=args.preemption,
            use_kernel=args.use_kernel, strategy=args.strategy,
            speculative=args.speculative, spec_len=args.spec_len,
            trace=args.trace_out is not None,
            metrics=args.metrics_out is not None)
        ecfg.validate(model)
        server = make_engine(model, experts=experts, router=router,
                             config=ecfg)

        def sp(i: int) -> SamplingParams:
            prios = args.priorities or (0,)
            return SamplingParams(
                max_new=args.new_tokens, temperature=args.slot_temperature,
                top_k=args.slot_top_k, seed=args.seed + i,
                stop_token_ids=tuple(args.stop_token or ()),
                priority=prios[i % len(prios)],
                tenant=f"tenant-{i % max(args.tenants, 1)}"
                if args.tenants > 1 else "default")

        for i in range(args.requests):
            server.add_request(batch_np["tokens"][i], sp(i), rid=i,
                               features=batch_np["features"][i])
        finished = {}
        while server.has_unfinished():
            for o in server.step():
                if args.stream and o.deltas:
                    tail = f"  [{o.finish_reason}]" if o.finished else ""
                    print(f"rid={o.rid:3d} +"
                          f"{[d.token for d in o.deltas]}{tail}")
                if o.finished:
                    finished[o.rid] = o.token_ids
        out = {i: finished[i] for i in range(args.requests)}
        n_tok = sum(len(v) for v in out.values())
        if args.trace_out:
            server.export_trace(args.trace_out)
            print(f"trace written to {args.trace_out} "
                  "(load in ui.perfetto.dev)")
        if args.metrics_out:
            server.export_metrics(args.metrics_out)
            print(f"metrics snapshot written to {args.metrics_out}")
    else:
        batch = {
            "tokens": jnp.asarray(batch_np["tokens"]),
            "labels": jnp.asarray(batch_np["labels"]),
            "features": jnp.asarray(batch_np["features"]),
        }
        server = DecentralizedServer(model, experts, router,
                                     cache_len=cache_len,
                                     use_kernel=args.use_kernel)
        gen = (server.generate_top1 if args.strategy == "top1"
               else server.generate_mixture)
        arr = np.asarray(gen(batch, SamplingParams(
            max_new=args.new_tokens, temperature=args.temperature,
            seed=args.seed)))
        out = {i: arr[i].tolist() for i in range(args.requests)}
        n_tok = args.requests * args.new_tokens
    dt = time.time() - t0

    per_expert = np.bincount(routed, minlength=len(experts))
    # routing/latent alignment up to cluster-id permutation (Hungarian)
    from scipy.optimize import linear_sum_assignment
    K, Kl = len(experts), int(batch_np["cluster"].max()) + 1
    conf = np.zeros((K, max(K, Kl)))
    for r, c in zip(routed, batch_np["cluster"]):
        conf[r, c] += 1
    rows, cols = linear_sum_assignment(-conf)
    aligned = conf[rows, cols].sum() / len(routed)
    print(json.dumps({
        "requests": args.requests,
        "new_tokens": args.new_tokens,
        "engine": args.engine,
        "strategy": args.strategy,
        "slots": args.slots if args.engine == "slots" else None,
        "paged": args.paged if args.engine == "slots" else None,
        "chunked_prefill": (args.chunked_prefill
                            if args.engine == "slots" else None),
        "prefix_cache": (args.prefix_cache
                         if args.engine == "slots" else None),
        "pods": server.occupancy() if args.engine == "slots" else None,
        "fused_step": (not args.no_fused_step
                       if args.engine == "slots" else None),
        "use_kernel": args.use_kernel,
        "stream": args.stream if args.engine == "slots" else None,
        "wall_s": round(dt, 2),
        "tok_per_s": round(n_tok / dt, 1),
        "requests_per_expert": per_expert.tolist(),
        "router_latent_alignment": float(aligned),
    }, indent=1))
    for i in range(min(4, args.requests)):
        print(f"req {i} → expert {routed[i]}: "
              f"prompt={batch_np['tokens'][i, :8].tolist()}… "
              f"gen={list(out[i])[:12]}…")


if __name__ == "__main__":
    main()
