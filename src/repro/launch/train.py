"""Training launcher — the full decentralized pipeline of paper §5.1:

1. extract frozen-encoder features for every unique sample (stub frontend);
2. balanced spherical k-means → K disjoint shards + centroid router;
3. train K experts fully independently (per-expert data, optimizer,
   checkpoints — zero communication), or the dense baseline on everything;
4. save per-expert checkpoints + the router.

On this CPU container it runs the reduced (smoke) configs against the
synthetic clustered corpus end-to-end; on a TPU cluster the same entrypoint
drives the production mesh (each expert maps to one pod — see
sharding/rules.py and the dry-run).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3_8b \
        --mode decentralized --experts 2 --steps 200 --out /tmp/run
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.checkpoint import ckpt
from repro.configs.base import ARCH_IDS, get_smoke_config
from repro.data.partition import partition_dataset
from repro.data.pipeline import LoaderConfig, ShardLoader, expert_loaders
from repro.data.synthetic import SyntheticConfig, SyntheticMultimodal
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import (TrainConfig, init_train_state,
                                 train_host_loop)


def build_corpus(args) -> SyntheticMultimodal:
    return SyntheticMultimodal(SyntheticConfig(
        vocab=args.vocab, seq_len=args.seq_len, n_latent=args.latent,
        n_samples=args.samples, feature_dim=args.feature_dim,
        seed=args.seed))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3_8b")
    ap.add_argument("--mode", choices=["dense", "decentralized"],
                    default="decentralized")
    ap.add_argument("--experts", type=int, default=2)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16,
                    help="dense global batch; experts use batch/K (paper "
                         "§6.1 compute matching)")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--latent", type=int, default=4)
    ap.add_argument("--samples", type=int, default=2048)
    ap.add_argument("--feature-dim", type=int, default=32)
    ap.add_argument("--clustering", choices=["balanced", "two_stage"],
                    default="balanced")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="/tmp/repro_run")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).reduced(vocab=args.vocab)
    model = build_model(cfg)
    corpus = build_corpus(args)
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                      total_steps=args.steps)
    tc = TrainConfig(opt=opt)
    os.makedirs(args.out, exist_ok=True)

    if args.mode == "dense":
        loader = ShardLoader(corpus, LoaderConfig(batch_size=args.batch))
        state = init_train_state(model, jax.random.PRNGKey(args.seed), opt)
        t0 = time.time()
        state, hist = train_host_loop(
            model, state, loader, args.steps, tc,
            callback=lambda s, m: print(f"dense step {s}: {m}", flush=True))
        ckpt.save_expert(args.out, 0, args.steps, state)
        print(f"dense done in {time.time()-t0:.1f}s; "
              f"final loss {hist[-1]['loss']:.4f}")
        return

    # ---- decentralized: partition → independent experts -----------------
    feats = corpus.all_features()
    part = partition_dataset(feats, args.experts,
                             algorithm=args.clustering, seed=args.seed)
    sizes = [len(s) for s in part.shards]
    print(f"partitioned {len(feats)} samples into {sizes} "
          f"(balanced k-means, {part.clustering.n_iter} iters)")
    ckpt.save_router(args.out, part.clustering.centroids,
                     part.router.config.temperature,
                     part.router.config.top_k)

    per_expert_batch = max(args.batch // args.experts, 1)
    loaders = expert_loaders(corpus, part.shards, per_expert_batch)
    summary = []
    for k in range(args.experts):
        # each expert: its own seed, its own data, its own optimizer — and
        # NO communication with the others (train them on separate nodes in
        # production; sequentially here).
        state = init_train_state(model,
                                 jax.random.PRNGKey(args.seed + 100 + k), opt)
        t0 = time.time()
        state, hist = train_host_loop(
            model, state, loaders[k], args.steps, tc,
            callback=lambda s, m, k=k: print(f"expert {k} step {s}: {m}",
                                             flush=True))
        path = ckpt.save_expert(args.out, k, args.steps, state)
        summary.append({"expert": k, "shard_size": sizes[k],
                        "final_loss": hist[-1]["loss"],
                        "wall_s": round(time.time() - t0, 1),
                        "checkpoint": path})
        print(f"expert {k} done: {summary[-1]}", flush=True)

    with open(os.path.join(args.out, "train_summary.json"), "w") as f:
        json.dump({"args": vars(args), "experts": summary}, f, indent=1)
    print("decentralized training complete →", args.out)


if __name__ == "__main__":
    main()
