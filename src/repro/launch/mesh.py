"""Production meshes.

Single pod : v5e-256 as (16, 16) over ("data", "model").
Multi-pod  : 2 pods = 512 chips as (2, 16, 16) over ("pod", "data", "model").

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init; everything else
sees the single real CPU device).
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — the "
            "dry-run entrypoint must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import (see launch/dryrun.py)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def pod_of_device(device_id: int, *, multi_pod: bool) -> int:
    """Device-id → pod index under the mesh layouts above (pod-major)."""
    return device_id // 256 if multi_pod else 0
