"""The parameter-free centroid router (paper §5.1–5.2, Eq. 28).

Routing weight for expert k given an input with (frozen-encoder) feature x:

    p(S_k | x) = softmax_k( τ · cos(x, c_k) )

followed by top-k filtering + renormalization (k = 1 in the paper's main
experiments, making ensemble inference compute-matched with the dense
baseline). Routing is time-independent and agnostic of the token state —
exactly Eq. 28.

The fused normalize→matmul→softmax→top-k computation has a Pallas TPU kernel
(repro/kernels/router_scores.py); this module is the public JAX API and
falls back to pure jnp when the kernel is disabled.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .clustering import l2_normalize
from .decentralize import topk_filter_renorm

Array = jnp.ndarray


@dataclass(frozen=True)
class RouterConfig:
    temperature: float = 10.0
    top_k: int = 1
    use_kernel: bool = False   # route through the Pallas kernel


@dataclass
class CentroidRouter:
    """Holds the K unit-norm centroids from balanced spherical k-means."""

    centroids: Array           # (K, D)
    config: RouterConfig = field(default_factory=RouterConfig)

    @property
    def K(self) -> int:
        return self.centroids.shape[0]

    def cluster_probs(self, features: Array) -> Array:
        """Eq. 28. features: (..., D) → (..., K)."""
        if self.config.use_kernel:
            from repro.kernels import ops as kops
            flat = features.reshape(-1, features.shape[-1])
            out = kops.router_scores(flat, self.centroids,
                                     self.config.temperature)
            return out.reshape(features.shape[:-1] + (self.K,))
        x = l2_normalize(features)
        c = l2_normalize(self.centroids)
        sims = x @ c.T
        return jax.nn.softmax(self.config.temperature * sims, axis=-1)

    def route(self, features: Array) -> Array:
        """Top-k filtered + renormalized weights: (..., K)."""
        probs = self.cluster_probs(features)
        moved = jnp.moveaxis(probs, -1, 0)             # (K, ...)
        filtered = topk_filter_renorm(moved, self.config.top_k)
        return jnp.moveaxis(filtered, 0, -1)

    def top1(self, features: Array) -> Array:
        """Hard assignment (training-time partitioning mirror)."""
        return jnp.argmax(self.cluster_probs(features), axis=-1)


def router_from_clustering(centroids: np.ndarray,
                           config: Optional[RouterConfig] = None) -> CentroidRouter:
    """Build the router directly from k-means output — zero extra trainable
    parameters, 'perfectly mirrors the initial data distribution strategy'."""
    return CentroidRouter(centroids=jnp.asarray(centroids, dtype=jnp.float32),
                          config=config or RouterConfig())
