"""Expert-ensemble inference (paper §5.2).

At each decode step the global generating velocity is the router-weighted
sum of expert velocities (Eq. 27). Because every expert velocity is affine
in its next-token conditional (u_k = c_k − δ_mask) and the router weights
sum to one, mixing velocities is *identical* to mixing the experts'
next-token probability distributions:

    p_mix(a | prefix) = Σ_k r_k(features) · softmax(logits_k)[a]

with r the top-k-filtered Eq. 28 router. With top-1 routing this degenerates
to "run only the selected expert" — the compute-matched setting of the
paper's main tables; the engine exploits that by gathering the single
selected expert's parameters instead of running all K.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .decentralize import mix_expert_distributions
from .router import CentroidRouter

Array = jnp.ndarray

# Floor applied before taking logs of mixture probabilities — shared by every
# consumer (engine sampling, eval NLL) so the clamp is identical everywhere.
PROB_FLOOR = 1e-30


def mix_expert_logits(expert_logits: Array, weights: Array,
                      *, log_space: bool = False) -> Array:
    """Combine expert next-token logits into ensemble probabilities.

    expert_logits: (K, ..., V); weights: (..., K) (already top-k filtered,
    rows summing to 1). Returns probabilities (..., V) — the exact Eq. 27
    recomposition (probability space, not logit averaging).
    """
    probs = jax.nn.softmax(expert_logits, axis=-1)          # (K, ..., V)
    w = jnp.moveaxis(weights, -1, 0)                        # (K, ...)
    mixed = mix_expert_distributions(probs, w)
    if log_space:
        return jnp.log(jnp.maximum(mixed, PROB_FLOOR))
    return mixed


@dataclass
class EnsembleSpec:
    """Static description of a decentralized ensemble."""

    n_experts: int
    top_k: int = 1
    temperature: float = 10.0


def ensemble_next_token_probs(router: CentroidRouter, features: Array,
                              expert_logits: Array) -> Array:
    """features: (B, D) routing features for each request; expert_logits:
    (K, B, V) per-expert next-token logits → (B, V) mixed probabilities."""
    weights = router.route(features)                        # (B, K)
    return mix_expert_logits(expert_logits, weights)


def stack_expert_params(expert_params):
    """K per-expert parameter pytrees → one pytree with a leading K dim on
    every leaf — the serving twin of ``trainer.stack_expert_states``. The
    leading dim is the ``dexpert`` axis that shards over the ``pod`` mesh
    axis (sharding/rules.py), so a vmapped decode over it is one sharded op
    with zero cross-pod traffic."""
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *expert_params)


def stack_experts_for_decode(expert_params):
    """Stack experts in the DECODE layout: scanned layer stacks (the
    ``blocks`` subtrees) carry the K dim at axis 1, *after* the scanned
    layer dim; everything else leads with K.

    ``decode_step``/``prefill`` consume layer stacks with ``lax.scan``,
    which requires the scan axis first — vmapping over a leading K would
    make XLA transpose every parameter (and cache) leaf to (L, K, …) on
    EVERY step. Pre-storing the scanned stacks layer-major makes the
    vmapped step transpose-free (~1.4× decode steps/sec at K=4 on CPU).
    The K dim still shards over ``pod`` regardless of its position.

    Returns ``(stacked, in_axes)`` where ``in_axes`` is the per-leaf vmap
    axis tree to pass to ``jax.vmap``.
    """
    stacked = stack_expert_params(expert_params)
    axes = jax.tree.map(lambda _: 0, stacked)

    def layer_major(sub):
        return (jax.tree.map(lambda a: jnp.moveaxis(a, 0, 1), sub),
                jax.tree.map(lambda _: 1, sub))

    if isinstance(stacked, dict) and "blocks" in stacked:
        stacked, axes = dict(stacked), dict(axes)
        stacked["blocks"], axes["blocks"] = layer_major(stacked["blocks"])
        if "encoder" in stacked:          # audio enc-dec: encoder stack too
            enc, eaxes = dict(stacked["encoder"]), dict(axes["encoder"])
            enc["blocks"], eaxes["blocks"] = layer_major(enc["blocks"])
            stacked["encoder"], axes["encoder"] = enc, eaxes
    return stacked, axes


def stacked_cache_axes(cache_like):
    """vmap axis tree for a stacked decode cache: every cache leaf carries
    its scan (layer/group) dim first, so the expert dim lives at axis 1."""
    return jax.tree.map(lambda _: 1, cache_like)


def make_stacked_serving(model, expert_params, cache_len: int, *,
                         use_kernel: bool = False, paged: bool = False):
    """Build the stacked-expert decode core shared by every mixture server
    (``DecentralizedServer``, ``MixtureSlotServer``, serve_bench): experts
    stacked in the decode layout plus jitted whole-ensemble steps.

    Returns ``(stacked, param_axes, prefill_all, mix_decode)`` where

    * ``prefill_all(stacked, batch)`` → ``(logits (K, B, S, V), caches)``
    * ``mix_decode(stacked, caches, tok, pos, weights)`` →
      ``(Eq. 27 mixed probabilities (B, V), new caches)`` — ONE vmapped
      ``decode_step`` over the K dim with the mixing fused into the jit.

    With ``paged`` the caches are the block-pool layout (pool leaves carry
    the K dim at axis 1, exactly like the direct leaves) and ``mix_decode``
    takes the per-slot block tables as a trailing argument, shared across
    all K experts (``in_axes=None`` under the vmap).
    """
    stacked, param_axes = stack_experts_for_decode(expert_params)
    # axis tree only depends on the cache STRUCTURE (paged and contiguous
    # caches share it): every leaf carries K at axis 1, after its scan dim
    cache_axes = stacked_cache_axes(model.cache_shapes(1, cache_len))

    def prefill_all(stacked_p, batch):
        return jax.vmap(
            lambda p: model.prefill(p, batch, cache_len,
                                    use_kernel=use_kernel),
            in_axes=(param_axes,), out_axes=(0, cache_axes))(stacked_p)

    if paged:
        def mix_decode(stacked_p, caches, tok, pos, weights, block_tables):
            logits, caches = jax.vmap(
                lambda p, c: model.decode_step_paged(
                    p, c, tok, pos, block_tables, use_kernel=use_kernel),
                in_axes=(param_axes, cache_axes),
                out_axes=(0, cache_axes))(stacked_p, caches)  # (K, B, V)
            return mix_expert_logits(logits, weights), caches
    else:
        def mix_decode(stacked_p, caches, tok, pos, weights):
            logits, caches = jax.vmap(
                lambda p, c: model.decode_step(p, c, tok, pos,
                                               use_kernel=use_kernel),
                in_axes=(param_axes, cache_axes),
                out_axes=(0, cache_axes))(stacked_p, caches)  # (K, B, V)
            return mix_expert_logits(logits, weights), caches

    return stacked, param_axes, jax.jit(prefill_all), jax.jit(mix_decode)


def make_stacked_chunk_fns(model, stacked, param_axes, cache_len: int,
                           chunk: int, *, use_kernel: bool = False):
    """Chunked-prefill companions to ``make_stacked_serving`` for the
    stacked-expert mixture core.

    Returns ``(prep_all, chunk_all)``:

    * ``prep_all(stacked, batch)`` → (embedded prompt (K, 1, W, D) — every
      expert owns its embedding table; admission slices off any cached
      prefix and pre-splits the suffix into per-chunk tensors, keeping the
      chunk step dispatch-free — per-expert chunk carries with the K dim
      at axis 1 of every leaf, the same slot the stacked cache keeps it
      in, so ``CacheSpec.shifted(1).insert_direct`` splices the finished
      carry without a transpose);
    * ``chunk_all(stacked, caches, carry, xc, start, length, block_table,
      weights)`` → (Eq. 27 mixed next-token probs (1, V) at the chunk's
      last valid position, new carry, new caches) — ONE vmapped
      ``prefill_chunk`` over the K dim; the block table is shared by all K
      experts (``in_axes=None``), exactly like the paged decode path.

    ``chunk_all`` is returned un-jitted so the mixture server can fuse it
    with the decode step into a single dispatch; ``prep_all`` is jitted
    (it runs once per admission, retracing per padded prompt width).
    """
    cache_axes = stacked_cache_axes(model.cache_shapes(1, cache_len))

    def prep_all(stacked_p, batch):
        x = jax.vmap(lambda p: model.embed_prompt(p, batch),
                     in_axes=(param_axes,))(stacked_p)     # (K, 1, W, D)
        carry = jax.vmap(
            lambda p: model.init_chunk_carry(p, batch, cache_len),
            in_axes=(param_axes,), out_axes=1)(stacked_p)
        return x, carry

    def chunk_all(stacked_p, caches, carry, xc, start, length, block_table,
                  weights):
        logits, carry, caches = jax.vmap(
            lambda p, c, cr, x: model.prefill_chunk(
                p, c, cr, x, start, length, block_table,
                use_kernel=use_kernel),
            in_axes=(param_axes, cache_axes, 1, 0),
            out_axes=(0, 1, cache_axes))(stacked_p, caches, carry, xc)
        return mix_expert_logits(logits, weights), carry, caches

    return jax.jit(prep_all), chunk_all


def make_stacked_fused(model, param_axes, cache_len: int, *,
                       chunk_all=None, use_kernel: bool = False,
                       paged: bool = False):
    """Fused-step companions to ``make_stacked_serving``: the vmapped
    Eq. 27 mixture decode PLUS the serving epilogue (seeded sampling, stop
    ids, budget/context checks, position advance — ``from_probs``: the
    mixed scores are probabilities) in one jitted dispatch, so a mixture
    decode token costs a single kernel launch like the single-model path.

    Returns ``(step, step_chunk, chunk_only)``:

    * ``step(stacked, caches, state)`` → ``(caches, state, next_tok,
      done)`` — ``state`` is the scheduler's per-slot device-state dict
      (``state["weights"]`` carries the (n_slots, K) router weights,
      ``state["tables"]`` the block tables when paged);
    * ``step_chunk(stacked, caches, state, carry, xc, start, length, cbt,
      w_row, temp, top_k, seed)`` → additionally consumes one prefill
      chunk and returns its (fused, device-side) first-token pick;
    * ``chunk_only(...)`` — the chunk + pick without a decode.

    ``step_chunk``/``chunk_only`` are None without ``chunk_all`` (pass the
    un-jitted chunk fn from ``make_stacked_chunk_fns``).
    """
    # function-level import: serve.fused imports PROB_FLOOR from here
    from repro.serve.fused import decode_epilogue, pick_first
    cache_axes = stacked_cache_axes(model.cache_shapes(1, cache_len))

    if paged:
        def mix(stacked_p, caches, st):
            logits, caches = jax.vmap(
                lambda p, c: model.decode_step_paged(
                    p, c, st["tok"], st["pos"], st["tables"],
                    use_kernel=use_kernel),
                in_axes=(param_axes, cache_axes),
                out_axes=(0, cache_axes))(stacked_p, caches)
            return mix_expert_logits(logits, st["weights"]), caches
    else:
        def mix(stacked_p, caches, st):
            logits, caches = jax.vmap(
                lambda p, c: model.decode_step(p, c, st["tok"], st["pos"],
                                               use_kernel=use_kernel),
                in_axes=(param_axes, cache_axes),
                out_axes=(0, cache_axes))(stacked_p, caches)
            return mix_expert_logits(logits, st["weights"]), caches

    def step(stacked_p, caches, st):
        probs, caches = mix(stacked_p, caches, st)
        st, nxt, done = decode_epilogue(probs, st, cache_len=cache_len,
                                        from_probs=True)
        return caches, st, nxt, done

    if chunk_all is None:
        return jax.jit(step), None, None

    def step_chunk(stacked_p, caches, st, carry, xc, start, length, cbt,
                   w_row, temp, top_k, seed):
        probs, caches = mix(stacked_p, caches, st)
        c_probs, carry, caches = chunk_all(stacked_p, caches, carry, xc,
                                           start, length, cbt, w_row)
        st, nxt, done = decode_epilogue(probs, st, cache_len=cache_len,
                                        from_probs=True)
        first = pick_first(c_probs, temp, top_k, seed, from_probs=True)
        return caches, st, nxt, done, first, carry

    def chunk_only(stacked_p, caches, carry, xc, start, length, cbt,
                   w_row, temp, top_k, seed):
        c_probs, carry, caches = chunk_all(stacked_p, caches, carry, xc,
                                           start, length, cbt, w_row)
        first = pick_first(c_probs, temp, top_k, seed, from_probs=True)
        return first, carry, caches

    return jax.jit(step), jax.jit(step_chunk), jax.jit(chunk_only)


def make_stacked_verify(model, param_axes, cache_len: int, spec_len: int, *,
                        use_kernel: bool = False, expert_draft: bool = True):
    """Speculative verify step for the stacked mixture core: score all
    ``spec_len`` candidate positions with the Eq. 27 mixture and accept
    the longest prefix matching the vanilla trajectory — one jitted
    dispatch, same contract as ``Model.fused_verify_step``
    (``state["weights"]`` carries the router weights, as in
    ``make_stacked_fused``).

    With ``expert_draft=True`` the drafts are SELF-generated on device:
    the draft model is the stacked params at expert index 0, sliced
    axes-aware inside the jit (a gather, free under XLA). Its KV trail is
    equally free: every expert writes its own cache slice during mixture
    decode/verify, so the expert-0 slice of the SHARED caches already
    holds expert-0's keys for every committed position — no separate
    draft cache to maintain, no catch-up forward. The draft loop runs
    ``spec_len - 1`` sequential greedy expert-0 ``decode_step_paged``
    micro-steps on a locally-threaded copy of that slice, then DISCARDS
    it: the vmapped verify re-scatters all K experts' K/V at every span
    position, so the draft's tentative writes never touch the real pool.
    Returns a jitted ``verify(stacked, caches, state)`` →
    ``(caches, state, toks, n_emit, done)``.

    With ``expert_draft=False`` the drafts arrive as an argument (the
    scheduler's host-side n-gram proposer):
    ``verify(stacked, caches, state, drafts)`` with the same outputs.
    """
    # function-level import: serve.fused imports PROB_FLOOR from here
    from repro.serve.fused import verify_epilogue
    cache_axes = stacked_cache_axes(model.cache_shapes(1, cache_len))

    def verify_core(stacked_p, caches, st, drafts):
        tokens = jnp.concatenate([st["tok"][:, None], drafts], axis=1)
        logits, caches = jax.vmap(
            lambda p, c: model.verify_step_paged(
                p, c, tokens, st["pos"], st["tables"],
                use_kernel=use_kernel),
            in_axes=(param_axes, cache_axes),
            out_axes=(0, cache_axes))(stacked_p, caches)  # (K, B, L, V)
        probs = mix_expert_logits(logits, st["weights"][:, None, :])
        st, toks, n_emit, done = verify_epilogue(
            probs, drafts, st, cache_len=cache_len, from_probs=True)
        return caches, st, toks, n_emit, done

    if not expert_draft:
        return jax.jit(verify_core)

    def verify(stacked_p, caches, st):
        draft_p = jax.tree.map(lambda leaf, ax: jnp.take(leaf, 0, axis=ax),
                               stacked_p, param_axes)
        draft_c = jax.tree.map(lambda leaf, ax: jnp.take(leaf, 0, axis=ax),
                               caches, cache_axes)
        tok = st["tok"]
        drafts = []
        for j in range(spec_len - 1):
            logits, draft_c = model.decode_step_paged(
                draft_p, draft_c, tok, st["pos"] + j, st["tables"],
                use_kernel=use_kernel)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            drafts.append(tok)
        drafts = jnp.stack(drafts, axis=1)               # (B, L-1)
        return verify_core(stacked_p, caches, st, drafts)

    return jax.jit(verify)


def select_expert_params(stacked_params, expert_idx: Array):
    """Top-1 fast path: gather one expert's parameter slice out of a pytree
    whose leaves carry a leading K dim. With the expert axis sharded over the
    ``pod`` mesh axis this lowers to a cross-pod gather of exactly one
    expert — the serving analogue of zero-communication training."""
    return jax.tree.map(lambda leaf: jnp.take(leaf, expert_idx, axis=0),
                        stacked_params)
