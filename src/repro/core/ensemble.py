"""Expert-ensemble inference (paper §5.2).

At each decode step the global generating velocity is the router-weighted
sum of expert velocities (Eq. 27). Because every expert velocity is affine
in its next-token conditional (u_k = c_k − δ_mask) and the router weights
sum to one, mixing velocities is *identical* to mixing the experts'
next-token probability distributions:

    p_mix(a | prefix) = Σ_k r_k(features) · softmax(logits_k)[a]

with r the top-k-filtered Eq. 28 router. With top-1 routing this degenerates
to "run only the selected expert" — the compute-matched setting of the
paper's main tables; the engine exploits that by gathering the single
selected expert's parameters instead of running all K.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .decentralize import mix_expert_distributions
from .router import CentroidRouter

Array = jnp.ndarray


def mix_expert_logits(expert_logits: Array, weights: Array,
                      *, log_space: bool = False) -> Array:
    """Combine expert next-token logits into ensemble probabilities.

    expert_logits: (K, ..., V); weights: (..., K) (already top-k filtered,
    rows summing to 1). Returns probabilities (..., V) — the exact Eq. 27
    recomposition (probability space, not logit averaging).
    """
    probs = jax.nn.softmax(expert_logits, axis=-1)          # (K, ..., V)
    w = jnp.moveaxis(weights, -1, 0)                        # (K, ...)
    mixed = mix_expert_distributions(probs, w)
    if log_space:
        return jnp.log(jnp.maximum(mixed, 1e-30))
    return mixed


@dataclass
class EnsembleSpec:
    """Static description of a decentralized ensemble."""

    n_experts: int
    top_k: int = 1
    temperature: float = 10.0


def ensemble_next_token_probs(router: CentroidRouter, features: Array,
                              expert_logits: Array) -> Array:
    """features: (B, D) routing features for each request; expert_logits:
    (K, B, V) per-expert next-token logits → (B, V) mixed probabilities."""
    weights = router.route(features)                        # (B, K)
    return mix_expert_logits(expert_logits, weights)


def select_expert_params(stacked_params, expert_idx: Array):
    """Top-1 fast path: gather one expert's parameter slice out of a pytree
    whose leaves carry a leading K dim. With the expert axis sharded over the
    ``pod`` mesh axis this lowers to a cross-pod gather of exactly one
    expert — the serving analogue of zero-communication training."""
    return jax.tree.map(lambda leaf: jnp.take(leaf, expert_idx, axis=0),
                        stacked_params)
