"""Discrete Flow Matching in *discrete time* (paper §3–4.1).

This module is the exact, enumerable form of the theory: everything lives on
the finite state space ``[d]^N`` (vocab size ``d``, sequence length ``N``),
so probability paths, velocities, divergences and the Continuity Equation can
be evaluated *exactly* and machine-checked. The production system (models/,
train/, serve/) realises the same objects at scale, where ``p_t`` is only
accessible through a neural network; this module is the ground truth the
tests and the decentralization theorem are verified against.

Conventions
-----------
* States ``x ∈ [d]^N`` are encoded as integers in ``[0, d**N)`` (base-``d``,
  position 0 = most significant digit). ``enumerate_states`` gives the
  decoded table.
* A distribution over states is a vector ``p`` of shape ``(d**N,)``.
* A coupling ``π(x0, x1)`` is a matrix of shape ``(d**N, d**N)``.
* A probability generating velocity is an array ``u`` of shape
  ``(N, d, d**N)`` with ``u[i, a, z] = u_t^i(a, z)`` — the rate of moving
  position ``i`` of current state ``z`` to token value ``a``.

All math is done in float64 (enable ``jax_enable_x64``) so the theorem
checks are exact to machine precision.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# State-space enumeration
# ---------------------------------------------------------------------------

def n_states(d: int, N: int) -> int:
    return d**N


def enumerate_states(d: int, N: int) -> np.ndarray:
    """All sequences in ``[d]^N`` as an ``(d**N, N)`` int array (base-d order)."""
    return np.array(list(itertools.product(range(d), repeat=N)), dtype=np.int32)


def encode(seqs: np.ndarray, d: int) -> np.ndarray:
    """Map ``(..., N)`` token sequences to state indices."""
    N = seqs.shape[-1]
    weights = d ** np.arange(N - 1, -1, -1)
    return (seqs * weights).sum(-1)


def decode(idx: np.ndarray, d: int, N: int) -> np.ndarray:
    """Map state indices to ``(..., N)`` token sequences."""
    idx = np.asarray(idx)
    out = np.zeros(idx.shape + (N,), dtype=np.int32)
    rem = idx.copy()
    for i in range(N - 1, -1, -1):
        out[..., i] = rem % d
        rem = rem // d
    return out


def neighbor_table(d: int, N: int) -> np.ndarray:
    """``nbr[z, i, a]`` = index of the state equal to ``z`` except position
    ``i`` holds token ``a``. Shape ``(d**N, N, d)``. The Hamming-1 structure
    underlying the discrete divergence (Eq. 11–12)."""
    states = enumerate_states(d, N)  # (S, N)
    S = states.shape[0]
    nbr = np.zeros((S, N, d), dtype=np.int64)
    weights = d ** np.arange(N - 1, -1, -1)
    base = encode(states, d)
    for i in range(N):
        # zero out position i then add each candidate token
        stripped = base - states[:, i] * weights[i]
        for a in range(d):
            nbr[:, i, a] = stripped + a * weights[i]
    return nbr


# ---------------------------------------------------------------------------
# Probability paths (Eq. 1–6)
# ---------------------------------------------------------------------------

@dataclass
class FactorizedPath:
    """A conditional-marginal probability path ``p_t(x | x0, x1)`` given as a
    per-position factorized table, plus the coupling π.

    ``cond[t]`` has shape ``(S0, S1, N, d)`` with
    ``cond[t][x0, x1, i, a] = p_t(x^i = a | x0, x1)``.
    """

    d: int
    N: int
    pi: Array                      # (S, S) coupling π(x0, x1)
    cond: list                     # list over t of (S, S, N, d)

    @property
    def T(self) -> int:
        return len(self.cond) - 1

    def conditional_joint(self, t: int) -> Array:
        """``p_t(x | x0, x1)`` over full states: shape (S, S, S)."""
        S = n_states(self.d, self.N)
        states = enumerate_states(self.d, self.N)  # (S, N)
        c = self.cond[t]  # (S, S, N, d)
        # prod_i c[x0, x1, i, states[x, i]]
        out = jnp.ones((S, S, S), dtype=c.dtype)
        for i in range(self.N):
            out = out * c[:, :, i, states[:, i]][:, :, :]
        return out

    def marginal(self, t: int) -> Array:
        """``p_t(x)`` via Eq. 1: marginalize the coupling."""
        joint = self.conditional_joint(t)  # (S0, S1, S)
        return jnp.einsum("abx,ab->x", joint, self.pi)


def mixture_path(d: int, N: int, pi: Array, schedulers: Array,
                 w: Array) -> FactorizedPath:
    """Build the convex-sum path of Eq. 5–6.

    schedulers: (T+1, N, J) with ``schedulers[t, i, j] = κ_t^{i,j}``,
    rows summing to 1 over j.
    w: (J, S0, S1, N, d) basis conditionals ``w^j(x^i | x0, x1)``.
    """
    cond = []
    for t in range(schedulers.shape[0]):
        # (S0,S1,N,d) = sum_j κ[t,i,j] * w[j,:,:,i,:]
        c = jnp.einsum("ij,jabid->abid", schedulers[t], w)
        cond.append(c)
    return FactorizedPath(d=d, N=N, pi=pi, cond=cond)


# ---------------------------------------------------------------------------
# Velocities, divergence, Continuity Equation (Eq. 9–17)
# ---------------------------------------------------------------------------

def velocity_is_valid(u: Array, p: Array, atol: float = 1e-9) -> bool:
    """Check Eq. 15–16 on the support of ``p``: columns sum to zero; the
    diagonal entry (staying) lies in [-1, 0]; off-entries in [0, 1]."""
    N, d, S = u.shape
    states = enumerate_states(d, N)
    col = jnp.abs(u.sum(axis=1)).max()
    if col > atol:
        return False
    support = np.asarray(p) > atol
    for i in range(N):
        diag = np.asarray(u[i, states[:, i], np.arange(S)])
        off = np.asarray(u[i]).copy()
        off[states[:, i], np.arange(S)] = 0.0
        if ((diag[support] < -1 - atol).any() or (diag[support] > atol).any()
                or (off[:, support] < -atol).any()
                or (off[:, support] > 1 + atol).any()):
            return False
    return True


def divergence(p: Array, u: Array, nbr: np.ndarray) -> Array:
    """Discrete divergence ``div_x(p_t u_t)`` of Eq. 12.

    div_x = - Σ_z p(z) Σ_i δ_z(x^ī) u^i(x^i, z).  For fixed i, the states z
    with δ_z(x^ī)=1 are exactly the Hamming-1 neighbours of x at position i
    (including z = x itself), i.e. z = nbr[x, i, b] for b ∈ [d].
    """
    N, d, S = u.shape
    div = jnp.zeros((S,), dtype=p.dtype)
    states = enumerate_states(d, N)
    for i in range(N):
        zs = nbr[:, i, :]                    # (S, d): neighbour indices of x at pos i
        pz = p[zs]                           # (S, d)
        a_of_x = states[:, i]                # token of x at position i
        u_vals = u[i, a_of_x[:, None], zs]   # (S, d): u^i(x^i, z)
        div = div - (pz * u_vals).sum(axis=1)
    return div


def continuity_residual(p_t: Array, p_next: Array, u: Array,
                        nbr: np.ndarray) -> Array:
    """Eq. 17 residual: ``p_{t+1}(x) − p_t(x) + div_x(p_t u_t)`` (0 ⇔ holds)."""
    return p_next - p_t + divergence(p_t, u, nbr)


def is_one_sparse(u: Array, p: Array, atol: float = 1e-12) -> bool:
    """Paper §4.2: at this timestep, u^i ≡ 0 (off-diagonal) for all but at most
    one position i — *uniformly in z on the support of p* (j = j(t) may depend
    only on t)."""
    N, d, S = u.shape
    states = enumerate_states(d, N)
    support = np.asarray(p) > atol
    active = []
    for i in range(N):
        off = np.asarray(u[i]).copy()
        off[states[:, i], np.arange(S)] = 0.0   # remove diagonal (stay) term
        if np.abs(off[:, support]).max() > atol:
            active.append(i)
    return len(active) <= 1


def apply_sampling_rule(p: Array, u: Array, nbr: np.ndarray) -> Array:
    """Exact pushforward of the discrete sampling rule Eq. 13:

    ``X_{t+1}^i ~ δ_{X_t^i}(·) + u^i(·, X_t)`` independently per position.
    Returns the pmf of ``X_{t+1}``: Σ_z p(z) Π_i (δ_z(x^i) + u^i(x^i, z)).
    """
    N, d, S = u.shape
    states = enumerate_states(d, N)
    out = jnp.zeros((S,), dtype=p.dtype)
    # per-position transition kernel K_i[z, a] = δ(a = z^i) + u[i, a, z]
    kernels = []
    for i in range(N):
        K = jnp.asarray(u[i]).T  # (S_z, d)
        K = K.at[jnp.arange(S), states[:, i]].add(1.0)
        kernels.append(K)
    # pushforward: for each z, the product measure over positions
    for x in range(S):
        toks = states[x]
        prob_x = jnp.ones((S,), dtype=p.dtype)
        for i in range(N):
            prob_x = prob_x * kernels[i][:, toks[i]]
        out = out.at[x].set(jnp.vdot(p, prob_x))
    return out


def marginal_velocity(path: FactorizedPath, t: int,
                      cond_u: Array) -> Array:
    """Theorem 1 (Eq. 9): marginalize conditional velocities against the
    posterior ``p_t(z|x0,x1)π(x0,x1)/p_t(z)``.

    cond_u: (S0, S1, N, d, S) with cond_u[x0,x1,i,a,z] = u_t^i(a, z | x0, x1).
    Returns u of shape (N, d, S).
    """
    joint = path.conditional_joint(t)            # (S0, S1, S_z)
    pz = jnp.einsum("abz,ab->z", joint, path.pi)  # p_t(z)
    post = joint * path.pi[:, :, None]           # (S0, S1, S_z)
    safe = jnp.where(pz > 0, pz, 1.0)
    u = jnp.einsum("abidz,abz->idz", cond_u, post) / safe[None, None, :]
    return u


def chain_marginals(p0: Array, us: list, nbr: np.ndarray) -> list:
    """Roll the sampling rule forward: returns [p_0, p_1, ..., p_T]."""
    ps = [p0]
    for u in us:
        ps.append(apply_sampling_rule(ps[-1], u, nbr))
    return ps
