"""Autoregressive sampling as an instance of discrete-time DFM (paper §4.2).

The objects here implement Eq. 18–22 exactly on the enumerable space
``[d]^N`` (positions are 0-indexed: at timestep ``t`` exactly ``P + t``
tokens are revealed, and the single active position is ``j(t) = P + t``).

The bridge to production: ``next_token_conditional`` is what a trained
language model approximates; ``velocity_from_conditional`` turns it into the
1-sparse probability-generating velocity of Eq. 22's marginalization. The
serving engine (repro/serve) realises ``apply_sampling_rule`` restricted to
the active position — which, by the paper's Theorem, is exactly ordinary
autoregressive decoding.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .dfm import FactorizedPath, decode, encode, enumerate_states, n_states

Array = jnp.ndarray


def mask_state(x1_tokens: np.ndarray, reveal: int, mask_id: int) -> np.ndarray:
    """First ``reveal`` tokens of x1, rest = mask (the C-coupling of Eq. 18)."""
    out = np.full_like(x1_tokens, mask_id)
    out[..., :reveal] = x1_tokens[..., :reveal]
    return out


def masked_coupling(q: Array, P: int, d: int, N: int, mask_id: int) -> Array:
    """π(x0, x1) for the coupling of Eq. 18 with a fixed prefix length P:
    x0 = (x1[:P], m, ..., m), x1 ~ q. Shape (S, S)."""
    S = n_states(d, N)
    states = enumerate_states(d, N)
    pi = np.zeros((S, S))
    x0_idx = encode(mask_state(states, P, mask_id), d)
    q_np = np.asarray(q)
    for x1 in range(S):
        pi[x0_idx[x1], x1] += q_np[x1]
    return jnp.asarray(pi)


def ar_scheduler(P: int, N: int, T: int) -> np.ndarray:
    """κ_t^i of Eq. 20 (0-indexed): κ[t, i] = 1 iff position i revealed at t,
    i.e. i < P + t. Shape (T+1, N)."""
    kappa = np.zeros((T + 1, N))
    for t in range(T + 1):
        kappa[t, : min(N, P + t)] = 1.0
    return kappa


def ar_path(q: Array, P: int, d: int, N: int, mask_id: int) -> FactorizedPath:
    """The AR conditional probability path of Eq. 19–20 as a FactorizedPath.

    T = N − P steps (all tokens revealed at t = T).
    ``cond[t][x0, x1, i, a] = κ_t^i δ_{x1^i}(a) + (1 − κ_t^i) δ_{x0^i}(a)``.
    """
    S = n_states(d, N)
    states = enumerate_states(d, N)
    T = N - P
    pi = masked_coupling(q, P, d, N, mask_id)
    kappa = ar_scheduler(P, N, T)
    onehot = np.eye(d)[states]  # (S, N, d): onehot[x, i, a] = δ(x^i = a)
    cond = []
    for t in range(T + 1):
        k = kappa[t][None, None, :, None]                     # (1,1,N,1)
        c = k * onehot[None, :, :, :] + (1 - k) * onehot[:, None, :, :]
        cond.append(jnp.asarray(c))
    return FactorizedPath(d=d, N=N, pi=pi, cond=cond)


def ar_conditional_velocity(t: int, P: int, d: int, N: int,
                            mask_id: int) -> Array:
    """Eq. 22: u_t^i(a, z | x0, x1) = (δ_{x_{t+1}}(a) − δ_{x_t}(a)) 1[z = x_t].

    Since x0 is a deterministic function of x1 under the coupling, we index
    conditionals by (x0, x1) but only the x1 slice matters. Returns
    (S, S, N, d, S): [x0, x1, i, a, z].
    """
    S = n_states(d, N)
    states = enumerate_states(d, N)
    xt_idx = encode(mask_state(states, P + t, mask_id), d)       # x_t per x1
    xt1_idx = encode(mask_state(states, P + t + 1, mask_id), d)  # x_{t+1}
    xt_toks = decode(xt_idx, d, N)
    xt1_toks = decode(xt1_idx, d, N)
    u = np.zeros((S, S, N, d, S))
    j = P + t  # the single active position (0-indexed)
    if j < N:
        for x1 in range(S):
            z = xt_idx[x1]
            u[:, x1, j, xt1_toks[x1, j], z] += 1.0
            u[:, x1, j, xt_toks[x1, j], z] -= 1.0
    return jnp.asarray(u)


def next_token_conditional(q: Array, prefix: np.ndarray, d: int,
                           N: int) -> np.ndarray:
    """q(x^j = a | x^{<j} = prefix) for j = len(prefix). What an LM learns."""
    j = len(prefix)
    states = enumerate_states(d, N)
    q_np = np.asarray(q)
    sel = np.all(states[:, :j] == np.asarray(prefix)[None, :], axis=1)
    probs = np.zeros(d)
    for a in range(d):
        probs[a] = q_np[sel & (states[:, j] == a)].sum()
    tot = probs.sum()
    return probs / tot if tot > 0 else np.full(d, 1.0 / d)


def ar_marginal_velocity(q: Array, P: int, t: int, d: int, N: int,
                         mask_id: int) -> Array:
    """Closed-form marginal velocity (Theorem 1 applied to Eq. 19–22).

    At the active position j = P + t and a reachable state z (prefix of some
    x1 in supp(q), masks after):  u^j(a, z) = q(x^j = a | z^{<j}) − δ(a = m).
    Zero elsewhere. Shape (N, d, S).
    """
    S = n_states(d, N)
    states = enumerate_states(d, N)
    u = np.zeros((N, d, S))
    j = P + t
    if j >= N:
        return jnp.asarray(u)
    q_np = np.asarray(q)
    # reachable states at time t: x_t images of supp(q)
    xt_idx = encode(mask_state(states, j, mask_id), d)
    reachable = np.unique(xt_idx[q_np > 0])
    for z in reachable:
        prefix = states[z, :j]
        cond = next_token_conditional(q, prefix, d, N)
        u[j, :, z] += cond
        u[j, mask_id, z] -= 1.0
    return jnp.asarray(u)


def velocity_from_conditional(cond_probs: Array, z_tok: Array) -> Array:
    """Production bridge: given a model's next-token distribution
    ``cond_probs`` (..., d) and the current token value at the active position
    ``z_tok`` (...,), return the 1-sparse velocity slice u^j(·, z):
    ``u = cond_probs − onehot(z_tok)`` — move all mass from the current
    (mask) token to the model's conditional. Used by the ensemble engine."""
    d = cond_probs.shape[-1]
    return cond_probs - jnp.eye(d, dtype=cond_probs.dtype)[z_tok]
