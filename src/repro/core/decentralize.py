"""Decentralization of the generating velocity (paper §4.3, Eq. 25–27).

The headline theorem: split the target distribution into disjoint clusters
``S_k``; then the *global* marginal velocity decomposes exactly as

    u_t^i(a, z) = Σ_k  r_k(z, t) · u_t^{i,(k)}(a, z)

where ``u^{(k)}`` is the velocity of the path built from the cluster-
conditional target ``q(·|S_k)`` (what expert k trains on, independently) and
the *exact router* is the posterior  ``r_k(z, t) = p_t(z|S_k) p(S_k) / p_t(z)``.

This module computes all three objects exactly on ``[d]^N`` so the theorem is
machine-checkable (tests/test_decentralize.py), and provides the production
form used by the serving engine: a router-weighted mixture of expert
next-token distributions.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from .autoregressive import ar_marginal_velocity, mask_state
from .dfm import encode, enumerate_states, n_states

Array = jnp.ndarray


@dataclass
class ClusterSplit:
    """A partition of the target support into K disjoint clusters.

    ``assignment[x1] = k`` for every state index with q(x1) > 0.
    """

    q: Array                 # (S,) global target
    assignment: np.ndarray   # (S,) int cluster ids (arbitrary where q=0)
    K: int

    def prior(self) -> Array:
        """p(S_k) = Σ_{x1 ∈ S_k} q(x1)."""
        q = np.asarray(self.q)
        return jnp.asarray(np.array(
            [q[self.assignment == k].sum() for k in range(self.K)]))

    def cluster_target(self, k: int) -> Array:
        """q(·|S_k) — the distribution expert k is trained on."""
        q = np.asarray(self.q).copy()
        q[self.assignment != k] = 0.0
        tot = q.sum()
        return jnp.asarray(q / tot if tot > 0 else q)


def expert_velocities(split: ClusterSplit, P: int, t: int, d: int, N: int,
                      mask_id: int) -> Array:
    """u^{(k)} for every cluster: shape (K, N, d, S). Each is the marginal
    velocity of the AR path whose target is q(·|S_k) — i.e. what expert k's
    model represents after training only on its own data."""
    return jnp.stack([
        ar_marginal_velocity(split.cluster_target(k), P, t, d, N, mask_id)
        for k in range(split.K)
    ])


def router_weights(split: ClusterSplit, P: int, t: int, d: int, N: int,
                   mask_id: int) -> Array:
    """Exact router r_k(z,t) = p_t(z|S_k) p(S_k) / p_t(z), shape (K, S).

    Under the AR path, p_t(z|S_k) = Σ_{x1 ∈ S_k} q(x1|S_k) 1[x_t(x1) = z],
    i.e. the cluster-conditional mass of the prefix z. States with
    p_t(z) = 0 get uniform weights (they are never visited).
    """
    S = n_states(d, N)
    states = enumerate_states(d, N)
    q = np.asarray(split.q)
    xt_idx = encode(mask_state(states, P + t, mask_id), d)
    pz_k = np.zeros((split.K, S))
    for x1 in range(S):
        if q[x1] > 0:
            pz_k[split.assignment[x1], xt_idx[x1]] += q[x1]
    pz = pz_k.sum(0)
    safe = np.where(pz > 0, pz, 1.0)
    r = pz_k / safe[None, :]
    r[:, pz == 0] = 1.0 / split.K
    return jnp.asarray(r)


def global_velocity_from_experts(expert_u: Array, router: Array) -> Array:
    """Eq. 27 recomposition: u(a,z) = Σ_k r_k(z) u^{(k)}(a,z).

    expert_u: (K, N, d, S); router: (K, S) → (N, d, S).
    """
    return jnp.einsum("knds,ks->nds", expert_u, router)


def decomposition_residual(split: ClusterSplit, P: int, t: int, d: int,
                           N: int, mask_id: int) -> Array:
    """‖u_global − Σ_k r_k u^{(k)}‖_∞ restricted to reachable states — the
    quantity the paper proves is exactly zero."""
    u_global = ar_marginal_velocity(split.q, P, t, d, N, mask_id)
    u_k = expert_velocities(split, P, t, d, N, mask_id)
    r = router_weights(split, P, t, d, N, mask_id)
    recomposed = global_velocity_from_experts(u_k, r)
    # restrict to reachable states (others are convention-dependent)
    states = enumerate_states(d, N)
    q = np.asarray(split.q)
    xt_idx = encode(mask_state(states, P + t, mask_id), d)
    reachable = np.unique(xt_idx[q > 0])
    diff = (u_global - recomposed)[:, :, reachable]
    return jnp.abs(diff).max()


# ---------------------------------------------------------------------------
# Production form: mixture of expert next-token distributions
# ---------------------------------------------------------------------------

def mix_expert_distributions(expert_probs: Array, weights: Array) -> Array:
    """Serving-time recomposition. Because the velocity is affine in the
    next-token conditional (u = cond − onehot(mask)) and router weights sum
    to 1, mixing velocities ≡ mixing conditionals:

        Σ_k r_k (c_k − δ_m) = (Σ_k r_k c_k) − δ_m.

    expert_probs: (K, ..., d); weights: (K, ...) broadcastable → (..., d).
    """
    w = weights[..., None] if weights.ndim == expert_probs.ndim - 1 else weights
    return (expert_probs * w).sum(axis=0)


def topk_filter_renorm(weights: Array, k: int) -> Array:
    """Paper §5.2: keep the top-k router weights, renormalize, zero the rest
    (k=1 in the main experiments ⇒ compute-matched single-expert routing)."""
    K = weights.shape[0]
    if k >= K:
        return weights / weights.sum(axis=0, keepdims=True)
    kept = weights * _scatter_topk(weights, k)
    return kept / jnp.maximum(kept.sum(axis=0, keepdims=True), 1e-30)


def _scatter_topk(weights: Array, k: int) -> Array:
    """Top-k mask along axis 0 for batched weights (K, ...)."""
    ranks = jnp.argsort(jnp.argsort(-weights, axis=0), axis=0)
    return (ranks < k).astype(weights.dtype)
