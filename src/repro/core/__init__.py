"""Core contribution of the paper: discrete-time Discrete Flow Matching,
autoregressive generation as its special case, and the exact decentralization
of the generating velocity into router-weighted expert velocities."""

from . import autoregressive, clustering, decentralize, dfm, ensemble, router

__all__ = [
    "autoregressive",
    "clustering",
    "decentralize",
    "dfm",
    "ensemble",
    "router",
]
