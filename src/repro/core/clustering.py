"""Balanced spherical k-means for data partitioning (paper §5.1, Fig. 1).

The paper clusters frozen vision-encoder (CLIP) features into K *equal-size*
clusters with cosine distance; the centroids then double as the inference
router. We implement:

* ``spherical_balanced_kmeans`` — the paper's main algorithm: Lloyd
  iterations with L2-normalized centroids + an exactly-balanced assignment
  step (greedy on similarity margins, a standard balanced-k-means device).
* ``two_stage_balanced_kmeans`` — the Table-9 ablation (McAllister et al.
  style): fine unbalanced clustering into ``fine_k`` clusters, then balanced
  coarse clustering of the fine centroids (weighted by fine-cluster mass).

All distances are cosine; all centroids are unit-norm (the paper's explicit
normalization). The heavy inner product (N×K similarity matrix) is exactly
the computation the ``router_scores`` Pallas kernel fuses at serving time.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


def l2_normalize(x: Array, axis: int = -1, eps: float = 1e-12) -> Array:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=axis, keepdims=True), eps)


@dataclass
class ClusterResult:
    centroids: np.ndarray    # (K, D), unit-norm — these ARE the router
    assignment: np.ndarray   # (N,) int
    sims: np.ndarray         # (N, K) final cosine similarities
    n_iter: int


def _balanced_assign(sims: np.ndarray, K: int) -> np.ndarray:
    """Exactly-balanced assignment from an (N, K) similarity matrix.

    Greedy by *margin*: points that lose the most by being displaced from
    their best cluster are assigned first; full clusters are closed. Cluster
    sizes differ by at most 1 (exactly N/K when K | N) — the paper's "all
    samples are evenly distributed" requirement.
    """
    N = sims.shape[0]
    cap = np.full(K, N // K)
    cap[: N % K] += 1
    # margin = best available sim − second best; high margin ⇒ assign early
    order = np.argsort(-(np.sort(sims, axis=1)[:, -1] - np.sort(sims, axis=1)[:, -2])) \
        if K > 1 else np.arange(N)
    assignment = np.full(N, -1, dtype=np.int64)
    remaining = cap.copy()
    for idx in order:
        ranked = np.argsort(-sims[idx])
        for k in ranked:
            if remaining[k] > 0:
                assignment[idx] = k
                remaining[k] -= 1
                break
    return assignment


def _update_centroids(x: np.ndarray, assignment: np.ndarray, K: int,
                      rng: np.random.Generator) -> np.ndarray:
    D = x.shape[1]
    cent = np.zeros((K, D))
    for k in range(K):
        members = x[assignment == k]
        if len(members) == 0:  # re-seed empty cluster
            cent[k] = x[rng.integers(len(x))]
        else:
            cent[k] = members.mean(0)
    norms = np.linalg.norm(cent, axis=1, keepdims=True)
    return cent / np.maximum(norms, 1e-12)


def spherical_balanced_kmeans(features: np.ndarray, K: int, *,
                              n_iter: int = 50, seed: int = 0,
                              balanced: bool = True) -> ClusterResult:
    """The paper's single-stage algorithm. ``features``: (N, D)."""
    rng = np.random.default_rng(seed)
    x = np.asarray(features, dtype=np.float64)
    x = x / np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-12)
    # k-means++-style spherical init
    cent = x[rng.choice(len(x), size=K, replace=False)].copy()
    assignment = None
    it = 0
    for it in range(1, n_iter + 1):
        sims = x @ cent.T  # cosine similarity (all unit-norm)
        new_assignment = (_balanced_assign(sims, K) if balanced
                          else sims.argmax(1))
        if assignment is not None and np.array_equal(new_assignment, assignment):
            assignment = new_assignment
            break
        assignment = new_assignment
        cent = _update_centroids(x, assignment, K, rng)
    sims = x @ cent.T
    return ClusterResult(centroids=cent, assignment=assignment,
                         sims=sims, n_iter=it)


def two_stage_balanced_kmeans(features: np.ndarray, K: int, *,
                              fine_k: int = 64, n_iter: int = 50,
                              seed: int = 0) -> ClusterResult:
    """Table-9 ablation: fine unbalanced clustering → balanced coarse
    clustering of the fine centroids (each weighted by its member count),
    then points inherit their fine centroid's coarse cluster. Balance is
    approximate at the point level (exact at the fine-centroid level), as in
    McAllister et al. (2025)."""
    fine_k = min(fine_k, len(features))
    fine = spherical_balanced_kmeans(features, fine_k, n_iter=n_iter,
                                     seed=seed, balanced=False)
    counts = np.bincount(fine.assignment, minlength=fine_k).astype(np.float64)
    # weighted balanced coarse clustering over fine centroids: replicate each
    # centroid proportionally to its mass so the greedy balancer sees weights.
    coarse = spherical_balanced_kmeans(fine.centroids, K, n_iter=n_iter,
                                       seed=seed + 1, balanced=True)
    assignment = coarse.assignment[fine.assignment]
    x = np.asarray(features, dtype=np.float64)
    x = x / np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-12)
    sims = x @ coarse.centroids.T
    return ClusterResult(centroids=coarse.centroids, assignment=assignment,
                         sims=sims, n_iter=fine.n_iter + coarse.n_iter)


def partition_text_only(n_text: int, K: int, seed: int = 0) -> np.ndarray:
    """Paper §6.1: text-only samples are randomly and *equally* distributed
    between the clusters."""
    rng = np.random.default_rng(seed)
    base = np.tile(np.arange(K), n_text // K + 1)[:n_text]
    return rng.permutation(base)
