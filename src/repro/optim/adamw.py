"""Pure-JAX AdamW with global-norm clipping and mixed-precision master
weights (optax is not available offline; this is the full substrate).

State is a pytree mirroring the parameters, so it inherits their sharding
(ZeRO-1: optimizer state sharded exactly like the FSDP-sharded params).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4                 # peak; scaled by the schedule
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"         # cosine | linear | constant
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step: Array) -> Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * \
            0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_ratio) * frac
    else:
        decay = jnp.asarray(1.0)
    return cfg.lr * warm * decay


def init_state(params, keep_master: Optional[bool] = None) -> Dict[str, Any]:
    """m/v in f32; optional f32 master copy when params are low-precision."""
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    if keep_master is None:
        keep_master = any(p.dtype != jnp.float32
                          for p in jax.tree.leaves(params))
    state = {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "count": jnp.zeros((), jnp.int32),
    }
    if keep_master:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply_updates(params, grads, state, cfg: AdamWConfig
                  ) -> Tuple[Any, Dict[str, Any], Dict[str, Array]]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    lr = lr_at(cfg, count)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else jnp.asarray(1.0)

    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)
    masters = state.get("master", params)

    def upd(p, g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        master32 = master.astype(jnp.float32)
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * master32
        new_master = master32 - lr * step
        return new_master.astype(p.dtype), m, v, new_master

    out = jax.tree.map(upd, params, grads, state["m"], state["v"], masters)
    # unzip the 4-tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "count": count}
    if "master" in state:
        new_state["master"] = jax.tree.map(
            lambda t: t[3], out, is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, new_state, metrics
