"""repro: Decentralized Autoregressive Generation — a JAX framework.

Core: the paper's discrete-time DFM theory + decentralized expert training
with a parameter-free centroid router; substrates: model zoo, data pipeline,
optimizer, checkpointing, pjit training, KV-cache/ensemble serving, Pallas
TPU kernels, multi-pod launch + roofline tooling.
"""
__version__ = "1.0.0"
