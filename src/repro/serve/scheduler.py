"""Continuous batching: a slot-based request scheduler over one decode
engine (vLLM-style, minus paging — slots are fixed-length cache rows).

Requests arrive with different prompt lengths and budgets; the server
admits each into a free slot (single-row prefill, inserted into the batch
cache at the slot index), decodes ALL active slots in lockstep with a
per-slot position vector, and retires finished requests — so new work
never waits for the longest running request.

v1 scope: attention-cache families (dense / moe / vlm) — their cache
layout is {k, v}: (L, B, S, KV, dh) with the slot (batch) dim at index 1.
In the decentralized deployment each expert pod runs one SlotServer and
the front-end router (Eq. 28) assigns requests to pods.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model

Array = jnp.ndarray


@dataclass
class Request:
    rid: int
    tokens: np.ndarray            # (prompt_len,) int32
    max_new: int
    out: List[int] = field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new


class SlotServer:
    def __init__(self, model: Model, params, n_slots: int, cache_len: int):
        assert model.cfg.family in ("dense", "moe", "vlm"), \
            "v1 slot server supports attention-cache families"
        self.model, self.params = model, params
        self.n_slots, self.cache_len = n_slots, cache_len
        self.cache = model.init_cache(n_slots, cache_len)
        self.pos = np.zeros(n_slots, dtype=np.int32)      # next position
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.last_tok = np.zeros(n_slots, dtype=np.int32)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len))
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(p, c, t, pos))

    # ------------------------------------------------------------------

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    @property
    def active(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    def admit(self, req: Request) -> bool:
        """Prefill the request alone and insert its KV rows at a free slot."""
        free = self.free_slots()
        if not free:
            return False
        slot = free[0]
        batch = {"tokens": jnp.asarray(req.tokens[None, :]),
                 "labels": jnp.zeros((1, len(req.tokens)), jnp.int32)}
        logits, row_cache = self._prefill(self.params, batch)
        # greedy first token from the prompt's last position
        first = int(jnp.argmax(logits[0, -1]))
        req.out.append(first)
        self.cache = jax.tree.map(
            lambda full, row: jax.lax.dynamic_update_slice_in_dim(
                full, row.astype(full.dtype), slot, axis=1),
            self.cache, row_cache)
        self.slot_req[slot] = req
        self.pos[slot] = len(req.tokens)
        self.last_tok[slot] = first
        return True

    def step(self) -> List[Request]:
        """One lockstep decode over every active slot. Returns requests
        retired this step."""
        act = self.active
        if not act:
            return []
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.last_tok),
            jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1), dtype=np.int32)
        retired = []
        for slot in act:
            req = self.slot_req[slot]
            req.out.append(int(nxt[slot]))
            self.pos[slot] += 1
            self.last_tok[slot] = nxt[slot]
            if req.done or self.pos[slot] >= self.cache_len - 1:
                retired.append(req)
                self.slot_req[slot] = None
        return retired

    # ------------------------------------------------------------------

    def serve(self, queue: List[Request], *, max_steps: int = 10_000
              ) -> Dict[int, List[int]]:
        """Drive the queue to completion with continuous admission."""
        pending = list(queue)
        finished: Dict[int, List[int]] = {}
        for _ in range(max_steps):
            while pending and self.free_slots():
                self.admit(pending.pop(0))
            if not self.active and not pending:
                break
            for req in self.step():
                finished[req.rid] = req.out
        return finished
