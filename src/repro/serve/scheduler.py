"""Continuous batching: slot-based request schedulers over the decode core
(vLLM-style, minus paging — slots are fixed-length cache rows).

Requests arrive with different prompt lengths and budgets; a server admits
each into a free slot (single-row prefill, inserted into the batched cache
at the slot index via the model's ``CacheSpec``), decodes ALL active slots
in lockstep with a per-slot position vector, and retires finished requests —
so new work never waits for the longest running request.

Every cache family is supported: the model's cache descriptor says where
each cache leaf's slot axis lives, so the same admission/step machinery
drives attention KV rings (dense/moe/vlm), enc-dec cross-attention caches
(audio), and recurrent states (ssm/hybrid).

The decentralized deployment (paper §5.2) is ``DecentralizedSlotServer``:
the parameter-free centroid router (Eq. 28) runs at the front end on each
request's frozen-encoder features and either

* dispatches the request to its top-1 expert's pod — one ``SlotServer`` per
  expert, the paper's compute-matched setting — or
* admits it into the stacked-expert mixture core (``MixtureSlotServer``):
  expert parameters carry a stacked K (``dexpert``) dim in the decode
  layout (K after each scanned stack's layer dim — transpose-free for the
  scan), one jitted decode step vmaps over it and fuses the Eq. 27
  probability mixture, so the top-k path is a single sharded op instead of
  K sequential engine calls.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ensemble import make_stacked_serving, mix_expert_logits
from repro.models.model import Model

Array = jnp.ndarray


@dataclass
class Request:
    rid: int
    tokens: np.ndarray            # (prompt_len,) int32
    max_new: int
    features: Optional[np.ndarray] = None   # frozen-encoder routing features
    extras: Dict[str, np.ndarray] = field(default_factory=dict)
    #                             # unbatched modality inputs: "patches"
    #                             # (vlm), "frames" (audio)
    out: List[int] = field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new

    def batch(self) -> Dict[str, Array]:
        """Single-row prefill batch (tokens + modality extras)."""
        b = {"tokens": jnp.asarray(self.tokens[None, :]),
             "labels": jnp.zeros((1, len(self.tokens)), jnp.int32)}
        for name, v in self.extras.items():
            b[name] = jnp.asarray(np.asarray(v)[None])
        return b


class _SlotTable:
    """Slot bookkeeping + the continuous-admission drive loop shared by the
    single-engine and stacked-mixture servers."""

    def __init__(self, n_slots: int, cache_len: int):
        self.n_slots, self.cache_len = n_slots, cache_len
        self.pos = np.zeros(n_slots, dtype=np.int32)      # next position
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.last_tok = np.zeros(n_slots, dtype=np.int32)

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    @property
    def active(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    def admit(self, req: Request) -> bool:
        raise NotImplementedError

    def step(self) -> List[Request]:
        raise NotImplementedError

    def _occupy(self, slot: int, req: Request, first_tok: int,
                prompt_len: int) -> None:
        req.out.append(first_tok)
        self.slot_req[slot] = req
        self.pos[slot] = prompt_len
        self.last_tok[slot] = first_tok

    def _advance(self, next_tok: np.ndarray) -> List[Request]:
        """Record one decoded token per active slot; retire finished
        requests. next_tok: (n_slots,) int32 (inactive rows ignored)."""
        retired = []
        for slot in self.active:
            req = self.slot_req[slot]
            req.out.append(int(next_tok[slot]))
            self.pos[slot] += 1
            self.last_tok[slot] = next_tok[slot]
            if req.done or self.pos[slot] >= self.cache_len - 1:
                retired.append(req)
                self.slot_req[slot] = None
        return retired

    def serve(self, queue: List[Request], *, max_steps: int = 10_000
              ) -> Dict[int, List[int]]:
        """Drive the queue to completion with continuous admission."""
        pending = list(queue)
        finished: Dict[int, List[int]] = {}
        for _ in range(max_steps):
            while pending and self.free_slots():
                self.admit(pending.pop(0))
            if not self.active and not pending:
                break
            for req in self.step():
                finished[req.rid] = req.out
        leftover = [r.rid for r in pending] + \
            [r.rid for r in self.slot_req if r is not None]
        if leftover:
            raise RuntimeError(
                f"serve() exhausted max_steps={max_steps} with requests "
                f"{leftover} unfinished — raise max_steps or shrink budgets")
        return finished


def make_serve_fns(model: Model, cache_len: int, *,
                   use_kernel: bool = False):
    """The jitted (prefill, decode) pair one SlotServer runs on. Params are
    an explicit argument, so pods serving different experts of the same
    model SHARE one pair (one trace/compile instead of K)."""
    prefill = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len, use_kernel=use_kernel))
    decode = jax.jit(
        lambda p, c, t, pos: model.decode_step(p, c, t, pos,
                                               use_kernel=use_kernel))
    return prefill, decode


class SlotServer(_SlotTable):
    """Continuous batching over ONE expert / model (greedy decoding)."""

    def __init__(self, model: Model, params, n_slots: int, cache_len: int,
                 *, use_kernel: bool = False, serve_fns=None):
        super().__init__(n_slots, cache_len)
        self.model, self.params = model, params
        self.use_kernel = use_kernel
        self.cache = model.init_cache(n_slots, cache_len)
        self.spec = model.cache_spec()
        self._prefill, self._decode = serve_fns or make_serve_fns(
            model, cache_len, use_kernel=use_kernel)

    def admit(self, req: Request) -> bool:
        """Prefill the request alone and insert its decode state at a free
        slot."""
        free = self.free_slots()
        if not free:
            return False
        slot = free[0]
        logits, row_cache = self._prefill(self.params, req.batch())
        # greedy first token from the prompt's last position
        first = int(jnp.argmax(logits[0, -1]))
        self.cache = self.spec.insert(self.cache, row_cache, slot)
        # logits width = positions consumed (incl. any image prefix)
        self._occupy(slot, req, first, logits.shape[1])
        return True

    def step(self) -> List[Request]:
        """One lockstep decode over every active slot. Returns requests
        retired this step."""
        if not self.active:
            return []
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.last_tok),
            jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1), dtype=np.int32)
        return self._advance(nxt)


class MixtureSlotServer(_SlotTable):
    """Continuous batching over the STACKED expert ensemble: one cache
    carrying the expert (K) dim, one jitted vmapped decode step with the
    Eq. 27 mixture fused in, per-slot router weights fixed at admission."""

    def __init__(self, model: Model, expert_params: List[Any], router,
                 n_slots: int, cache_len: int, *, use_kernel: bool = False):
        super().__init__(n_slots, cache_len)
        self.model, self.router = model, router
        self.K = len(expert_params)
        self.use_kernel = use_kernel
        self.stacked, _, self._prefill_all, self._mix_decode = \
            make_stacked_serving(model, expert_params, cache_len,
                                 use_kernel=use_kernel)
        # expert (K) dim at axis 1, AFTER each leaf's scan dim — the layout
        # the vmapped scanned decode consumes without per-step transposes
        self.cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape[:1] + (self.K,) + s.shape[1:],
                                s.dtype),
            model.cache_shapes(n_slots, cache_len))
        self.spec = model.cache_spec().shifted(1)   # batch axes move by 1
        self.weights = np.zeros((n_slots, self.K), dtype=np.float32)
        self._mix = jax.jit(mix_expert_logits)

    def admit(self, req: Request) -> bool:
        free = self.free_slots()
        if not free:
            return False
        if req.features is None:
            raise ValueError("mixture admission routes on request features")
        slot = free[0]
        w = self.router.route(jnp.asarray(req.features[None]))    # (1, K)
        logits, row_cache = self._prefill_all(self.stacked, req.batch())
        probs = self._mix(logits[:, :, -1], w)                    # (1, V)
        first = int(jnp.argmax(probs[0]))
        self.cache = self.spec.insert(self.cache, row_cache, slot)
        self.weights[slot] = np.asarray(w[0])
        self._occupy(slot, req, first, logits.shape[2])
        return True

    def step(self) -> List[Request]:
        if not self.active:
            return []
        probs, self.cache = self._mix_decode(
            self.stacked, self.cache, jnp.asarray(self.last_tok),
            jnp.asarray(self.pos), jnp.asarray(self.weights))
        nxt = np.asarray(jnp.argmax(probs, axis=-1), dtype=np.int32)
        return self._advance(nxt)


class DecentralizedSlotServer:
    """Front-end centroid router over continuously-batched expert pods.

    strategy="top1"    — grouped top-1 (compute-matched): one ``SlotServer``
                         per expert pod; each request decodes on exactly the
                         expert the router assigns it.
    strategy="mixture" — general top-k: the stacked-expert mixture core.
    """

    def __init__(self, model: Model, expert_params: List[Any], router,
                 n_slots: int, cache_len: int, *, strategy: str = "top1",
                 use_kernel: bool = False):
        assert strategy in ("top1", "mixture"), strategy
        self.model, self.router = model, router
        self.K = len(expert_params)
        self.strategy = strategy
        if strategy == "top1":
            fns = make_serve_fns(model, cache_len, use_kernel=use_kernel)
            self.pods = [SlotServer(model, p, n_slots, cache_len,
                                    use_kernel=use_kernel, serve_fns=fns)
                         for p in expert_params]
        else:
            self.core = MixtureSlotServer(model, expert_params, router,
                                          n_slots, cache_len,
                                          use_kernel=use_kernel)

    def route(self, queue: List[Request]) -> np.ndarray:
        feats = np.stack([r.features for r in queue])
        return np.asarray(self.router.top1(jnp.asarray(feats)))

    def serve(self, queue: List[Request], *, max_steps: int = 10_000
              ) -> Dict[int, List[int]]:
        if not queue:
            return {}
        if self.strategy == "mixture":
            return self.core.serve(queue, max_steps=max_steps)
        expert_of = self.route(queue)
        pending: List[List[Request]] = [[] for _ in range(self.K)]
        for req, k in zip(queue, expert_of):
            pending[int(k)].append(req)
        finished: Dict[int, List[int]] = {}
        for _ in range(max_steps):
            idle = True
            for k, pod in enumerate(self.pods):
                while pending[k] and pod.free_slots():
                    pod.admit(pending[k].pop(0))
                if pod.active or pending[k]:
                    idle = False
                for req in pod.step():
                    finished[req.rid] = req.out
            if idle:
                break
        leftover = [r.rid for reqs in pending for r in reqs] + \
            [r.rid for pod in self.pods for r in pod.slot_req
             if r is not None]
        if leftover:
            raise RuntimeError(
                f"serve() exhausted max_steps={max_steps} with requests "
                f"{leftover} unfinished — raise max_steps or shrink budgets")
        return finished

    def occupancy(self) -> List[int]:
        """Active slots per pod (top-1) or in the mixture core."""
        if self.strategy == "mixture":
            return [len(self.core.active)]
        return [len(p.active) for p in self.pods]
