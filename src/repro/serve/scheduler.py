"""Continuous batching: slot-based request schedulers over the decode core
(vLLM-style, with paged KV caching and chunked-prefill co-scheduling).

Every engine here exposes the incremental request-lifecycle API from
``repro.serve.api`` as its *primitive* surface:

* ``add_request(prompt, SamplingParams(...), ...) -> rid`` — submit a
  prompt (or a prebuilt ``Request``) to the engine's waiting queue, at any
  time. Admission into a slot happens inside ``step()``.
* ``step() -> list[RequestOutput]`` — run one scheduler step (admission +
  one co-scheduled prefill-chunk/decode dispatch) and stream back a
  per-token update for EVERY request that progressed, not just the
  retirements: each ``RequestOutput`` carries the new ``TokenDelta``s
  (stamped for TTFT/ITL), the cumulative ids, and — once finished — a
  ``finish_reason`` in {length, stop, aborted, truncated}.
* ``abort(rid) -> RequestOutput | None`` — cancel a request at any point
  in its life: still queued, mid-prefill, or mid-decode. Frees its slot,
  returns its pool blocks, and drops its prefix-cache references; returns
  the terminal output (``finish_reason == "aborted"``) or None if the rid
  is unknown or already finished (a no-op).
* ``has_unfinished() -> bool`` — anything still waiting or active.

``make_engine(model, params | experts=..., router=..., config=EngineConfig)``
builds the right engine for a deployment; the legacy ``serve(queue)`` is
now a thin drain loop over exactly these primitives (submit everything,
step until idle, collect the finished outputs) and keeps exact greedy
parity with the pre-redesign servers.

Requests arrive with different prompt lengths and budgets; a server admits
each into a free slot, decodes ALL active slots in lockstep with a per-slot
position vector, and retires finished requests — so new work never waits
for the longest running request. Two admission modes:

* **monolithic** (``chunk=0``) — admission runs one single-row prefill and
  inserts the decode state into the batched cache via the model's
  ``CacheSpec``. Simple, but every active decode slot stalls for the full
  prefill of each arriving prompt.
* **chunked** (``chunk>0``) — admission only embeds the prompt (pre-split
  into per-chunk tensors) and reserves its KV blocks; the step loop then
  consumes the prompt ``chunk`` positions at a time, written straight into
  the paged pool through the slot's block table
  (``attn.chunk_attention`` / the prefix-aware flash kernel), with
  recurrent / conv / cross-attention state threaded through a per-request
  carry. Each chunk rides the SAME jitted dispatch as the lockstep decode
  (safe: decode writes and chunk writes touch disjoint physical blocks,
  and the chunk's truth lives in its carry). A ``token_budget`` bounds the
  per-step token work — decoding slots count 1 each, the chunk counts
  ``chunk`` — so decode throughput under bursty prompt arrivals is bounded
  below by construction instead of collapsing to zero during prefills.

Every cache family is supported: the model's cache descriptor says where
each cache leaf's slot axis lives, so the same admission/step machinery
drives attention KV rings (dense/moe/vlm), enc-dec cross-attention caches
(audio), and recurrent states (ssm/hybrid).

Two cache layouts share the machinery:

* **contiguous** (``page_block=0``) — each slot owns a fixed-length cache
  row of ``cache_len`` positions: simple, but every request pays for the
  longest possible row and the server's memory is O(n_slots × cache_len).
* **paged** (``page_block>0``) — attention KV leaves live in one shared
  block pool; each slot holds a *block table* mapping its logical blocks
  to physical pool blocks. Admission reserves only the blocks its prompt
  needs (``BlockAllocator`` free list), decode steps grow the reservation
  lazily, and retirement returns the blocks — so a request can decode past
  its initial reservation (no silent truncation) and pool memory is sized
  to expected load, not worst case. Recurrent/cross-attention leaves keep
  their direct per-slot rows (they are O(1) per slot already). Physical
  block 0 is reserved as a scratch target so inactive slots' lockstep
  writes never touch a live request's blocks.

A request that hits the serving context bound (``cache_len``) before its
token budget retires with ``Request.truncated = True`` — distinguishable
from normal completion. The bound is capacity-exact: position
``cache_len - 1`` is decodable (the seed retired one token early).

The decentralized deployment (paper §5.2) is ``DecentralizedSlotServer``:
the parameter-free centroid router (Eq. 28) runs at the front end on each
request's frozen-encoder features and either

* dispatches the request to its top-1 expert's pod — one ``SlotServer`` per
  expert, the paper's compute-matched setting — or
* admits it into the stacked-expert mixture core (``MixtureSlotServer``):
  expert parameters carry a stacked K (``dexpert``) dim in the decode
  layout (K after each scanned stack's layer dim — transpose-free for the
  scan), one jitted decode step vmaps over it and fuses the Eq. 27
  probability mixture, so the top-k path is a single sharded op instead of
  K sequential engine calls. In the paged layout all K experts share one
  block table per slot (the pool carries the ``dexpert`` dim).

**The single-dispatch contract.** Every steady-state scheduler step is
ONE jitted device dispatch followed by ONE ``jax.device_get``: the model
forward (plus any co-scheduled prefill chunk), Eq. 27 mixing where
applicable, seeded sampling, the stop/budget/context checks and the
position advance all run on device (``repro.serve.fused``), and the host
reads back only the ``(next_tok, done)`` pair — or, speculating, the
``(toks, n_emit, done)`` triple. Host code between dispatches does pure
numpy bookkeeping; anything that would force an extra device sync in the
step loop belongs inside the fused step (repro-lint's host-sync rule
enforces this mechanically).

Speculative decoding (``EngineConfig(speculative="ngram" | "expert",
spec_len=L)``) turns the per-step dispatch into a draft + multi-token
verify: a cheap proposer guesses ``L - 1`` tokens (host n-gram prompt
lookup — ``repro.serve.speculate`` — or the mixture core's expert 0
drafting on device), ``Model.verify_step_paged`` scores all ``L``
candidate positions in one launch over the paged pool, and the fused
accept rule (``verify_epilogue``) keeps the longest prefix that matches
the request's OWN seeded sampling stream — so outputs are token-for-token
identical to vanilla decode, speculating or not, greedy or sampled.
Rejected candidates need no undo: their K/V writes sit past the accepted
position and the next span overwrites them before any query can attend
that far (rollback-by-overwrite). Steps that cannot speculate — chunk
co-scheduling, pool pressure on the span reservation, non-capable model
families (``Model.speculative_capable``) — fall back to the vanilla
one-token step; the trajectory is unchanged, only the step size.
"""
from __future__ import annotations

import json
import logging
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.sanitizer import PoolSanitizer
from repro.core.ensemble import (PROB_FLOOR, make_stacked_chunk_fns,
                                 make_stacked_fused, make_stacked_serving,
                                 make_stacked_verify, mix_expert_logits)
from repro.models.model import Model
from repro.obs import metrics as _obs_metrics
from repro.obs.engine import EngineObs
from repro.obs.trace import ADMIT_TID, merge_chrome
from repro.serve.api import (EngineConfig, RequestOutput, SamplingParams,
                             TokenDelta, effective_page_block, stop_id_row)
from repro.serve.fused import (DONE_REASONS, _sample_tokens, argmax_tokens,
                               decode_epilogue, pick_first, sample_tokens,
                               sample_tokens_probs)
from repro.serve.prefix_cache import PrefixCache, block_keys
from repro.serve.qos import (DEFAULT_ADMIT_LOOKAHEAD, ParkedState,
                             QoSConfig, TenantScheduler, predict_ttft,
                             priority_of, tenant_of)
from repro.serve.speculate import NGramProposer

Array = jnp.ndarray

logger = logging.getLogger(__name__)


@dataclass
class Request:
    """One in-flight request. ``SamplingParams`` is the canonical carrier
    of the decoding controls; the flat ``max_new``/``temperature``/
    ``top_k``/``seed`` fields remain as the legacy construction surface
    (and are kept in sync with ``params`` either way)."""

    rid: int
    tokens: np.ndarray            # (prompt_len,) int32
    max_new: int
    features: Optional[np.ndarray] = None   # frozen-encoder routing features
    extras: Dict[str, np.ndarray] = field(default_factory=dict)
    #                             # unbatched modality inputs: "patches"
    #                             # (vlm), "frames" (audio)
    temperature: float = 0.0      # 0 → greedy (the default: parity-exact)
    top_k: int = 0                # sample from the k highest-scoring tokens
    #                             # (0 → the full vocabulary)
    seed: int = 0                 # per-request sampling stream
    params: Optional[SamplingParams] = None
    out: List[int] = field(default_factory=list)
    truncated: bool = False       # retired at the context bound, not done
    finish_reason: Optional[str] = None     # set exactly once, at retirement
    t_submit: float = 0.0         # perf_counter at add_request
    t_admit: float = 0.0          # perf_counter at slot admission (PR 9:
    #                             # queued_s = t_admit - t_submit)
    t_first: float = 0.0          # perf_counter at the first emitted token
    t_done: float = 0.0           # perf_counter at retirement
    t_tok: List[float] = field(default_factory=list)   # per-token stamps
    emitted: int = 0              # tokens already streamed out via step()
    spec_req_steps: int = 0       # this request's speculative verify steps
    spec_req_accepted: int = 0    # draft tokens those steps accepted
    preemptions: int = 0          # times this request was parked/requeued
    resuming: bool = False        # parked by recompute: the next admission
    #                             # is a resume (re-prefill prompt + output)

    def __post_init__(self):
        if self.params is None:
            self.params = SamplingParams(
                max_new=self.max_new, temperature=self.temperature,
                top_k=self.top_k, seed=self.seed)
        else:                     # params is canonical: mirror to legacy
            self.max_new = self.params.max_new
            self.temperature = self.params.temperature
            self.top_k = self.params.top_k
            self.seed = self.params.seed

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new

    @property
    def hit_stop(self) -> bool:
        """The LAST generated token is a stop/eos id (prompt tokens never
        trigger — only the output stream is inspected)."""
        s = self.params.stop_set
        return bool(s) and bool(self.out) and self.out[-1] in s

    def reason_now(self) -> Optional[str]:
        """Retirement reason after the latest emitted token, or None if
        the request should keep decoding. Capacity truncation is the
        caller's to detect (it is positional, not content, state)."""
        if self.hit_stop:
            return "stop"
        if self.done:
            return "length"
        return None

    def record(self, tok: int, t: Optional[float] = None) -> None:
        """Append one generated token with its latency stamp."""
        t = time.perf_counter() if t is None else t
        self.out.append(int(tok))
        self.t_tok.append(t)
        self.t_first = self.t_first or t

    @property
    def prefill_tokens(self) -> np.ndarray:
        """Token ids a (re-)prefill consumes. Normally the prompt; when a
        recompute-preempted request resumes, the prompt plus all but the
        last generated token — their KV was dropped at the park, and the
        last token is the decode input (its KV is written by the next
        decode step), exactly as after a fresh admission."""
        if self.resuming and len(self.out) > 1:
            return np.concatenate(
                [self.tokens, np.asarray(self.out[:-1], np.int32)])
        return self.tokens

    def batch(self, pad_to: int = 0) -> Dict[str, Array]:
        """Single-row prefill batch (tokens + modality extras). ``pad_to``
        right-pads the token row to that length (chunked prefill rounds the
        prompt up to a whole number of chunks; padded rows are masked)."""
        toks = self.prefill_tokens
        if pad_to > len(toks):
            toks = np.concatenate(
                [toks, np.zeros(pad_to - len(toks), np.int32)])
        b = {"tokens": jnp.asarray(toks[None, :]),
             "labels": jnp.zeros((1, len(toks)), jnp.int32)}
        for name, v in self.extras.items():
            b[name] = jnp.asarray(np.asarray(v)[None])
        return b


# _sample_tokens / sample_tokens moved to repro.serve.fused (so the fused
# dispatch, the stacked mixture core and the schedulers share one tracing)
# and re-exported above for back-compat.

_FEATURES_MSG = ("request {rid}: this engine routes on frozen-encoder "
                 "features — pass features= to add_request")


def _as_request(prompt, params: Optional[SamplingParams], extras,
                features, rid: int) -> Request:
    """The one place a submission becomes a ``Request``: pass a prebuilt
    ``Request`` through untouched, or wrap a token-id array with its
    ``SamplingParams`` (shared by the engines' ``add_request`` and the
    decentralized front end)."""
    if isinstance(prompt, Request):
        return prompt
    sp = params if params is not None else SamplingParams()
    return Request(rid, np.asarray(prompt, dtype=np.int32), sp.max_new,
                   features=features, extras=dict(extras or {}), params=sp)


def _raise_dropped(dropped: List[str], n_finished: int,
                   max_steps: int) -> None:
    """Exhausting the drive loop with unfinished requests is never a silent
    drop: log the count (with each request's progress — queued, decode
    position, or partial prefill position), then raise."""
    logger.error(
        "serve() exhausted max_steps=%d: dropping %d unfinished "
        "request(s) %s (%d finished)", max_steps, len(dropped), dropped,
        n_finished)
    raise RuntimeError(
        f"serve() exhausted max_steps={max_steps} with {len(dropped)} "
        f"request(s) {dropped} unfinished — raise max_steps or shrink "
        f"budgets")


class BlockAllocator:
    """Free-list allocator over a shared pool of KV cache blocks.

    Physical block 0 is reserved as the scratch block: inactive slots'
    lockstep decode writes land there (their block tables are zeroed), so
    the pool hands out blocks 1..n_blocks-1. ``alloc`` is all-or-nothing —
    a partially satisfiable request leaves the free list untouched.

    ``free`` guards against out-of-range ids and double frees with clear
    errors: once blocks are refcounted and shared (the prefix cache), a
    bookkeeping slip would otherwise hand the same physical block to two
    live requests and corrupt both silently.

    Every block also carries a generation counter, bumped when it is
    freed: a holder that stamped the generation at reservation can prove
    its ``(slot, block)`` reference is still live (``assert_live``) — a
    stale reference held across a free/realloc raises a use-after-free
    instead of silently aliasing the block's new owner (the failure shape
    of PR 4's refcount-0 eviction bug).
    """

    def __init__(self, n_blocks: int):
        if n_blocks < 2:
            raise ValueError(f"pool needs >= 2 blocks (one is the reserved "
                             f"scratch block), got {n_blocks}")
        self.n_blocks = n_blocks
        self._free = list(range(n_blocks - 1, 0, -1))   # pop() → low ids
        self._free_set = set(self._free)
        self.gen = [0] * n_blocks       # bumped at free() per block

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(out)
        return out

    def free(self, blocks: List[int]) -> None:
        if len(set(blocks)) != len(blocks):
            raise ValueError(f"double free within one call: {blocks}")
        for b in blocks:
            if not 0 < b < self.n_blocks:
                raise ValueError(
                    f"freeing block {b} outside the pool range "
                    f"1..{self.n_blocks - 1} (block 0 is the reserved "
                    f"scratch block)")
            if b in self._free_set:
                raise ValueError(
                    f"double free of block {b} — it is already on the free "
                    f"list; block refcount bookkeeping is corrupt")
        self._free.extend(blocks)
        self._free_set.update(blocks)
        for b in blocks:
            self.gen[b] += 1

    def assert_live(self, block: int, gen: int, *, owner: str = "") -> None:
        """Raise unless ``block`` is still in the allocation generation the
        holder stamped at reservation — i.e. it has NOT been freed (and
        possibly reissued) since. ``owner`` names the holder in the
        error."""
        cur = self.gen[block]
        if cur != gen:
            who = f" held by {owner}" if owner else ""
            raise ValueError(
                f"use-after-free: block {block}{who} was freed since its "
                f"reservation (generation {cur} != held {gen}) — the "
                "reference is stale and may alias the block's new owner")


class _SlotTable:
    """Slot bookkeeping + the continuous-admission drive loop shared by the
    single-engine and stacked-mixture servers. With ``block_size > 0`` it
    also owns the paged-cache block tables and allocator; with ``chunk > 0``
    it runs chunked-prefill continuous batching: admission only embeds the
    prompt and reserves its blocks, and each scheduler step co-schedules one
    prefill chunk (FCFS over mid-prefill slots) with the lockstep decode of
    every decoding slot in a single jitted dispatch, subject to
    ``token_budget`` (decode slots count 1 token each, the chunk counts
    ``chunk``; 0 → n_slots + chunk, so co-scheduling always fits)."""

    def __init__(self, n_slots: int, cache_len: int, *, block_size: int = 0,
                 n_blocks: int = 0, window: int = 0, chunk: int = 0,
                 token_budget: int = 0, prefix_cache: bool = False,
                 sanitize: bool = False, obs: Optional[EngineObs] = None,
                 qos: Optional[QoSConfig] = None, preemption: str = "off"):
        self.n_slots, self.cache_len = n_slots, cache_len
        # -- multi-tenant QoS (PR 10, repro.serve.qos) --------------------
        # policy objects; None/"off" keeps the legacy FCFS behavior (plus
        # the bounded admission skip-ahead, which is always on)
        self.qos = qos
        self.preemption = preemption
        quantum = (qos.quantum if qos is not None and qos.quantum > 0
                   else (chunk if chunk > 0 else 16))
        self._drr_admit = TenantScheduler(qos, quantum)
        self._drr_chunk = TenantScheduler(qos, quantum)
        self._parked: Dict[int, ParkedState] = {}   # rid -> parked state
        self._chunk_pick: Optional[int] = None      # this step's chunk slot
        self._step_ewma = 0.0        # EWMA step() wall time (TTFT model)
        self._tenant_stats: Dict[str, Dict[str, int]] = {}
        # telemetry bundle (PR 9): the always-on per-engine registry plus
        # the (default no-op) span recorder. stats() and the n_aborted /
        # n_stopped / n_spec_* back-compat attributes are views over it.
        self.obs = obs if obs is not None else EngineObs()
        self.obs.name_tracks(n_slots, f"pod {self.obs.pod}")
        self.pos = np.zeros(n_slots, dtype=np.int32)      # next position
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.last_tok = np.zeros(n_slots, dtype=np.int32)
        self.admit_retired: List[Request] = []  # retired without a slot
        self.waiting: List[Request] = []        # submitted, not yet admitted
        self._next_rid = 0                      # auto-assigned request ids
        self._needs_features = False            # mixture/top1 routing input
        self.chunk = chunk
        self.chunked = chunk > 0
        self.token_budget = token_budget if token_budget > 0 \
            else n_slots + chunk
        self.prefilling = [False] * n_slots
        self.prefill_pos = np.zeros(n_slots, dtype=np.int32)
        self.prefill_base = np.zeros(n_slots, dtype=np.int32)  # cached prefix
        self.prefill_width = np.zeros(n_slots, dtype=np.int32)
        self.prefill_x: List[Any] = [None] * n_slots   # per-chunk tensors
        self.prefill_carry: List[Any] = [None] * n_slots
        self.prefill_keys: List[Any] = [None] * n_slots  # full-block keys
        self.prefill_order: List[int] = []      # FCFS over mid-prefill slots
        self._seq_axis = 1         # sequence axis of the embedded prompt
        self._from_probs = False   # mixture scores are probabilities
        self.fused = False         # single-dispatch decode step (subclasses
        #                          # flip it on after building the fused fns)
        self._dstate = None        # persistent per-slot device state; None →
        #                          # rebuild from the host mirrors next step
        self._tables_dirty = False  # block tables grew but nothing else
        #                          # changed: patch st["tables"] only
        self._stop_width = 1       # stop-id matrix width (monotone, pow2 —
        #                          # each growth retraces the fused step once)
        self.speculative: Optional[str] = None  # set from EngineConfig by
        self.spec_len = 1          # _init_speculation (servers call it)
        self._can_spec = False     # armed: config asks AND the model can
        #                          # roll a span back (speculative_capable)
        self._step_span = 1        # decode-write span of the CURRENT step:
        #                          # 1 vanilla, spec_len speculating (the
        #                          # PoolSanitizer and _nb_live read it)
        self.block_size = block_size
        self.paged = block_size > 0
        if self.paged:
            s_kv = min(cache_len, window) if window > 0 else cache_len
            self.ring = window > 0
            if self.ring:
                if s_kv % block_size:
                    raise ValueError(
                        f"sliding-window ring length {s_kv} must be a "
                        f"multiple of page_block={block_size}")
                self.nb_slot = s_kv // block_size
            else:
                self.nb_slot = -(-cache_len // block_size)
            if n_blocks <= 0:       # default: full capacity + scratch
                n_blocks = n_slots * self.nb_slot + 1
            self.allocator = BlockAllocator(n_blocks)
            self.obs.pool_total_g.set(self.allocator.n_blocks)
            self.obs.pool_free_g.set(self.allocator.n_free)
            self.block_tables = np.zeros((n_slots, self.nb_slot), np.int32)
            self.n_alloc = np.zeros(n_slots, dtype=np.int32)
            # allocation generation of each mapped entry (use-after-free
            # detection: checked against allocator.gen at release and by
            # the PoolSanitizer's per-step scan)
            self.block_gens = np.zeros((n_slots, self.nb_slot), np.int64)
        self.prefix: Optional[PrefixCache] = None
        if prefix_cache:
            # flag combinations were vetted by EngineConfig.validate();
            # reaching here with prefix on means paged + chunked are too
            assert self.paged and self.chunked, (block_size, chunk)
            self.prefix = PrefixCache(self.allocator, block_size,
                                      registry=self.obs.registry)
        # debug-mode dynamic checker over the paged pool (EngineConfig.
        # sanitize / --sanitize): shadows every step with an ownership scan
        self.sanitizer: Optional[PoolSanitizer] = \
            PoolSanitizer(self) if sanitize and self.paged else None
        if not self.paged:
            # preemption parks/drops paged blocks; a family with no
            # pageable leaves (effective_page_block == 0) degrades to the
            # direct path and cannot be preempted
            self.preemption = "off"

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    # lifetime counters, re-implemented as views over the registry (PR 9)
    # so exposition and stats() can never disagree
    @property
    def n_aborted(self) -> int:
        return self.obs.n_aborted

    @property
    def n_stopped(self) -> int:
        return self.obs.n_stopped

    @property
    def n_spec_steps(self) -> int:
        return self.obs.n_spec_steps

    @property
    def n_spec_tokens(self) -> int:
        return self.obs.n_spec_tokens

    @property
    def active(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    @property
    def decoding(self) -> List[int]:
        """Slots in the lockstep decode (mid-prefill slots are excluded —
        their truth lives in the chunk carry, not the batched cache)."""
        return [i for i, r in enumerate(self.slot_req)
                if r is not None and not self.prefilling[i]]

    def admit(self, req: Request) -> bool:
        raise NotImplementedError

    def _decode_step(self) -> List[Request]:
        """One raw scheduler dispatch (lockstep decode, optionally fused
        with a prefill chunk). Returns the requests retired by it."""
        raise NotImplementedError

    # ------------------------------------------------------------------
    # The incremental request-lifecycle API (the primitive surface)
    # ------------------------------------------------------------------

    def add_request(self, prompt, params: Optional[SamplingParams] = None,
                    extras: Optional[Dict[str, np.ndarray]] = None, *,
                    features: Optional[np.ndarray] = None,
                    rid: Optional[int] = None) -> int:
        """Submit a prompt (token-id array) — or a prebuilt ``Request`` —
        to the waiting queue and return its rid. Admission into a slot
        happens inside ``step()``; submission never blocks and never
        dispatches device work. A request NO capacity could ever admit
        (prompt past the serving context, or a reservation bigger than
        the whole pool) is rejected here with a ValueError rather than
        poisoning the head of the queue."""
        req = _as_request(prompt, params, extras, features,
                          self._next_rid if rid is None else rid)
        if self._needs_features and req.features is None:
            raise ValueError(_FEATURES_MSG.format(rid=req.rid))
        self._reject_unservable(req)
        self._next_rid = max(self._next_rid, req.rid + 1)
        req.t_submit = req.t_submit or time.perf_counter()
        self.obs.submitted.inc()
        if self.qos is not None:
            why = self._admission_control(req)
            if why is not None:
                self._finish_rejected(req, why)
                return req.rid
        self.waiting.append(req)
        return req.rid

    def _admission_control(self, req: Request) -> Optional[str]:
        """SLO-aware load shedding at submission (``QoSConfig``): None →
        accept into the queue; otherwise the reason to reject. The
        predicted-TTFT model is first-order by design: every prompt token
        queued or still prefilling ahead of the arrival must flow through
        the per-step chunk budget at the observed (EWMA) step time."""
        q = self.qos
        if q.max_waiting and len(self.waiting) >= q.max_waiting:
            return (f"queue depth {len(self.waiting)} at the "
                    f"max_waiting={q.max_waiting} bound")
        if q.max_predicted_ttft_s > 0 and self.chunked \
                and self._step_ewma > 0:
            backlog = sum(self._prefill_width(r) for r in self.waiting)
            backlog += sum(
                int(self.prefill_width[s] - self.prefill_pos[s])
                for s in self.prefill_order)
            eta = predict_ttft(backlog + self._prefill_width(req),
                               self.chunk, self._step_ewma)
            if eta > q.max_predicted_ttft_s:
                return (f"predicted TTFT {eta:.3f}s over the "
                        f"max_predicted_ttft_s={q.max_predicted_ttft_s} "
                        f"SLO ({backlog} backlog tokens)")
        return None

    def _finish_rejected(self, req: Request, why: str) -> None:
        """Admission control refused the submission: retire it without a
        slot (``finish_reason="rejected"``, zero tokens) — the terminal
        ``RequestOutput`` streams from the next ``step()``, exactly like
        an admission retirement. Rejection is load shedding, not an
        error, so it logs rather than raises."""
        logger.info("reject request %d (tenant %s): %s", req.rid,
                    tenant_of(req), why)
        req.t_done = time.perf_counter()
        self._set_reason(req, "rejected")
        tenant = tenant_of(req)
        self._tenant(tenant)["rejections"] += 1
        self.obs.rejected(tenant).inc()
        self._obs_retired(None, req)
        self.admit_retired.append(req)

    def _tenant(self, tenant: str) -> Dict[str, int]:
        st = self._tenant_stats.get(tenant)
        if st is None:
            st = {"tokens": 0, "preemptions": 0, "resumes": 0,
                  "rejections": 0}
            self._tenant_stats[tenant] = st
        return st

    def _reject_unservable(self, req: Request) -> None:
        """Fail fast at submission on requests that can never be admitted,
        even by an idle server: the engine runs forever, so parking one at
        the queue head would wedge every later arrival behind it."""
        width = self._prefill_width(req)
        self._reject_overlong(req, width)
        # monolithic admission of a context-filling prompt retires at
        # admission without reserving; every other paged path reserves the
        # whole prompt — which needs `need` DISTINCT physical blocks
        # (prefix-shared blocks live in the same pool, so sharing can't
        # shrink the requirement below the table's span)
        if self.paged and (self.chunked or width < self.cache_len):
            need = self.nb_slot if self.ring else \
                max(min(-(-width // self.block_size), self.nb_slot), 1)
            usable = self.allocator.n_blocks - 1
            if need > usable:
                raise ValueError(
                    f"request {req.rid}: its prompt reservation needs "
                    f"{need} KV blocks but the pool has only {usable} "
                    f"usable (pool_blocks={self.allocator.n_blocks}, "
                    f"page_block={self.block_size}) — provision more "
                    f"pool_blocks or shorten the prompt")

    def step(self) -> List[RequestOutput]:
        """One engine step: admit from the waiting queue while slots (and,
        paged, pool blocks) allow, then run one co-scheduled prefill-chunk
        / lockstep-decode dispatch. Streams back a ``RequestOutput`` for
        every request that progressed — finished ones first (admission
        retirements, then this step's), then the live per-token deltas in
        slot order."""
        t_start = time.perf_counter()
        self._chunk_pick = None      # this step's chunk pick, not yet made
        self._admit_waiting()
        finished = self._drain_admit_retired()
        if self.active:
            if self.sanitizer is not None:
                self.sanitizer.begin_step()
            finished += self._decode_step()
            if self.sanitizer is not None:
                self.sanitizer.check_step()
        outs = [self._output(r) for r in finished]
        for req in (self.slot_req[s] for s in range(self.n_slots)):
            if req is not None and req.emitted < len(req.out):
                outs.append(self._output(req))
        self._obs_step()
        # EWMA step time feeds the admission-control TTFT prediction
        dt = time.perf_counter() - t_start
        self._step_ewma = dt if self._step_ewma == 0.0 \
            else 0.9 * self._step_ewma + 0.1 * dt
        return outs

    def abort(self, rid: int) -> Optional[RequestOutput]:
        """Cancel a request wherever it is in its life — still queued,
        mid-prefill, or mid-decode. Frees its slot, returns its pool
        blocks, and drops its prefix-cache references (shared cached
        blocks stay resident for other holders / the LRU list). Returns
        the terminal output (``finish_reason == "aborted"``); an unknown
        or already-finished rid is a no-op returning None."""
        for i, req in enumerate(self.waiting):
            if req.rid == rid:
                self.waiting.pop(i)
                parked = self._parked.pop(rid, None)
                if parked is not None:
                    # a parked victim holds pinned prefix refs (and, swap,
                    # a host payload): release them exactly
                    self._drop_parked(parked)
                    if self.sanitizer is not None:
                        self.sanitizer.check_pool()
                return self._finish_aborted(req)
        for slot, req in enumerate(self.slot_req):
            if req is None or req.rid != rid:
                continue
            if self.prefilling[slot]:
                self.prefill_order.remove(slot)
                self.prefilling[slot] = False
                self.prefill_x[slot] = None
                self.prefill_carry[slot] = None
                self.prefill_keys[slot] = None
                self.prefill_pos[slot] = 0
                self.prefill_base[slot] = 0
                self.prefill_width[slot] = 0
            self._release(slot)
            if self.sanitizer is not None:
                # an aborted request must leave zero leaked blocks behind
                self.sanitizer.check_pool()
            return self._finish_aborted(req)
        return None

    def has_unfinished(self) -> bool:
        """True while any request is waiting or holds a slot."""
        return bool(self.waiting) or bool(self.active)

    def _finish_aborted(self, req: Request) -> RequestOutput:
        req.finish_reason = "aborted"
        req.t_done = time.perf_counter()
        obs = self.obs
        obs.aborted.inc()
        self._account_retired(req)
        tr = obs.trace
        if tr.enabled:
            slot = getattr(req, "_obs_slot", None)
            tid = obs.slot_tid(slot) if slot is not None else ADMIT_TID
            t0 = getattr(req, "_obs_t_phase", 0.0)
            if t0:                  # close the phase the abort interrupted
                tr.complete(getattr(req, "_obs_phase", "decode"), t0,
                            req.t_done, tid, args={"rid": req.rid})
            elif req.t_admit == 0.0:   # aborted straight out of the queue
                tr.async_begin("queued", req.t_submit, req.rid)
                tr.async_end("queued", req.t_done, req.rid)
            tr.instant("abort", req.t_done, tid, args={"rid": req.rid})
        return self._output(req)

    def _admit_waiting(self) -> None:
        """Admission from the waiting queue. Without a QoSConfig this is
        FCFS with a bounded skip-ahead window (``DEFAULT_ADMIT_LOOKAHEAD``)
        past an unadmittable queue head — a pool-starved large prompt no
        longer head-of-line-blocks smaller admissible requests behind it.
        With a QoSConfig, deficit round robin arbitrates *between tenants*
        (weighted, charged in prompt tokens) while FCFS order is preserved
        *within* each tenant. Either way a request no idle server can
        admit would wait forever: raise instead."""
        if self.qos is None:
            self._admit_fcfs()
        else:
            self._admit_drr()
        if self.waiting and not self.active:
            req = self.waiting[0]
            # last resort on an otherwise idle server: parked requests'
            # pinned prefix blocks may be what is starving the pool —
            # release the pins (their contents stay reproducible: swap
            # payloads move host-side first, recompute re-prefills) and
            # retry the head once before declaring the pool too small
            if self._parked and self._unpin_parked():
                t0 = time.perf_counter()
                if self._try_admit(req):
                    self._dequeue(req)
                    self._on_admitted(req, t0)
                    return
            raise RuntimeError(
                f"cannot admit request {req.rid} even on an "
                f"idle server — the KV block pool is too small for it")

    def _dequeue(self, req: Request) -> None:
        # identity scan: the Request dataclass __eq__ compares ndarray
        # fields, so list.remove would die on ambiguous truth values
        i = next(i for i, r in enumerate(self.waiting) if r is req)
        self.waiting.pop(i)

    def _admit_fcfs(self) -> None:
        while self.waiting and self.free_slots():
            admitted = False
            for i in range(min(len(self.waiting),
                               DEFAULT_ADMIT_LOOKAHEAD)):
                req = self.waiting[i]
                t0 = time.perf_counter()
                if self._try_admit(req):
                    self._dequeue(req)
                    self._on_admitted(req, t0)
                    admitted = True
                    break            # restart the scan from the head
            if not admitted:
                break                # wait for blocks to free up

    def _admit_drr(self) -> None:
        """DRR admission: each round offers every tenant's HEAD waiting
        request (within-tenant FCFS) to the tenant scheduler at a cost of
        its prefill width; a tenant whose head can't be admitted right
        now is refunded and stood aside for this step, so one starved
        tenant never blocks the others' admissions."""
        blocked: set = set()
        while self.waiting and self.free_slots():
            heads: Dict[str, Request] = {}
            for r in self.waiting:
                t = tenant_of(r)
                if t not in heads and t not in blocked:
                    heads[t] = r
            if not heads:
                break
            cand = {t: self._prefill_width(r) for t, r in heads.items()}
            pick = self._drr_admit.pick(cand)
            req = heads[pick]
            t0 = time.perf_counter()
            if not self._try_admit(req):
                self._drr_admit.refund(pick, cand[pick])
                blocked.add(pick)
                continue
            self._dequeue(req)
            self._on_admitted(req, t0)

    # ------------------------------------------------------------------
    # Preemption: park / resume over the paged pool (repro.serve.qos)
    # ------------------------------------------------------------------

    def _try_admit(self, req: Request) -> bool:
        """One admission attempt with the QoS extensions: a swap-parked
        request resumes by swap-in (no prefill at all); anything else —
        including recompute-parked requests, which re-enter chunked
        prefill over prompt + generated tokens — goes through the
        subclass ``admit``. On pool-pressure failure, preemption (when
        enabled) evicts one strictly-lower-priority victim and retries
        until the request fits or no eligible victim remains."""
        parked = self._parked.get(req.rid)
        while True:
            if parked is not None and parked.mode == "swap":
                ok = self._admit_swapped(parked)
            else:
                ok = self.admit(req)
            if ok:
                if parked is not None and parked.mode == "recompute":
                    # the resume's prefix match re-acquired whatever it
                    # still shares; the park's pin is now redundant
                    self._parked.pop(req.rid, None)
                    if self.prefix is not None:
                        for b in parked.pinned:
                            self.prefix.release(b)
                    parked.pinned = ()
                return True
            if self.preemption == "off":
                return False
            victim = self._pick_victim(priority_of(req))
            if victim is None:
                return False
            self._preempt(victim)

    def _pick_victim(self, floor: int,
                     exclude: Tuple[Optional[int], ...] = ()
                     ) -> Optional[int]:
        """Slot of the best preemption victim with priority strictly
        below ``floor`` — lowest priority first, youngest admission first
        among equals (it has the least work to lose). Mid-prefill slots
        are eligible (they requeue cheaply); in recompute mode a decoding
        victim whose resume prefill could never fit the pool again is
        skipped (preempting it would strand it unadmittable forever)."""
        best, best_key = None, None
        usable = self.allocator.n_blocks - 1 if self.paged else 0
        for slot in self.active:
            if slot in exclude:
                continue
            req = self.slot_req[slot]
            p = priority_of(req)
            if p >= floor:
                continue
            if self.preemption == "recompute" and not self.prefilling[slot]:
                need = -(-int(self.pos[slot]) // self.block_size)
                if min(need, self.nb_slot) > usable:
                    continue
            key = (p, -req.t_admit)
            if best_key is None or key < best_key:
                best, best_key = slot, key
        return best

    def _can_park(self, slot: int) -> bool:
        """A decoding slot may be parked only if its resume could ever be
        admitted again: always true for swap (the payload re-enters any
        free blocks), but a recompute resume must re-prefill its whole
        position span through the pool."""
        if self.preemption != "recompute" or self.prefilling[slot]:
            return True
        need = -(-int(self.pos[slot]) // self.block_size)
        return min(need, self.nb_slot) <= self.allocator.n_blocks - 1

    def _preempt(self, slot: int) -> None:
        """Evict the request holding ``slot`` to relieve pool pressure.
        Mid-prefill victims simply requeue (their chunk state is cheap to
        rebuild); decoding victims park — ``swap`` carries their private
        block contents to the host, ``recompute`` drops them and replays
        the generated tokens through chunked prefill at resume. Either
        way the victim re-enters the waiting queue at the front, and its
        resumed output is token-for-token identical: sampling is seeded
        per token index, independent of the schedule."""
        req = self.slot_req[slot]
        mode = self.preemption
        if self.prefilling[slot]:
            mode = "requeue"
            self.prefill_order.remove(slot)
            self.prefilling[slot] = False
            self.prefill_x[slot] = None
            self.prefill_carry[slot] = None
            self.prefill_keys[slot] = None
            self.prefill_pos[slot] = 0
            self.prefill_base[slot] = 0
            self.prefill_width[slot] = 0
            self._release(slot)
        elif mode == "swap":
            self._park_swap(slot, req)
        else:
            self._park_recompute(slot, req)
        self._obs_preempted(slot, req, mode)
        req.preemptions += 1
        tenant = tenant_of(req)
        self._tenant(tenant)["preemptions"] += 1
        self.obs.preempted(tenant, mode).inc()
        self.waiting.insert(0, req)
        logger.info("preempt request %d (tenant %s, priority %d, mode %s)",
                    req.rid, tenant, priority_of(req), mode)

    def _park_recompute(self, slot: int, req: Request) -> None:
        """Drop the victim's blocks, keeping only pinned prefix-cache
        references; the resume replays ``prompt + out[:-1]`` through
        chunked prefill (largely hitting the cache when the pins held)."""
        n = int(self.n_alloc[slot])
        refs = self.prefix.refcounts if self.prefix is not None else {}
        pinned = tuple(
            b for b in (int(x) for x in self.block_tables[slot, :n])
            if b in refs)
        if pinned:
            self.prefix.acquire(list(pinned))    # pin across the park
        req.resuming = True
        self._parked[req.rid] = ParkedState(
            req=req, mode="recompute", pinned=pinned,
            pos=int(self.pos[slot]), last_tok=int(self.last_tok[slot]))
        self._release(slot)

    def _park_swap(self, slot: int, req: Request) -> None:
        """Copy the victim's private block rows (and its direct, non-
        paged cache leaves) to the host, then free them; cache-tracked
        rows stay resident in the pool under a pin. Resume scatters the
        payload into freshly allocated blocks — no recompute at all."""
        n = int(self.n_alloc[slot])
        blocks = [int(b) for b in self.block_tables[slot, :n]]
        refs = self.prefix.refcounts if self.prefix is not None else {}
        shared = tuple((i, b) for i, b in enumerate(blocks) if b in refs)
        private = tuple((i, b) for i, b in enumerate(blocks)
                        if b not in refs)
        payload = jax.device_get(self.spec.swap_out(
            self.cache, slot, [b for _, b in private]))
        pinned = tuple(b for _, b in shared)
        if pinned:
            self.prefix.acquire(list(pinned))    # pin across the park
        self._parked[req.rid] = ParkedState(
            req=req, mode="swap", pinned=pinned, shared=shared,
            private=private, payload=payload, pos=int(self.pos[slot]),
            last_tok=int(self.last_tok[slot]), n_alloc=n,
            extras=self._park_extras(slot))
        self._release(slot)

    def _admit_swapped(self, st: ParkedState) -> bool:
        """Resume a swap-parked request: allocate fresh physical blocks
        for its private rows, rebuild its block table (pinned shared rows
        map back in place — the parked pin transfers silently to the
        slot's table reference), scatter the host payload back, and
        re-occupy a slot with NO prefill: the decode cursor restarts
        exactly where the park left it."""
        free = self.free_slots()
        if not free:
            return False
        req = st.req
        slot = free[0]
        fresh: List[int] = []
        if st.private:
            got = self._alloc_blocks(len(st.private))
            if got is None:
                return False
            fresh = got
        for i, b in st.shared:
            self.block_tables[slot, i] = b
        for (i, _), b in zip(st.private, fresh):
            self.block_tables[slot, i] = b
        self.n_alloc[slot] = st.n_alloc
        self._stamp_gens(slot, 0, st.n_alloc)
        self._tables_dirty = True
        self.cache = self.spec.swap_in(self.cache, st.payload, slot,
                                       fresh)
        self.slot_req[slot] = req
        self.pos[slot] = st.pos
        self.last_tok[slot] = st.last_tok
        self._restore_extras(slot, st.extras)
        self._dstate = None
        self._parked.pop(req.rid, None)
        return True

    def _drop_parked(self, st: ParkedState) -> None:
        """Free a parked request's held resources exactly: the pinned
        prefix references go back to the cache's LRU accounting and the
        swap payload is dropped (host memory only — its private blocks
        returned to the pool at park time)."""
        if self.prefix is not None:
            for b in st.pinned:
                self.prefix.release(b)
        st.pinned = ()
        st.payload = None

    def _unpin_parked(self) -> bool:
        """Deadlock relief on an otherwise idle server: drop every parked
        request's pinned prefix references so the LRU can evict those
        blocks for the admission that is starving. Recompute parks lose
        nothing (resume re-prefills whatever was evicted); swap parks
        first fold the pinned rows' contents into their host payload and
        thereafter resume fully from host copies. True if any pin was
        released."""
        released = False
        for st in self._parked.values():
            if not st.pinned:
                continue
            if st.mode == "swap" and st.shared:
                extra = jax.device_get(self.spec.swap_out(
                    self.cache, 0, [b for _, b in st.shared]))
                st.payload = self._merge_payload(st.payload, extra)
                st.private = st.private + st.shared
                st.shared = ()
            for b in st.pinned:
                self.prefix.release(b)
            st.pinned = ()
            released = True
        return released

    def _merge_payload(self, a, b):
        """Append payload ``b``'s pool rows after ``a``'s. Direct leaves
        keep ``a``'s slot copy — ``b`` was gathered with a dummy slot and
        only its pool rows are meaningful."""
        def one(x, y, b_ax, s_ax):
            if s_ax < 0:
                return x
            return np.concatenate([np.asarray(x), np.asarray(y)],
                                  axis=b_ax)
        return jax.tree.map(one, a, b, self.spec.batch_axes,
                            self.spec.paged.seq_axes)

    def _park_extras(self, slot: int) -> Dict[str, Any]:
        """Subclass hook: extra per-slot host state a swap park must
        carry (the mixture server parks its router-weight row)."""
        return {}

    def _restore_extras(self, slot: int, extras: Dict[str, Any]) -> None:
        """Subclass hook: restore ``_park_extras`` state at swap resume."""
        return None

    def _obs_preempted(self, slot: int, req: Request, mode: str) -> None:
        """Close the victim's open phase span and mark the preemption as
        an instant on its slot track; the queued span re-opens from this
        stamp at resume (``_on_admitted``)."""
        t = time.perf_counter()
        req._obs_queued_from = t
        tr = self.obs.trace
        if tr.enabled:
            tid = self.obs.slot_tid(slot)
            t0 = getattr(req, "_obs_t_phase", 0.0)
            if t0:
                tr.complete(getattr(req, "_obs_phase", "decode"), t0, t,
                            tid, args={"rid": req.rid})
            tr.instant("preempt", t, tid,
                       args={"rid": req.rid, "mode": mode,
                             "tenant": tenant_of(req)})
        req._obs_t_phase = 0.0

    def _on_admitted(self, req: Request, t0: float) -> None:
        """Telemetry boundary for one successful admission: stamp
        ``t_admit`` (queue delay ends here), close the request's
        ``queued`` span, and open its slot-resident phase. Requests that
        retired inside ``admit()`` (context-filling prompts, max_new == 1)
        clamp the admission span to their ``t_done`` so a request's spans
        always sum to its end-to-end latency."""
        t1 = req.t_done if req.finish_reason is not None \
            else time.perf_counter()
        resumed_from = getattr(req, "_obs_queued_from", 0.0)
        if not req.t_admit:          # resumes keep their first admission
            req.t_admit = t0
        obs = self.obs
        obs.admitted.inc()
        # a resumed request's queue delay is measured from its preemption
        obs.queued_s.observe(t0 - (resumed_from or req.t_submit))
        slot = next((s for s, r in enumerate(self.slot_req) if r is req),
                    None)
        if req.finish_reason is None and slot is not None:
            # phase bookkeeping rides the Request (host-only attributes):
            # the retirement path closes the open phase span from these
            req._obs_slot = slot
            req._obs_phase = "prefill" if self.prefilling[slot] \
                else "decode"
            req._obs_t_phase = t1
        tr = obs.trace
        if tr.enabled:
            tr.async_begin("queued", resumed_from or req.t_submit, req.rid,
                           args={"rid": req.rid})
            tr.async_end("queued", t0, req.rid)
            tid = obs.slot_tid(slot) if slot is not None else ADMIT_TID
            tr.complete("admission", t0, t1, tid, args={"rid": req.rid})
        if resumed_from:
            tenant = tenant_of(req)
            self._tenant(tenant)["resumes"] += 1
            obs.resumed(tenant).inc()
            if tr.enabled and slot is not None:
                tr.instant("resume", t0, obs.slot_tid(slot),
                           args={"rid": req.rid, "tenant": tenant})
            req._obs_queued_from = 0.0

    def _obs_step(self) -> None:
        """Per-step telemetry epilogue: bump the step counter and refresh
        the occupancy/pool gauges (plus, tracing, one "C" counter sample
        that Perfetto renders as timeline graphs)."""
        obs = self.obs
        obs.steps.inc()
        n_act, n_wait = len(self.active), len(self.waiting)
        obs.active_g.set(n_act)
        obs.waiting_g.set(n_wait)
        if self.paged:
            obs.pool_free_g.set(self.allocator.n_free)
        tr = obs.trace
        if tr.enabled:
            vals = {"active": n_act, "waiting": n_wait}
            if self.paged:
                vals["pool_free_blocks"] = self.allocator.n_free
            tr.counter("engine", time.perf_counter(), vals)

    def _output(self, req: Request) -> RequestOutput:
        """Build the streaming update for ``req`` (tokens newly decoded
        since its last update) and advance its emission cursor."""
        new = req.out[req.emitted:]
        stamps = req.t_tok[req.emitted:]
        deltas = [TokenDelta(tok, req.emitted + i, t)
                  for i, (tok, t) in enumerate(zip(new, stamps))]
        req.emitted = len(req.out)
        return RequestOutput(
            rid=req.rid, deltas=deltas, token_ids=list(req.out),
            finished=req.finish_reason is not None,
            finish_reason=req.finish_reason, t_submit=req.t_submit,
            t_first=req.t_first, t_done=req.t_done, t_admit=req.t_admit)

    def _prefill_width(self, req: Request) -> int:
        """Decoder positions a request's prefill consumes (so admission can
        reserve blocks before paying for the prefill). Subclasses set
        ``self.model`` before admitting. A resuming (recompute-preempted)
        request re-prefills its generated tokens too."""
        w = len(req.prefill_tokens)
        if self.model.cfg.family == "vlm":
            w += self.model.cfg.n_patches          # image prefix
        return w

    def _reject_overlong(self, req: Request, width: int) -> None:
        """A prompt that exceeds the serving context is malformed and
        rejected loudly — the cache cannot even hold its prefill."""
        if width > self.cache_len:
            raise ValueError(
                f"request {req.rid}: prompt needs {width} positions but the "
                f"serving context is cache_len={self.cache_len} — reject "
                f"the request or raise cache_len")

    def _admission_precheck(self, req: Request, slot: int,
                            width: int) -> bool:
        """Runs BEFORE the prefill is paid for. False → can't admit right
        now (pool has no blocks free: the request stays pending)."""
        self._reject_overlong(req, width)
        if self.paged and width < self.cache_len and \
                not self._reserve(slot, width):
            return False
        return True

    def _admit_prefilled(self, slot: int, req: Request, first: int,
                         width: int, row_cache) -> None:
        """Insert an admitted request's prefill state (paged or contiguous)
        and occupy its slot. A request whose whole budget is the prefill
        token (max_new == 1) retires immediately — the slot must not decode
        a token past its budget."""
        if self.paged:
            blocks = jnp.asarray(
                self.block_tables[slot, :int(self.n_alloc[slot])])
            self.cache = self.spec.insert_paged(self.cache, row_cache, slot,
                                                blocks)
        else:
            self.cache = self.spec.insert(self.cache, row_cache, slot)
        self._occupy(slot, req, first, width)
        reason = req.reason_now()        # max_new == 1, or first tok stops
        if reason:
            self._retire_from_slot(slot, req, reason)
            self.admit_retired.append(req)

    # ------------------------------------------------------------------
    # Paged-cache bookkeeping
    # ------------------------------------------------------------------

    def _alloc_blocks(self, n: int) -> Optional[List[int]]:
        """Pool allocation with prefix-cache pressure relief: when the free
        list can't satisfy, evict LRU unreferenced cached blocks back to it
        and retry — cached-but-idle prefixes never block admission."""
        blocks = self.allocator.alloc(n)
        if blocks is None and self.prefix is not None:
            self.prefix.evict(n - self.allocator.n_free)
            blocks = self.allocator.alloc(n)
        return blocks

    def _reserve(self, slot: int, upto: int,
                 shared: Optional[List[int]] = None) -> bool:
        """Grow ``slot``'s block reservation to cover logical positions
        [0, upto). Ring (sliding-window) slots reserve their whole bounded
        span at once. All-or-nothing; False when the pool can't satisfy.

        ``shared`` (admission only, table empty) maps prefix-cache hit
        blocks read-only into the table's leading entries; only the
        remainder is allocated fresh. The matched run is PINNED (acquired)
        before that allocation runs — ``_alloc_blocks`` relieves pool
        pressure by evicting LRU refcount-0 blocks, which is exactly what
        the matched run still is until it is pinned — and un-pinned again
        if the allocation fails, so a failed admission retry leaves the
        cache as it found it."""
        need = self.nb_slot if self.ring else \
            min(-(-upto // self.block_size), self.nb_slot)
        need = max(need, 1)
        have = int(self.n_alloc[slot])
        if need <= have:
            return True
        if shared:
            assert have == 0, (slot, have)
            self.prefix.acquire(shared)
            blocks = self._alloc_blocks(need - len(shared))
            if blocks is None:
                for b in shared:
                    self.prefix.release(b)
                return False
            self.block_tables[slot, :len(shared)] = shared
            self.block_tables[slot, len(shared):need] = blocks
            self.n_alloc[slot] = need
            self._stamp_gens(slot, 0, need)
            self._tables_dirty = True    # only the table changed
            return True
        blocks = self._alloc_blocks(need - have)
        if blocks is None:
            return False
        self.block_tables[slot, have:need] = blocks
        self.n_alloc[slot] = need
        self._stamp_gens(slot, have, need)
        # growth changes the table and NOTHING else — patch st["tables"]
        # instead of tearing down the whole device state (mid-decode growth
        # fires every page_block steps; a full rebuild there costs more
        # than the dispatch it feeds)
        self._tables_dirty = True
        return True

    def _stamp_gens(self, slot: int, lo: int, hi: int) -> None:
        """Record the allocation generation of newly mapped table entries
        [lo, hi) — the use-after-free witness ``_release`` (and the
        PoolSanitizer) check against ``allocator.gen``."""
        gen = self.allocator.gen
        for i in range(lo, hi):
            self.block_gens[slot, i] = gen[int(self.block_tables[slot, i])]

    def _grow_active(self) -> None:
        """Before a lockstep decode step: make sure every decoding slot
        owns the block its next write position lands in."""
        if not self.paged or self.ring:
            return
        # vectorized fast path: positions only cross a block boundary every
        # block_size steps, so most steps no slot needs growth — one numpy
        # compare instead of a python _reserve call per slot
        need = np.minimum(-(-(self.pos + 1) // self.block_size),
                          self.nb_slot)
        # n_alloc == 0 masks out free slots (a decoding slot always holds
        # at least its admission block)
        if not np.any((need > self.n_alloc) & (self.n_alloc > 0)):
            return
        for slot in self.decoding:
            if self.slot_req[slot] is None:
                continue             # preempted as a victim in this loop
            while not self._reserve(slot, int(self.pos[slot]) + 1):
                if self.preemption != "off":
                    # preempt a strictly-lower-priority victim to keep
                    # this slot decoding; never the growing slot itself,
                    # nor this step's already-scheduled chunk slot
                    p = priority_of(self.slot_req[slot])
                    victim = self._pick_victim(
                        p, exclude=(slot, self._chunk_pick))
                    if victim is None:
                        # last resort: an equal-priority victim (youngest
                        # first, never a higher one). The grower's reserve
                        # succeeds right after the park, so every eviction
                        # funds immediate decode progress — two requests
                        # too big for the pool together hand it back and
                        # forth but can never livelock
                        victim = self._pick_victim(
                            p + 1, exclude=(slot, self._chunk_pick))
                    if victim is not None:
                        self._preempt(victim)
                        continue
                    # every other active slot outranks the grower: park
                    # the growing request itself rather than crash (the
                    # higher-priority slots keep progressing and free
                    # blocks for its resume). A slot that cannot grow
                    # even alone is a genuinely too-small pool and still
                    # raises below.
                    if len(self.active) > 1 and self._can_park(slot):
                        self._preempt(slot)
                        break
                    # parked requests' pinned prefix blocks may be what
                    # is starving the pool: release the pins (contents
                    # stay reproducible) and retry the reservation
                    if self._parked and self._unpin_parked():
                        continue
                req = self.slot_req[slot]
                raise RuntimeError(
                    f"KV block pool exhausted growing slot {slot} (request "
                    f"{req.rid}): {self.allocator.n_free} free of "
                    f"{self.allocator.n_blocks} blocks — provision more "
                    f"pool_blocks or fewer slots")

    def _grow_active_span(self, span: int) -> bool:
        """Span variant of ``_grow_active``: make sure every decoding slot
        owns blocks for ALL ``span`` positions a speculative step may
        write. False → the pool can't cover the whole span right now; the
        caller degrades to the vanilla one-token step instead of raising
        (speculation is a latency lever, never a liveness requirement).
        Slots reserved before the failing one keep their blocks — they
        would need them within ``span`` vanilla steps anyway, and
        retirement returns them. Only reached non-ring (sliding-window
        models are not ``speculative_capable``)."""
        need = np.minimum(-(-(self.pos + span) // self.block_size),
                          self.nb_slot)
        if not np.any((need > self.n_alloc) & (self.n_alloc > 0)):
            return True
        for slot in self.decoding:
            if not self._reserve(slot, int(self.pos[slot]) + span):
                return False
        return True

    def _init_speculation(self, config: EngineConfig, model,
                          build) -> None:
        """Arm speculative decoding when the config asks for it AND the
        engine shape supports it: fused paged decode on a model that can
        roll a span back (``speculative_capable`` — recurrent and
        sliding-window families can't, and silently degrade to vanilla
        decode, where parity is trivial). ``build()`` returns the jitted
        verify step, deferred so ineligible servers never trace it."""
        self.speculative = config.speculative
        self.spec_len = config.spec_len
        self._can_spec = (config.speculative is not None
                          and config.spec_len > 1 and self.fused
                          and self.paged and model.speculative_capable)
        if not self._can_spec:
            return
        self._vstep = build()
        self._ngram = NGramProposer(self.spec_len) \
            if config.speculative == "ngram" else None

    def _release(self, slot: int) -> None:
        self.slot_req[slot] = None
        self.pos[slot] = 0           # free slots write the scratch block
        self.last_tok[slot] = 0
        self._dstate = None          # retirement/abort: rebuild device state
        if self.paged:
            n = int(self.n_alloc[slot])
            if n:
                blocks = self.block_tables[slot, :n].tolist()
                # use-after-free check: every block this slot is about to
                # return must still be in the generation it reserved — a
                # mismatch means something freed (and possibly reissued)
                # it behind the table's back
                for i, b in enumerate(blocks):
                    self.allocator.assert_live(
                        b, int(self.block_gens[slot, i]),
                        owner=f"slot {slot} entry {i}")
                if self.prefix is not None:
                    # cache-tracked blocks stay resident (shared or LRU-
                    # evictable); only untracked ones return to the free
                    # list here
                    blocks = [b for b in blocks
                              if not self.prefix.release(b)]
                if blocks:
                    self.allocator.free(blocks)
            self.block_tables[slot, :] = 0
            self.block_gens[slot, :] = 0
            self.n_alloc[slot] = 0

    def _retire_at_admission(self, req: Request, first_tok: int) -> None:
        """The prompt already fills the context bound: the request keeps its
        single prefill token and retires without ever holding a slot."""
        req.record(first_tok)
        req.t_done = time.perf_counter()
        self._set_reason(req, req.reason_now() or "truncated")
        self._obs_retired(None, req)
        self.admit_retired.append(req)

    def _set_reason(self, req: Request, reason: str) -> None:
        """Stamp the terminal ``finish_reason`` (keeping the legacy
        ``truncated`` flag in sync) and bump the per-reason counters."""
        req.finish_reason = reason
        req.truncated = reason == "truncated"
        self.obs.retired(reason).inc()
        self._account_retired(req)

    def _account_retired(self, req: Request) -> None:
        """Fold a terminal request into its tenant's token accounting
        (the per-tenant breakdown ``stats()`` reports and the
        ``serve_tenant_tokens_total`` series)."""
        tenant = tenant_of(req)
        self._tenant(tenant)["tokens"] += len(req.out)
        self.obs.tenant_tokens(tenant).inc(len(req.out))

    def _obs_retired(self, slot: Optional[int], req: Request) -> None:
        """Telemetry boundary for one retirement (``t_done`` already
        stamped): latency histograms, the per-request speculative accept
        rate, and — tracing — the close of the open phase span plus a
        ``retire`` instant carrying the finish reason."""
        obs = self.obs
        obs.e2e_s.observe(req.t_done - req.t_submit)
        if req.t_first > 0:
            obs.ttft_s.observe(req.t_first - req.t_submit)
        if req.spec_req_steps and self.spec_len > 1:
            obs.req_accept_rate.observe(
                req.spec_req_accepted
                / (req.spec_req_steps * (self.spec_len - 1)))
        tr = obs.trace
        if tr.enabled:
            tid = obs.slot_tid(slot) if slot is not None else ADMIT_TID
            t0 = getattr(req, "_obs_t_phase", 0.0)
            if t0:
                tr.complete(getattr(req, "_obs_phase", "decode"), t0,
                            req.t_done, tid, args={"rid": req.rid})
            tr.instant("retire", req.t_done, tid,
                       args={"rid": req.rid,
                             "finish_reason": req.finish_reason})

    def _drain_admit_retired(self) -> List[Request]:
        out, self.admit_retired = self.admit_retired, []
        return out

    # ------------------------------------------------------------------
    # Lockstep advance / drive loop
    # ------------------------------------------------------------------

    def _occupy(self, slot: int, req: Request, first_tok: int,
                prompt_len: int) -> None:
        if req.resuming:
            # resumed recompute prefill: the "first token" pick merely
            # re-predicted the last already-recorded token (and a sampled
            # pick used a fresh count-0 fold, so it need not even match) —
            # discard it and put the decode cursor exactly back where the
            # park left it: pos = resume width = park-time pos, last_tok =
            # the last recorded token
            req.resuming = False
            self.slot_req[slot] = req
            self.pos[slot] = prompt_len
            self.last_tok[slot] = int(req.out[-1])
            self._dstate = None
            return
        req.record(first_tok)
        self.slot_req[slot] = req
        self.pos[slot] = prompt_len
        self.last_tok[slot] = first_tok
        self._dstate = None          # admission: rebuild device state

    def _advance(self, next_tok: np.ndarray) -> List[Request]:
        """Record one decoded token per decoding slot; retire finished
        requests — budget exhausted (``length``), a generated stop/eos id
        (``stop``), or the capacity bound (``truncated``; capacity-exact:
        position cache_len - 1 is decodable).
        next_tok: (n_slots,) int32 (inactive/prefilling rows ignored)."""
        retired = []
        t = time.perf_counter()
        for slot in self.decoding:
            req = self.slot_req[slot]
            req.record(int(next_tok[slot]), t)
            self.pos[slot] += 1
            self.last_tok[slot] = next_tok[slot]
            reason = req.reason_now() or \
                ("truncated" if self.pos[slot] >= self.cache_len else None)
            if reason:
                self._retire_from_slot(slot, req, reason)
                retired.append(req)
        return retired

    def _retire_from_slot(self, slot: int, req: Request,
                          reason: str) -> None:
        """Finalize a request that currently holds ``slot``: stamp the
        finish reason, release the slot (and its blocks)."""
        self._set_reason(req, reason)
        req.t_done = time.perf_counter()
        self._obs_retired(slot, req)
        self._release(slot)

    # ------------------------------------------------------------------
    # Fused single-dispatch decode step (repro.serve.fused)
    # ------------------------------------------------------------------

    def _device_state(self) -> Dict[str, Array]:
        """The per-slot device-state dict the fused dispatch consumes:
        tok/pos plus every sampling/stop/budget control, as persistent
        device arrays. Rebuilt from the host mirrors ONLY when admission,
        retirement/abort or block-table growth invalidated it
        (``self._dstate = None``); between those events the dict returned
        by the previous fused dispatch is passed straight back in — the
        steady-state step uploads nothing. Pure block-table growth
        (``_tables_dirty``) patches ``st["tables"]`` alone: one small
        upload instead of a dozen."""
        if self._dstate is not None:
            if self.paged:
                nbl = self._nb_live()
                # growth marks the table dirty; the width check is a
                # belt-and-braces guard for any horizon move without one
                if self._tables_dirty or \
                        self._dstate["tables"].shape[1] != nbl:
                    self._dstate = dict(
                        self._dstate,
                        tables=jnp.asarray(self._decode_tables()[:, :nbl]))
                    self._tables_dirty = False
            return self._dstate
        self._tables_dirty = False
        n = self.n_slots
        temps = np.zeros(n, np.float32)
        top_ks = np.zeros(n, np.int32)
        seeds = np.zeros(n, np.uint32)
        counts = np.zeros(n, np.int32)
        max_new = np.full(n, np.iinfo(np.int32).max, np.int32)
        active = np.zeros(n, np.bool_)
        dec = self.decoding
        for s in dec:
            need = len(self.slot_req[s].params.stop_set)
            while need > self._stop_width:   # monotone pow2: bounded retraces
                self._stop_width *= 2
        stops = np.full((n, self._stop_width), -1, np.int32)
        for s in dec:
            r = self.slot_req[s]
            active[s] = True
            temps[s], top_ks[s] = r.temperature, r.top_k
            # & wraps negative seeds into uint32 range (NumPy 2.x raises
            # on out-of-bounds assignment instead of wrapping)
            seeds[s], counts[s] = r.seed & 0xFFFFFFFF, len(r.out)
            max_new[s] = r.max_new
            stops[s] = stop_id_row(r.params, self._stop_width)
        st = {"tok": jnp.asarray(self.last_tok),
              "pos": jnp.asarray(self.pos),
              "active": jnp.asarray(active),
              "temps": jnp.asarray(temps), "top_ks": jnp.asarray(top_ks),
              "seeds": jnp.asarray(seeds), "counts": jnp.asarray(counts),
              "max_new": jnp.asarray(max_new),
              "stop_ids": jnp.asarray(stops)}
        if self.paged:
            st["tables"] = jnp.asarray(
                self._decode_tables()[:, :self._nb_live()])
        self._dstate = self._state_extras(st)
        return self._dstate

    def _state_extras(self, st: Dict[str, Array]) -> Dict[str, Array]:
        """Subclass hook: extra per-slot device state the fused dispatch
        needs (the mixture server adds its router weights)."""
        return st

    def _pick_args(self, req: Request):
        """The (temp, top_k, seed) device rows for a fused first-token
        pick (count is 0 by construction — the pick IS token 0)."""
        return (jnp.asarray([req.temperature], jnp.float32),
                jnp.asarray([req.top_k], jnp.int32),
                jnp.asarray([req.seed & 0xFFFFFFFF], jnp.uint32))

    def _advance_fused(self, dec: List[int], nxt: np.ndarray,
                       done: np.ndarray) -> List[Request]:
        """Host half of the fused step: record each decoding slot's token
        and retire the slots the device-side ``done`` bitmap flagged — no
        per-slot token inspection, the reason is already decided."""
        retired = []
        t = time.perf_counter()
        for slot in dec:
            req = self.slot_req[slot]
            req.record(int(nxt[slot]), t)
            self.pos[slot] += 1
            self.last_tok[slot] = nxt[slot]
            d = int(done[slot])
            if d:
                reason = DONE_REASONS[d]
                # the device bitmap replaces reason_now(): they must agree
                assert reason == (req.reason_now() or "truncated"), \
                    (slot, reason, req.reason_now())
                self._retire_from_slot(slot, req, reason)
                retired.append(req)
        return retired

    def _run_fused(self, st):
        """Dispatch one fused decode step; returns device (nxt, done) and
        stores the new cache/state on self."""
        raise NotImplementedError

    def _run_fused_chunk(self, st, slot, xc, start, length, cbt, pick):
        """Fused decode + one prefill chunk (+ device-side first-token
        pick); returns device (nxt, done, first)."""
        raise NotImplementedError

    def _run_chunk_only(self, slot, xc, start, length, cbt, pick):
        """One prefill chunk + device-side first-token pick (nothing
        decoding); returns the device (1,) first token."""
        raise NotImplementedError

    def _decode_step_fused(self) -> List[Request]:
        """One scheduler step as ONE jitted device dispatch: model forward
        (+ optional co-scheduled prefill chunk), Eq. 27 mixing where
        applicable, seeded sampling, stop/budget/context checks and the
        position advance all run on device; the host reads back only the
        (next_tok, done) pair — and the chunk's first token on a prefill's
        final chunk."""
        dec = self.decoding
        self._step_span = 1          # chunk/vanilla steps write one position
        do_chunk = self.chunked and self._schedule_chunk()
        if not dec and not do_chunk:
            return []
        if do_chunk:
            slot, xc, start, length, cbt = self._chunk_args()
            pick = self._pick_args(self.slot_req[slot])
            if not dec:
                t0 = time.perf_counter()
                first = self._run_chunk_only(slot, xc, start, length, cbt,
                                             pick)
                t1 = self._obs_chunk_span(slot, start, t0)
                retired = self._after_chunk_tok(
                    slot, length, lambda: int(jax.device_get(first)[0]))
                self.obs.step_timing("chunk", t0, t1)
                return retired
            self._grow_active()
            dec = self.decoding      # growth may have preempted a victim
            st = self._device_state()
            t0 = time.perf_counter()
            nxt, done, first = self._run_fused_chunk(st, slot, xc, start,
                                                     length, cbt, pick)
            t1 = self._obs_chunk_span(slot, start, t0)
            nxt_h, done_h, first_h = jax.device_get((nxt, done, first))
            self.obs.step_timing("decode+chunk", t0, t1)
            retired = self._advance_fused(dec, nxt_h, done_h)
            retired += self._after_chunk_tok(slot, length,
                                             lambda: int(first_h[0]))
            return retired
        if self._can_spec:
            retired = self._decode_step_spec(dec)
            if retired is not None:
                return retired
            # pool can't cover the span this step: vanilla single token
        self._grow_active()
        dec = self.decoding          # growth may have preempted a victim
        st = self._device_state()
        t0 = time.perf_counter()
        nxt, done = self._run_fused(st)
        t1 = time.perf_counter()
        nxt_h, done_h = jax.device_get((nxt, done))
        self.obs.step_timing("decode", t0, t1)
        return self._advance_fused(dec, nxt_h, done_h)

    def _obs_chunk_span(self, slot: int, start: int, t0: float) -> float:
        """Stamp the end of a chunk dispatch and (tracing) emit its
        ``prefill_chunk[i]`` span on the slot's track. Returns the stamp —
        the dispatch half of the step timing."""
        t1 = time.perf_counter()
        tr = self.obs.trace
        if tr.enabled:
            req = self.slot_req[slot]
            tr.complete(f"prefill_chunk[{start // self.chunk}]", t0, t1,
                        self.obs.slot_tid(slot),
                        args={"rid": req.rid, "start": start})
        return t1

    def _obs_phase_flip(self, slot: int, req: Request) -> None:
        """Prefill → decode transition: close the request's ``prefill``
        span and open its ``decode`` phase at the same stamp (phases share
        boundaries, so a request's spans tile its latency exactly)."""
        t = time.perf_counter()
        t0 = getattr(req, "_obs_t_phase", 0.0)
        tr = self.obs.trace
        if tr.enabled and t0:
            tr.complete("prefill", t0, t, self.obs.slot_tid(slot),
                        args={"rid": req.rid})
        req._obs_phase = "decode"
        req._obs_t_phase = t

    # ------------------------------------------------------------------
    # Speculative decoding: draft + multi-token verify (repro.serve.
    # speculate / Model.verify_step_paged / fused.verify_epilogue)
    # ------------------------------------------------------------------

    def _decode_step_spec(self, dec: List[int]) -> Optional[List[Request]]:
        """One speculative step, still a single dispatch + single
        ``device_get``: reserve every decoding slot's span blocks, build
        the drafts (host n-gram lookup, or None for on-device expert
        drafting), run the fused verify and advance each slot by its
        accepted run. None → the pool can't cover the span; the caller
        falls back to the vanilla one-token step (the output trajectory
        is identical either way — only the step size changes)."""
        span = self.spec_len
        if not self._grow_active_span(span):
            return None
        self._step_span = span       # sanitizer plan + _nb_live horizon
        st = self._device_state()
        drafts = self._draft_tokens(dec) if self._ngram is not None else None
        t0 = time.perf_counter()
        toks, n_emit, done = self._run_verify(st, drafts)
        t1 = time.perf_counter()
        toks_h, n_h, done_h = jax.device_get((toks, n_emit, done))
        self.obs.step_timing("spec_verify", t0, t1)
        return self._advance_span(dec, toks_h, n_h, done_h)

    def _draft_tokens(self, dec: List[int]) -> Array:
        """Host-side n-gram drafts, one row per slot. Idle / mid-prefill
        rows stay zero: their verify writes land in the scratch block
        (tables masked / zeroed) and the epilogue masks their outputs."""
        drafts = np.zeros((self.n_slots, self.spec_len - 1), np.int32)
        for s in dec:
            r = self.slot_req[s]
            drafts[s] = self._ngram.propose(
                np.concatenate([r.tokens, np.asarray(r.out, np.int32)]))
        return jnp.asarray(drafts)

    def _run_verify(self, st, drafts):
        """Dispatch one fused verify step; returns device
        ``(toks, n_emit, done)`` and stores the new cache/state on self.
        ``drafts`` is None when the verify fn drafts on device."""
        raise NotImplementedError

    def _advance_span(self, dec: List[int], toks: np.ndarray,
                      n_emit: np.ndarray, done: np.ndarray
                      ) -> List[Request]:
        """Host half of the speculative step: record each decoding slot's
        ACCEPTED run (1..spec_len tokens — forward progress is >= the
        vanilla step by construction) and retire the slots the device
        ``done`` bitmap flagged. The device already truncated each span
        at its first stop/budget/context halt, so a request finishing
        mid-span records nothing past its terminal token and retires
        exactly once — ``stats()['stopped']`` counts it once too."""
        retired = []
        t = time.perf_counter()
        obs = self.obs
        accepted = 0
        for slot in dec:
            req = self.slot_req[slot]
            n = int(n_emit[slot])
            for j in range(n):
                req.record(int(toks[slot, j]), t)
            self.pos[slot] += n
            if n:
                self.last_tok[slot] = toks[slot, n - 1]
            obs.spec_steps.inc()
            obs.spec_tokens.inc(n)
            obs.accept_len.observe(n)
            # per-request diagnostics: n - 1 of the step's spec_len - 1
            # drafts were accepted (the first token is the committed one)
            req.spec_req_steps += 1
            req.spec_req_accepted += max(n - 1, 0)
            accepted += max(n - 1, 0)
            d = int(done[slot])
            if d:
                reason = DONE_REASONS[d]
                # the device bitmap replaces reason_now(): they must agree
                assert reason == (req.reason_now() or "truncated"), \
                    (slot, reason, req.reason_now())
                self._retire_from_slot(slot, req, reason)
                retired.append(req)
        if dec and self.spec_len > 1:
            src = self.speculative or "ngram"
            obs.drafts(src, "proposed").inc(len(dec) * (self.spec_len - 1))
            obs.drafts(src, "accepted").inc(accepted)
        return retired

    # ------------------------------------------------------------------
    # Token selection: greedy fast path / per-request seeded sampling
    # ------------------------------------------------------------------

    def _pick_first(self, req: Request, row, *,
                    from_probs: bool = False) -> int:
        """First token from a prefill's last-position scores ((V,) row).
        Greedy unless the request asked for sampling; token index 0 of the
        request's seeded stream either way. One jitted dispatch for BOTH
        paths (greedy rows take the argmax inside ``sample_tokens``) — the
        eager ``jnp.argmax`` this replaces cost a separate device sync per
        admitted request. The chunked path avoids even this dispatch: its
        pick is fused into the final chunk's step (``pick_first``).
        Probability rows route through ``sample_tokens_probs`` so the
        floor + log transform rides the same dispatch — the eager
        ``jnp.log`` it replaces was a host-path dispatch repro-lint
        flags."""
        fn = sample_tokens_probs if from_probs else sample_tokens
        return int(fn(
            row[None], jnp.asarray([req.temperature], jnp.float32),
            jnp.asarray([req.top_k], jnp.int32),
            jnp.asarray([req.seed & 0xFFFFFFFF], jnp.uint32),
            jnp.asarray([len(req.out)], jnp.int32))[0])

    def _next_tokens(self, scores, *, from_probs: bool = False) -> np.ndarray:
        """Next token per slot from the lockstep dispatch's (n_slots, V)
        scores. All-greedy steps take the jitted argmax fast path
        (``argmax_tokens`` — the eager ``jnp.argmax`` it replaces was an
        un-fused dispatch + implicit sync per step, the PR 6 incident
        repro-lint's host-sync rule now catches); any sampled slot routes
        the whole step through the jitted seeded sampler (greedy rows
        still take their argmax inside it, probability rows fold the
        floor + log into the same dispatch)."""
        dec = self.decoding
        if all(self.slot_req[s].temperature <= 0 for s in dec):
            return np.asarray(argmax_tokens(scores), dtype=np.int32)
        fn = sample_tokens_probs if from_probs else sample_tokens
        temps = np.zeros(self.n_slots, np.float32)
        top_ks = np.zeros(self.n_slots, np.int32)
        seeds = np.zeros(self.n_slots, np.uint32)
        counts = np.zeros(self.n_slots, np.int32)
        for s in dec:
            r = self.slot_req[s]
            temps[s], top_ks[s] = r.temperature, r.top_k
            # & wraps negative seeds into uint32 range (NumPy 2.x raises
            # on out-of-bounds assignment instead of wrapping)
            seeds[s], counts[s] = r.seed & 0xFFFFFFFF, len(r.out)
        return np.asarray(fn(
            scores, jnp.asarray(temps), jnp.asarray(top_ks),
            jnp.asarray(seeds), jnp.asarray(counts)), dtype=np.int32)

    # ------------------------------------------------------------------
    # Observability
    # ------------------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        """Serving stats: active slots, waiting depth, aborted/stopped
        counters, pool free blocks, prefix-cache hit rate — the numbers
        the serve log and ``occupancy()`` surface. Since PR 9 this is a
        *view* over the engine's metrics registry (``self.metrics``) —
        same keys and values as ever, one source of truth underneath.
        The aborted/stopped counters are per-``serve()``-run (each drain
        loop starts by ``reset_stats()``); driving ``step()`` directly
        accumulates them until ``reset_stats()`` is called."""
        out: Dict[str, Any] = {"active": len(self.active),
                               "waiting": len(self.waiting),
                               "aborted": self.n_aborted,
                               "stopped": self.n_stopped}
        if self.paged:
            out["pool_free_blocks"] = self.allocator.n_free
            out["pool_blocks"] = self.allocator.n_blocks
        if self.speculative is not None:
            out["spec_steps"] = self.n_spec_steps
            out["spec_tokens"] = self.n_spec_tokens
            out["spec_tokens_per_step"] = (
                self.n_spec_tokens / self.n_spec_steps
                if self.n_spec_steps else 0.0)
        if self.prefix is not None:
            out.update(self.prefix.stats())
        if self.sanitizer is not None:
            out.update(self.sanitizer.stats())
        if self.qos is not None or self.preemption != "off":
            out["parked"] = len(self._parked)
            out["tenants"] = self._tenant_breakdown()
        return out

    def _tenant_breakdown(self) -> Dict[str, Dict[str, int]]:
        """Per-tenant view: cumulative counters (tokens at retirement,
        preemptions, resumes, rejections) plus the live picture — active
        slots, pool blocks held by those slots, blocks pinned by parked
        requests, and tokens emitted by still-running requests."""
        def zero() -> Dict[str, int]:
            return {"tokens": 0, "preemptions": 0, "resumes": 0,
                    "rejections": 0, "active_slots": 0, "pool_blocks": 0,
                    "parked": 0, "pinned_blocks": 0, "tokens_live": 0}
        tenants: Dict[str, Dict[str, int]] = {}
        for t, st in self._tenant_stats.items():
            tenants[t] = dict(zero(), **st)
        for slot in self.active:
            req = self.slot_req[slot]
            d = tenants.setdefault(tenant_of(req), zero())
            d["active_slots"] += 1
            d["pool_blocks"] += int(self.n_alloc[slot])
            d["tokens_live"] += len(req.out)
        for st in self._parked.values():
            d = tenants.setdefault(tenant_of(st.req), zero())
            d["parked"] += 1
            d["pinned_blocks"] += len(st.pinned)
        return tenants

    @property
    def metrics(self) -> _obs_metrics.MetricsRegistry:
        """The engine's private metrics registry (always live; published
        to ``repro.obs.default_registry()`` when the config set
        ``metrics=True``)."""
        return self.obs.registry

    def reset_stats(self) -> None:
        """Documented per-run counter hygiene: zero the request-lifecycle
        counters (``aborted`` and the per-reason retirement counters
        behind ``stopped``) so back-to-back ``serve()`` runs on one
        engine never report a previous run's terminal counts. Cumulative
        telemetry — latency histograms, speculative and prefix-cache
        totals — is untouched; zero *everything* with the registry-wide
        ``engine.metrics.reset()``."""
        self.obs.reset_run_counters()

    def export_trace(self, path: Optional[str] = None) -> dict:
        """The recorded span trace as a Chrome/Perfetto ``trace_event``
        JSON object (empty unless the engine was built with
        ``EngineConfig(trace=True)``). Load the written file directly in
        ``ui.perfetto.dev`` or ``chrome://tracing``."""
        doc = self.obs.trace.to_chrome()
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc

    def export_metrics(self, path: Optional[str] = None) -> dict:
        """JSON snapshot of the engine's metrics registry (optionally
        written to ``path``). Prometheus text is ``prometheus_metrics``."""
        doc = self.obs.registry.to_dict()
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f, indent=1)
        return doc

    def prometheus_metrics(self) -> str:
        """Prometheus text exposition of this engine's registry."""
        return self.obs.registry.to_prometheus()

    # ------------------------------------------------------------------
    # Chunked prefill: admission, chunk scheduling, decode transition
    # ------------------------------------------------------------------

    def _admit_chunked(self, req: Request, slot: int, width: int,
                       prep) -> bool:
        """Shared chunked admission: validate, match the prompt against the
        prefix cache (hit blocks are mapped read-only into the table and
        their positions skipped), reserve the remaining blocks (the WHOLE
        width up front, so a chunk can never strand mid-prompt on an
        exhausted pool), embed the prompt + build the carry via
        ``prep(batch)``, slice off the cached prefix, pre-split the suffix
        into per-chunk tensors, and park the slot mid-prefill at the first
        uncached position. False → pool can't reserve right now; the
        request stays pending (the match re-runs on retry, so a prefix
        evicted meanwhile is simply re-prefilled)."""
        self._reject_overlong(req, width)
        toks = req.prefill_tokens    # resume: prompt + generated tokens
        base, shared, keys = 0, [], None
        if self.prefix is not None:
            # memoized per request: a pool-blocked admission retries every
            # step, and the keys (incl. the extras digest) are immutable —
            # but a resume's token span differs from the original prompt,
            # so the memo is keyed by span length too
            cached = getattr(req, "_prefix_keys", None)
            memo_key = (self.block_size, len(toks))
            if cached is None or cached[0] != memo_key:
                keys = block_keys(toks, req.extras, self.block_size,
                                  width // self.block_size,
                                  n_prefix=width - len(toks))
                req._prefix_keys = (memo_key, keys)
            else:
                keys = cached[1]
            tr = self.obs.trace
            t_m0 = time.perf_counter() if tr.enabled else 0.0
            shared = self.prefix.match(keys, width)
            if tr.enabled:
                tr.complete("prefix_match", t_m0, time.perf_counter(),
                            self.obs.slot_tid(slot),
                            args={"rid": req.rid,
                                  "hit_blocks": len(shared)})
            base = len(shared) * self.block_size
        if self.paged and not self._reserve(slot, width, shared=shared):
            return False
        if self.prefix is not None:
            self.prefix.record(width, base)
        pad = -(width - base) % self.chunk
        b = req.batch(pad_to=len(toks) + pad)
        x, carry = prep(b)
        if base:
            x = jax.lax.slice_in_dim(x, base, x.shape[self._seq_axis],
                                     axis=self._seq_axis)
        chunks = tuple(jnp.split(x, x.shape[self._seq_axis] // self.chunk,
                                 axis=self._seq_axis))
        self._occupy_prefilling(slot, req, width, chunks, carry,
                                base=base, keys=keys)
        return True

    def _occupy_prefilling(self, slot: int, req: Request, width: int,
                           x, carry, *, base: int = 0, keys=None) -> None:
        """Hold a slot in the mid-prefill state: the embedded prompt suffix
        (as a tuple of per-chunk tensors) and the chunk carry are per-slot
        host state, the slot's block table already covers the prompt
        (leading entries may be shared cached blocks — the prefill starts
        at ``base``, the first uncached position), and its decode-side rows
        stay inert (pos 0, table masked to scratch) until the transition."""
        self.slot_req[slot] = req
        self.prefilling[slot] = True
        self.prefill_pos[slot] = base
        self.prefill_base[slot] = base
        self.prefill_width[slot] = width
        self.prefill_x[slot] = x
        self.prefill_carry[slot] = carry
        self.prefill_keys[slot] = keys
        self.prefill_order.append(slot)
        self.pos[slot] = 0
        self.last_tok[slot] = 0
        self._dstate = None          # table masking changed for this slot

    def _decode_tables(self) -> np.ndarray:
        """Block tables as the decode dispatch must see them: mid-prefill
        slots are masked to the scratch block so the lockstep decode's
        writes for those rows can never touch the blocks their chunks are
        filling."""
        if not self.prefill_order:
            return self.block_tables
        bt = self.block_tables.copy()
        bt[self.prefill_order] = 0
        return bt

    def _nb_live(self) -> int:
        """Logical-block horizon of the decode dispatch: columns past
        ``max(pos) // block + 1`` hold no key any slot can attend (the
        position mask zeroes them), so the tables are truncated to this
        width before upload — the gather AND the attention span shrink to
        the live region, the jnp analogue of the kernel's pos-derived
        block skip. Ring (sliding-window) layouts address the full
        logical span and are never truncated. The dispatch retraces once
        per distinct width — at most ``nb_slot`` shapes, all warmed by
        the first request that decodes to full depth."""
        if self.ring:
            return self.nb_slot
        # a speculative step writes (and attends) up to _step_span - 1
        # positions past pos, so the horizon covers the whole span
        mx = int(self.pos.max(initial=0)) + self._step_span - 1
        return min(mx // self.block_size + 1, self.nb_slot)

    def _schedule_chunk(self) -> bool:
        """Token-budget admission of one prefill chunk into this step:
        decoding slots count one token each and always run (starvation
        freedom for decodes); the chunk rides along when it fits the budget,
        and runs alone when nothing is decoding."""
        if not self.prefill_order:
            return False
        n_dec = len(self.decoding)
        return n_dec == 0 or n_dec + self.chunk <= self.token_budget

    def _pick_chunk_slot(self) -> int:
        """This step's prefill-chunk slot. FCFS (``prefill_order`` head)
        without QoS; with a QoSConfig, deficit round robin across the
        tenants that have a mid-prefill slot (one chunk = one charge),
        FCFS within a tenant. Cached per step so the sanitizer's shadow
        replay and the dispatch see the same pick without double-charging
        the DRR."""
        pick = self._chunk_pick
        if pick is not None and self.prefilling[pick]:
            return pick
        pick = self.prefill_order[0]
        if self.qos is not None and len(self.prefill_order) > 1:
            heads: Dict[str, int] = {}
            for s in self.prefill_order:
                t = tenant_of(self.slot_req[s])
                if t not in heads:
                    heads[t] = s
            if len(heads) > 1:
                chosen = self._drr_chunk.pick(
                    {t: self.chunk for t in heads})
                pick = heads[chosen]
        self._chunk_pick = pick
        return pick

    def _chunk_args(self):
        """(slot, x_chunk, start, length, block_table) for this step's
        mid-prefill slot (``_pick_chunk_slot``). The prompt was pre-split
        into chunk tensors at admission, so picking this step's chunk
        costs no dispatch; ``length`` masks the final chunk's padding."""
        slot = self._pick_chunk_slot()
        start = int(self.prefill_pos[slot])
        length = min(self.chunk, int(self.prefill_width[slot]) - start)
        xc = self.prefill_x[slot][
            (start - int(self.prefill_base[slot])) // self.chunk]
        cbt = jnp.asarray(self.block_tables[slot]) if self.paged \
            else jnp.zeros((1,), jnp.int32)
        return slot, xc, start, length, cbt

    def _after_chunk(self, slot: int, length: int, c_out) -> List[Request]:
        """Unfused wrapper over ``_after_chunk_tok``: the first token is
        picked eagerly from the chunk's output scores."""
        req = self.slot_req[slot]
        return self._after_chunk_tok(
            slot, length,
            lambda: self._pick_first(req, c_out[0],
                                     from_probs=self._from_probs))

    def _after_chunk_tok(self, slot: int, length: int,
                         first_fn) -> List[Request]:
        """Advance a slot's prefill by one chunk; on the final chunk take
        the first token from ``first_fn`` (unfused: an eager pick from the
        chunk scores; fused: materializing the device-side pick that rode
        the chunk dispatch — intermediate chunks never call it, keeping
        their zero-sync property), register the prompt's full blocks with
        the prefix cache, splice the carry's direct-leaf state into the
        batched cache, and transition the slot to decode (or retire, for
        context-filling prompts and max_new == 1)."""
        self.prefill_pos[slot] += length
        if int(self.prefill_pos[slot]) < int(self.prefill_width[slot]):
            return []
        req = self.slot_req[slot]
        first = int(first_fn())
        width = int(self.prefill_width[slot])
        self.prefill_order.remove(slot)
        self.prefilling[slot] = False
        self.prefill_x[slot] = None
        carry, self.prefill_carry[slot] = self.prefill_carry[slot], None
        if self.prefix is not None:
            # the prompt's full blocks are now whole and immutable (decode
            # writes land past the prompt): make them shareable — BEFORE
            # any retirement below releases them to the LRU list
            n_full = width // self.block_size
            self.prefix.insert(self.prefill_keys[slot] or [],
                               self.block_tables[slot, :n_full])
        self.prefill_keys[slot] = None
        self.prefill_base[slot] = 0
        if width >= self.cache_len:      # prompt fills the context bound
            req.record(first)
            self._retire_from_slot(slot, req,
                                   req.reason_now() or "truncated")
            return [req]
        self.cache = self.spec.insert_direct(self.cache, carry, slot)
        self._obs_phase_flip(slot, req)
        self._occupy(slot, req, first, width)
        reason = req.reason_now()        # max_new == 1, or first tok stops
        if reason:
            self._retire_from_slot(slot, req, reason)
            return [req]
        return []

    def _drop_details(self) -> List[str]:
        """Progress annotation for every request still holding a slot — a
        mid-prefill request reports its partial position (it is neither
        queued nor decoding, and used to fall through drop accounting)."""
        out = []
        for slot, r in enumerate(self.slot_req):
            if r is None:
                continue
            if self.prefilling[slot]:
                out.append(f"{r.rid} (prefill {int(self.prefill_pos[slot])}"
                           f"/{int(self.prefill_width[slot])})")
            else:
                out.append(f"{r.rid} (decode pos {int(self.pos[slot])})")
        return out

    def serve(self, queue: List[Request], *, max_steps: int = 10_000
              ) -> Dict[int, List[int]]:
        """Drive a queue to completion — a thin drain loop over the
        incremental API (``add_request`` everything, ``step`` until
        nothing is unfinished, collect the finished outputs).

        Each run starts with ``reset_stats()``: the ``aborted``/
        ``stopped`` counts ``stats()`` reports afterwards are THIS run's,
        never stale totals accumulated across earlier ``serve()`` calls
        on the same engine.

        Admission can fail transiently on a paged server (not enough free
        KV blocks yet) — the request stays pending until retirements free
        blocks. Exhausting ``max_steps`` with unfinished requests raises
        (never a silent drop); every unfinished request is reported with its
        progress, including mid-prefill requests with their partial
        position.
        """
        self.reset_stats()
        for req in queue:
            self.add_request(req)
        finished: Dict[int, List[int]] = {}
        reasons: Dict[int, str] = {}
        for _ in range(max_steps):
            for out in self.step():
                if out.finished:
                    finished[out.rid] = out.token_ids
                    reasons[out.rid] = out.finish_reason
            if not self.has_unfinished():
                break
        dropped = [f"{r.rid} (queued)" for r in self.waiting] + \
            self._drop_details()
        if dropped:
            _raise_dropped(dropped, len(finished), max_steps)
        logger.info("serve: %d finished (finish_reasons %s), stats %s",
                    len(finished), reasons, self.stats())
        return finished


def _legacy_config(n_slots: int, cache_len: int, *, page_block: int,
                   pool_blocks: int, chunk: int, token_budget: int,
                   prefix_cache: bool, use_kernel: bool,
                   fused_step: bool = True,
                   strategy: str = "top1") -> EngineConfig:
    """Map the pre-redesign constructor kwargs onto an ``EngineConfig`` so
    every entry point funnels through one ``validate()``."""
    return EngineConfig(
        n_slots=n_slots, cache_len=cache_len, paged=page_block > 0,
        page_block=page_block if page_block > 0 else 16,
        pool_blocks=pool_blocks, chunked_prefill=chunk > 0,
        chunk=chunk if chunk > 0 else 16, token_budget=token_budget,
        prefix_cache=prefix_cache, fused_step=fused_step,
        use_kernel=use_kernel, strategy=strategy)


def make_chunk_fns(model: Model, cache_len: int, chunk: int, *,
                   use_kernel: bool = False, paged: bool = False):
    """The jitted chunked-prefill function family one SlotServer runs on
    (shared across the pods of a top-1 DecentralizedSlotServer, like
    ``make_serve_fns``): admission prep (embed the padded prompt and build
    the carry in one dispatch — admission then slices off any cached
    prefix and pre-splits the suffix into per-chunk tensors, so a chunk
    STEP still issues no eager slicing), the FUSED step — decode every
    decoding slot AND consume one prefill chunk in a single dispatch —
    and the chunk-only step for a server with nothing decoding. ``prep``
    retraces once per distinct padded prompt width (widths are rounded to
    whole chunks, so the bucket count stays small).

    The fusion is safe with zero ordering constraints because the two
    halves touch disjoint state: decode writes land in the decoding slots'
    own physical blocks (the chunk slot's table row is masked to scratch),
    the chunk writes land in its own reserved blocks, and the chunk's
    recurrent state flows through its carry — the lockstep decode's
    garbage updates to the mid-prefill slot's cache rows are overwritten by
    ``insert_direct`` at the transition."""
    def prep(p, b):
        x = model.embed_prompt(p, b)                    # (1, W, D)
        return x, model.init_chunk_carry(p, b, cache_len)

    chunk_only = jax.jit(
        lambda p, c, carry, xc, start, ln, cbt: model.prefill_chunk(
            p, c, carry, xc, start, ln, cbt, use_kernel=use_kernel))
    if paged:
        def fused(p, c, toks, pos, dbt, carry, xc, start, ln, cbt):
            d_logits, c = model.decode_step_paged(p, c, toks, pos, dbt,
                                                  use_kernel=use_kernel)
            c_logits, carry, c = model.prefill_chunk(
                p, c, carry, xc, start, ln, cbt, use_kernel=use_kernel)
            return d_logits, c_logits, carry, c
    else:
        def fused(p, c, toks, pos, carry, xc, start, ln, cbt):
            d_logits, c = model.decode_step(p, c, toks, pos,
                                            use_kernel=use_kernel)
            c_logits, carry, c = model.prefill_chunk(
                p, c, carry, xc, start, ln, cbt, use_kernel=use_kernel)
            return d_logits, c_logits, carry, c
    return jax.jit(prep), jax.jit(fused), chunk_only


def make_serve_fns(model: Model, cache_len: int, *, use_kernel: bool = False,
                   paged: bool = False):
    """The jitted (prefill, decode) pair one SlotServer runs on. Params are
    an explicit argument, so pods serving different experts of the same
    model SHARE one pair (one trace/compile instead of K). With ``paged``
    the decode fn takes the per-slot block tables as its last argument."""
    prefill = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len, use_kernel=use_kernel))
    if paged:
        decode = jax.jit(
            lambda p, c, t, pos, bt: model.decode_step_paged(
                p, c, t, pos, bt, use_kernel=use_kernel))
    else:
        decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(p, c, t, pos,
                                                   use_kernel=use_kernel))
    return prefill, decode


def make_fused_fns(model: Model, cache_len: int, chunk: int = 0, *,
                   use_kernel: bool = False, paged: bool = False):
    """The jitted fused-step function family one SlotServer runs on
    (shared across the pods of a top-1 DecentralizedSlotServer, like
    ``make_serve_fns``). Returns ``(step, step_chunk, chunk_only)``:

    * ``step(params, cache, state)`` → ``(cache, state, next_tok, done)``
      — the WHOLE decode token (forward + sampling + stop/budget/context
      checks + position advance) in one dispatch
      (``Model.fused_decode_step``);
    * ``step_chunk(params, cache, state, carry, xc, start, length, cbt,
      temp, top_k, seed)`` — the same with one co-scheduled prefill chunk
      and its device-side first-token pick fused in;
    * ``chunk_only(params, cache, carry, xc, start, length, cbt, temp,
      top_k, seed)`` → ``(first, carry, cache)`` — a chunk with nothing
      decoding. The last two are None when ``chunk == 0``.
    """
    step = jax.jit(lambda p, c, st: model.fused_decode_step(
        p, c, st, cache_len=cache_len, use_kernel=use_kernel, paged=paged))
    if chunk <= 0:
        return step, None, None

    def step_chunk(p, c, st, carry, xc, start, ln, cbt, temp, top_k, seed):
        c, st, nxt, done = model.fused_decode_step(
            p, c, st, cache_len=cache_len, use_kernel=use_kernel,
            paged=paged)
        c_out, carry, c = model.prefill_chunk(p, c, carry, xc, start, ln,
                                              cbt, use_kernel=use_kernel)
        first = pick_first(c_out, temp, top_k, seed)
        return c, st, nxt, done, first, carry

    def chunk_only(p, c, carry, xc, start, ln, cbt, temp, top_k, seed):
        c_out, carry, c = model.prefill_chunk(p, c, carry, xc, start, ln,
                                              cbt, use_kernel=use_kernel)
        return pick_first(c_out, temp, top_k, seed), carry, c

    return step, jax.jit(step_chunk), jax.jit(chunk_only)


def make_verify_fns(model: Model, cache_len: int, *,
                    use_kernel: bool = False):
    """The jitted speculative verify step one SlotServer runs on (shared
    across the pods of a top-1 DecentralizedSlotServer, like
    ``make_fused_fns``): ``verify(params, cache, state, drafts)`` →
    ``(cache, state, toks, n_emit, done)`` — the span forward over
    ``[committed token, drafts]`` plus the accept/reject epilogue in one
    dispatch (``Model.fused_verify_step``). Traces once per drafts width,
    which is fixed at ``spec_len - 1`` for an engine's lifetime."""
    return jax.jit(lambda p, c, st, drafts: model.fused_verify_step(
        p, c, st, drafts, cache_len=cache_len, use_kernel=use_kernel))


class SlotServer(_SlotTable):
    """Continuous batching over ONE expert / model (greedy decoding).

    ``page_block > 0`` switches the attention KV leaves to the paged cache:
    ``pool_blocks`` physical blocks of ``page_block`` positions shared by
    all slots (0 → sized for full capacity, i.e. no admission blocking).

    ``chunk > 0`` switches admission to chunked prefill: the prompt is
    consumed ``chunk`` positions at a time, written straight into the paged
    pool, and each chunk rides the same jitted dispatch as the lockstep
    decode — no more stop-the-world prefill. ``token_budget`` bounds the
    per-step token work (decoding slots + chunk).

    ``prefix_cache=True`` (needs paging + chunked prefill) makes the pool
    blocks content-addressed and shareable: admissions whose prompts share
    a cached prefix map the shared blocks read-only and start chunked
    prefill at the first uncached position. Families whose decode state
    accumulates outside the pool (ssm, hybrid — see
    ``Model.prefix_cacheable``) degrade to the uncached path.
    """

    def __init__(self, model: Model, params, n_slots: int = 0,
                 cache_len: int = 0, *, use_kernel: bool = False,
                 serve_fns=None, page_block: int = 0, pool_blocks: int = 0,
                 chunk: int = 0, token_budget: int = 0, chunk_fns=None,
                 prefix_cache: bool = False, fused_step: bool = True,
                 fused_fns=None, verify_fns=None,
                 config: Optional[EngineConfig] = None, pod: int = 0):
        if config is None:
            config = _legacy_config(
                n_slots, cache_len, page_block=page_block,
                pool_blocks=pool_blocks, chunk=chunk,
                token_budget=token_budget, prefix_cache=prefix_cache,
                fused_step=fused_step, use_kernel=use_kernel)
        config.validate(model)
        self.config = config
        n_slots, cache_len = config.n_slots, config.cache_len
        use_kernel = config.use_kernel
        page_block = effective_page_block(
            model, config.page_block if config.paged else 0)
        chunk = config.chunk if config.chunked_prefill else 0
        super().__init__(n_slots, cache_len, block_size=page_block,
                         n_blocks=config.pool_blocks,
                         window=model.cfg.sliding_window, chunk=chunk,
                         token_budget=config.token_budget,
                         prefix_cache=config.prefix_cache
                         and model.prefix_cacheable,
                         sanitize=config.sanitize,
                         qos=config.qos, preemption=config.preemption,
                         obs=EngineObs(pod=pod, trace=config.trace,
                                       trace_ring=config.trace_ring,
                                       publish=config.metrics))
        self.model, self.params = model, params
        self.use_kernel = use_kernel
        if self.paged:
            self.cache = model.init_paged_cache(
                n_slots, self.allocator.n_blocks, page_block, cache_len)
            self.spec = model.cache_spec(page_block)
        else:
            self.cache = model.init_cache(n_slots, cache_len)
            self.spec = model.cache_spec()
        self._prefill, self._decode = serve_fns or make_serve_fns(
            model, cache_len, use_kernel=use_kernel, paged=self.paged)
        if self.chunked:
            self._prep, self._fused, self._chunk_only = \
                chunk_fns or make_chunk_fns(model, cache_len, chunk,
                                            use_kernel=use_kernel,
                                            paged=self.paged)
        self.fused = config.fused_step
        if self.fused:
            self._fstep, self._fstep_chunk, self._fchunk_only = \
                fused_fns or make_fused_fns(model, cache_len, chunk,
                                            use_kernel=use_kernel,
                                            paged=self.paged)
        self._init_speculation(
            config, model,
            lambda: verify_fns or make_verify_fns(model, cache_len,
                                                  use_kernel=use_kernel))

    def admit(self, req: Request) -> bool:
        """Admit a request into a free slot. Monolithic: prefill it alone
        and insert its decode state. Chunked: embed the prompt, reserve its
        blocks, and park the slot mid-prefill — the step loop consumes the
        prompt chunk by chunk. False when no slot — or, paged, not enough
        free blocks."""
        free = self.free_slots()
        if not free:
            return False
        slot = free[0]
        width = self._prefill_width(req)
        if self.chunked:
            return self._admit_chunked(
                req, slot, width, lambda b: self._prep(self.params, b))
        if not self._admission_precheck(req, slot, width):
            return False
        logits, row_cache = self._prefill(self.params, req.batch())
        # first token from the prompt's last position (greedy / sampled)
        first = self._pick_first(req, logits[0, -1])
        # logits width = positions consumed (incl. any image prefix)
        assert logits.shape[1] == width, (logits.shape, width)
        if width == self.cache_len:
            self._retire_at_admission(req, first)
            return True
        self._admit_prefilled(slot, req, first, width, row_cache)
        return True

    def _run_fused(self, st):
        self.cache, self._dstate, nxt, done = self._fstep(
            self.params, self.cache, st)
        return nxt, done

    def _run_verify(self, st, drafts):
        self.cache, self._dstate, toks, n_emit, done = self._vstep(
            self.params, self.cache, st, drafts)
        return toks, n_emit, done

    def _run_fused_chunk(self, st, slot, xc, start, length, cbt, pick):
        (self.cache, self._dstate, nxt, done, first,
         self.prefill_carry[slot]) = self._fstep_chunk(
            self.params, self.cache, st, self.prefill_carry[slot], xc,
            start, length, cbt, *pick)
        return nxt, done, first

    def _run_chunk_only(self, slot, xc, start, length, cbt, pick):
        first, self.prefill_carry[slot], self.cache = self._fchunk_only(
            self.params, self.cache, self.prefill_carry[slot], xc, start,
            length, cbt, *pick)
        return first

    def _decode_step(self) -> List[Request]:
        """One raw scheduler dispatch. Monolithic: lockstep decode over
        every active slot. Chunked: co-schedule the lockstep decode with
        one prefill chunk under the token budget, in a single jitted
        dispatch. Fused (the default): the host epilogue rides the same
        dispatch too — see ``_decode_step_fused``. Returns requests
        retired this step."""
        if self.fused:
            return self._decode_step_fused()
        dec = self.decoding
        do_chunk = self.chunked and self._schedule_chunk()
        if not dec and not do_chunk:
            return []
        if do_chunk:
            slot, xc, start, length, cbt = self._chunk_args()
            if not dec:
                c_out, carry, self.cache = self._chunk_only(
                    self.params, self.cache, self.prefill_carry[slot], xc,
                    start, length, cbt)
                self.prefill_carry[slot] = carry
                return self._after_chunk(slot, length, c_out)
            self._grow_active()
            if self.paged:
                d_logits, c_out, carry, self.cache = self._fused(
                    self.params, self.cache, jnp.asarray(self.last_tok),
                    jnp.asarray(self.pos),
                    jnp.asarray(self._decode_tables()[:, :self._nb_live()]),
                    self.prefill_carry[slot], xc, start, length, cbt)
            else:
                d_logits, c_out, carry, self.cache = self._fused(
                    self.params, self.cache, jnp.asarray(self.last_tok),
                    jnp.asarray(self.pos), self.prefill_carry[slot], xc,
                    start, length, cbt)
            self.prefill_carry[slot] = carry
            nxt = self._next_tokens(d_logits)
            retired = self._advance(nxt)
            retired += self._after_chunk(slot, length, c_out)
            return retired
        if self.paged:
            self._grow_active()
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(self.last_tok),
                jnp.asarray(self.pos),
                jnp.asarray(self._decode_tables()[:, :self._nb_live()]))
        else:
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(self.last_tok),
                jnp.asarray(self.pos))
        return self._advance(self._next_tokens(logits))


class MixtureSlotServer(_SlotTable):
    """Continuous batching over the STACKED expert ensemble: one cache
    carrying the expert (K) dim, one jitted vmapped decode step with the
    Eq. 27 mixture fused in, per-slot router weights fixed at admission.
    In the paged layout the block pool carries the K dim too, and all K
    experts of a slot share ONE block table."""

    def __init__(self, model: Model, expert_params: List[Any], router,
                 n_slots: int = 0, cache_len: int = 0, *,
                 use_kernel: bool = False, page_block: int = 0,
                 pool_blocks: int = 0, chunk: int = 0,
                 token_budget: int = 0, prefix_cache: bool = False,
                 fused_step: bool = True,
                 config: Optional[EngineConfig] = None, pod: int = 0):
        if config is None:
            config = _legacy_config(
                n_slots, cache_len, page_block=page_block,
                pool_blocks=pool_blocks, chunk=chunk,
                token_budget=token_budget, prefix_cache=prefix_cache,
                fused_step=fused_step, use_kernel=use_kernel,
                strategy="mixture")
        config.validate(model)
        self.config = config
        n_slots, cache_len = config.n_slots, config.cache_len
        use_kernel = config.use_kernel
        page_block = effective_page_block(
            model, config.page_block if config.paged else 0)
        chunk = config.chunk if config.chunked_prefill else 0
        super().__init__(n_slots, cache_len, block_size=page_block,
                         n_blocks=config.pool_blocks,
                         window=model.cfg.sliding_window, chunk=chunk,
                         token_budget=config.token_budget,
                         prefix_cache=config.prefix_cache
                         and model.prefix_cacheable,
                         sanitize=config.sanitize,
                         qos=config.qos, preemption=config.preemption,
                         obs=EngineObs(pod=pod, trace=config.trace,
                                       trace_ring=config.trace_ring,
                                       publish=config.metrics))
        self._seq_axis = 2      # embedded prompts carry K at axis 0
        self._from_probs = True  # the mixed scores are Eq. 27 probabilities
        self._needs_features = True   # admission routes on features
        self.model, self.router = model, router
        self.K = len(expert_params)
        self.use_kernel = use_kernel
        self.stacked, param_axes, self._prefill_all, self._mix_decode = \
            make_stacked_serving(model, expert_params, cache_len,
                                 use_kernel=use_kernel, paged=self.paged)
        chunk_all = None
        if self.chunked:
            self._prep_all, chunk_all = \
                make_stacked_chunk_fns(model, self.stacked, param_axes,
                                       cache_len, chunk,
                                       use_kernel=use_kernel)
            mix_decode = self._mix_decode
            if self.paged:
                def fused(sp, c, toks, pos, w, dbt, carry, xc, start, ln,
                          cbt, w_row):
                    probs, c = mix_decode(sp, c, toks, pos, w, dbt)
                    c_probs, carry, c = chunk_all(sp, c, carry, xc, start,
                                                  ln, cbt, w_row)
                    return probs, c_probs, carry, c
            else:
                def fused(sp, c, toks, pos, w, carry, xc, start, ln, cbt,
                          w_row):
                    probs, c = mix_decode(sp, c, toks, pos, w)
                    c_probs, carry, c = chunk_all(sp, c, carry, xc, start,
                                                  ln, cbt, w_row)
                    return probs, c_probs, carry, c
            self._fused_mix = jax.jit(fused)
            self._chunk_only_mix = jax.jit(chunk_all)
        self.fused = config.fused_step
        if self.fused:
            self._fstep, self._fstep_chunk, self._fchunk_only = \
                make_stacked_fused(model, param_axes, cache_len,
                                   chunk_all=chunk_all,
                                   use_kernel=use_kernel, paged=self.paged)
        self._init_speculation(
            config, model,
            lambda: make_stacked_verify(
                model, param_axes, cache_len, config.spec_len,
                use_kernel=use_kernel,
                expert_draft=config.speculative == "expert"))
        # expert (K) dim at axis 1, AFTER each leaf's scan dim — the layout
        # the vmapped scanned decode consumes without per-step transposes
        shapes = model.paged_cache_shapes(
            n_slots, self.allocator.n_blocks, page_block, cache_len) \
            if self.paged else model.cache_shapes(n_slots, cache_len)
        self.cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape[:1] + (self.K,) + s.shape[1:],
                                s.dtype), shapes)
        # batch/seq axes move by 1 under the K dim
        self.spec = model.cache_spec(page_block).shifted(1)
        self.weights = np.zeros((n_slots, self.K), dtype=np.float32)
        self._mix = jax.jit(mix_expert_logits)

    def admit(self, req: Request) -> bool:
        free = self.free_slots()
        if not free:
            return False
        if req.features is None:
            raise ValueError("mixture admission routes on request features")
        slot = free[0]
        width = self._prefill_width(req)
        if self.chunked:
            if not self._admit_chunked(
                    req, slot, width,
                    lambda b: self._prep_all(self.stacked, b)):
                return False
            # device_get is the explicit sync for the host weights mirror
            # — np.asarray of the device row was an implicit one (repro-
            # lint host-sync)
            w = jax.device_get(
                self.router.route(jnp.asarray(req.features[None])))
            self.weights[slot] = w[0]
            return True
        if not self._admission_precheck(req, slot, width):
            return False
        # route only once admission is paying for the prefill — a request
        # blocked on free KV blocks must not re-run the router every retry
        w = jax.device_get(
            self.router.route(jnp.asarray(req.features[None])))   # (1, K)
        logits, row_cache = self._prefill_all(self.stacked, req.batch())
        probs = self._mix(logits[:, :, -1], w)                    # (1, V)
        first = self._pick_first(req, probs[0], from_probs=True)
        assert logits.shape[2] == width, (logits.shape, width)
        if width == self.cache_len:
            self._retire_at_admission(req, first)
            return True
        self.weights[slot] = w[0]
        self._admit_prefilled(slot, req, first, width, row_cache)
        return True

    def _state_extras(self, st):
        st["weights"] = jnp.asarray(self.weights)
        return st

    def _park_extras(self, slot: int) -> Dict[str, Any]:
        # the router-weight row is per-slot host state a swap resume
        # cannot rebuild (recompute resumes re-route on the features)
        return {"weights": self.weights[slot].copy()}

    def _restore_extras(self, slot: int, extras: Dict[str, Any]) -> None:
        if "weights" in extras:
            self.weights[slot] = extras["weights"]

    def _run_fused(self, st):
        self.cache, self._dstate, nxt, done = self._fstep(
            self.stacked, self.cache, st)
        return nxt, done

    def _run_verify(self, st, drafts):
        # drafts is None when expert 0 drafts on device (speculative=
        # "expert"); the n-gram variant takes the host drafts argument
        out = self._vstep(self.stacked, self.cache, st) if drafts is None \
            else self._vstep(self.stacked, self.cache, st, drafts)
        self.cache, self._dstate, toks, n_emit, done = out
        return toks, n_emit, done

    def _run_fused_chunk(self, st, slot, xc, start, length, cbt, pick):
        w_row = jnp.asarray(self.weights[slot:slot + 1])
        (self.cache, self._dstate, nxt, done, first,
         self.prefill_carry[slot]) = self._fstep_chunk(
            self.stacked, self.cache, st, self.prefill_carry[slot], xc,
            start, length, cbt, w_row, *pick)
        return nxt, done, first

    def _run_chunk_only(self, slot, xc, start, length, cbt, pick):
        w_row = jnp.asarray(self.weights[slot:slot + 1])
        first, self.prefill_carry[slot], self.cache = self._fchunk_only(
            self.stacked, self.cache, self.prefill_carry[slot], xc, start,
            length, cbt, w_row, *pick)
        return first

    def _decode_step(self) -> List[Request]:
        if self.fused:
            return self._decode_step_fused()
        dec = self.decoding
        do_chunk = self.chunked and self._schedule_chunk()
        if not dec and not do_chunk:
            return []
        if do_chunk:
            slot, xc, start, length, cbt = self._chunk_args()
            w_row = jnp.asarray(self.weights[slot:slot + 1])
            if not dec:
                c_out, carry, self.cache = self._chunk_only_mix(
                    self.stacked, self.cache, self.prefill_carry[slot], xc,
                    start, length, cbt, w_row)
                self.prefill_carry[slot] = carry
                return self._after_chunk(slot, length, c_out)
            self._grow_active()
            if self.paged:
                probs, c_out, carry, self.cache = self._fused_mix(
                    self.stacked, self.cache, jnp.asarray(self.last_tok),
                    jnp.asarray(self.pos), jnp.asarray(self.weights),
                    jnp.asarray(self._decode_tables()[:, :self._nb_live()]),
                    self.prefill_carry[slot], xc, start, length, cbt, w_row)
            else:
                probs, c_out, carry, self.cache = self._fused_mix(
                    self.stacked, self.cache, jnp.asarray(self.last_tok),
                    jnp.asarray(self.pos), jnp.asarray(self.weights),
                    self.prefill_carry[slot], xc, start, length, cbt, w_row)
            self.prefill_carry[slot] = carry
            retired = self._advance(self._next_tokens(probs,
                                                      from_probs=True))
            retired += self._after_chunk(slot, length, c_out)
            return retired
        if self.paged:
            self._grow_active()
            probs, self.cache = self._mix_decode(
                self.stacked, self.cache, jnp.asarray(self.last_tok),
                jnp.asarray(self.pos), jnp.asarray(self.weights),
                jnp.asarray(self._decode_tables()[:, :self._nb_live()]))
        else:
            probs, self.cache = self._mix_decode(
                self.stacked, self.cache, jnp.asarray(self.last_tok),
                jnp.asarray(self.pos), jnp.asarray(self.weights))
        return self._advance(self._next_tokens(probs, from_probs=True))


class DecentralizedSlotServer:
    """Front-end centroid router over continuously-batched expert pods.

    strategy="top1"    — grouped top-1 (compute-matched): one ``SlotServer``
                         per expert pod; each request decodes on exactly the
                         expert the router assigns it.
    strategy="mixture" — general top-k: the stacked-expert mixture core.

    ``page_block > 0`` switches every pod (or the mixture core) to the
    paged KV cache; ``pool_blocks`` is per pod. ``prefix_cache=True``
    gives every pod its own radix prefix cache (the mixture core shares
    one across all K stacked experts — the pool carries the ``dexpert``
    dim, so a shared prefix block is shared for all K at once); the
    per-expert routing concentrates similar requests on the same pods,
    which is exactly what makes the per-pod caches hit.
    """

    def __init__(self, model: Model, expert_params: List[Any], router,
                 n_slots: int = 0, cache_len: int = 0, *,
                 strategy: str = "top1", use_kernel: bool = False,
                 page_block: int = 0, pool_blocks: int = 0, chunk: int = 0,
                 token_budget: int = 0, prefix_cache: bool = False,
                 fused_step: bool = True,
                 config: Optional[EngineConfig] = None):
        if config is None:
            config = _legacy_config(
                n_slots, cache_len, page_block=page_block,
                pool_blocks=pool_blocks, chunk=chunk,
                token_budget=token_budget, prefix_cache=prefix_cache,
                fused_step=fused_step, use_kernel=use_kernel,
                strategy=strategy)
        config.validate(model)
        self.config = config
        self.model, self.router = model, router
        self.K = len(expert_params)
        self.strategy = config.strategy
        self._next_rid = 0
        if self.strategy == "top1":
            eff_block = effective_page_block(
                model, config.page_block if config.paged else 0)
            cache_len, chunk = config.cache_len, \
                config.chunk if config.chunked_prefill else 0
            fns = make_serve_fns(model, cache_len,
                                 use_kernel=config.use_kernel,
                                 paged=eff_block > 0)
            cfns = make_chunk_fns(model, cache_len, chunk,
                                  use_kernel=config.use_kernel,
                                  paged=eff_block > 0) if chunk > 0 \
                else None
            ffns = make_fused_fns(model, cache_len, chunk,
                                  use_kernel=config.use_kernel,
                                  paged=eff_block > 0) \
                if config.fused_step else None
            vfns = make_verify_fns(model, cache_len,
                                   use_kernel=config.use_kernel) \
                if (config.speculative is not None and config.spec_len > 1
                    and config.fused_step and eff_block > 0
                    and model.speculative_capable) else None
            # pod=k labels each pod's registry/trace track (pid=k in the
            # merged Perfetto export) so per-expert load is attributable
            self.pods = [SlotServer(model, p, config=config,
                                    serve_fns=fns, chunk_fns=cfns,
                                    fused_fns=ffns, verify_fns=vfns,
                                    pod=k)
                         for k, p in enumerate(expert_params)]
        else:
            self.core = MixtureSlotServer(model, expert_params, router,
                                          config=config, pod=0)

    def route(self, queue: List[Request]) -> np.ndarray:
        feats = np.stack([r.features for r in queue])
        return np.asarray(self.router.top1(jnp.asarray(feats)))

    # ------------------------------------------------------------------
    # Incremental API: the front-end router runs at submission time
    # ------------------------------------------------------------------

    def add_request(self, prompt, params: Optional[SamplingParams] = None,
                    extras: Optional[Dict[str, np.ndarray]] = None, *,
                    features: Optional[np.ndarray] = None,
                    rid: Optional[int] = None) -> int:
        """Submit a request: the Eq. 28 centroid router assigns it at the
        front end — to its top-1 expert's pod, or (mixture) straight into
        the stacked core's queue."""
        if self.strategy == "mixture":
            rid = self.core.add_request(prompt, params, extras,
                                        features=features, rid=rid)
            self._next_rid = self.core._next_rid
            return rid
        req = _as_request(prompt, params, extras, features,
                          self._next_rid if rid is None else rid)
        if req.features is None:
            raise ValueError(_FEATURES_MSG.format(rid=req.rid))
        self._next_rid = max(self._next_rid, req.rid + 1)
        # submission is now, not when the pod sees the request — the
        # front-end routing dispatch must count toward TTFT
        req.t_submit = req.t_submit or time.perf_counter()
        k = int(np.asarray(self.router.top1(
            jnp.asarray(np.asarray(req.features)[None])))[0])
        return self.pods[k].add_request(req)

    def step(self) -> List[RequestOutput]:
        """One step of every pod (in pod order — admission then the fused
        dispatch, exactly the legacy drive loop's schedule), concatenating
        their streamed outputs."""
        if self.strategy == "mixture":
            return self.core.step()
        outs: List[RequestOutput] = []
        for pod in self.pods:
            outs += pod.step()
        return outs

    def abort(self, rid: int) -> Optional[RequestOutput]:
        """Cancel a request on whichever pod holds it (no-op → None)."""
        if self.strategy == "mixture":
            return self.core.abort(rid)
        for pod in self.pods:
            out = pod.abort(rid)
            if out is not None:
                return out
        return None

    def has_unfinished(self) -> bool:
        if self.strategy == "mixture":
            return self.core.has_unfinished()
        return any(pod.has_unfinished() for pod in self.pods)

    def serve(self, queue: List[Request], *, max_steps: int = 10_000
              ) -> Dict[int, List[int]]:
        """Drain loop over the incremental API (see ``_SlotTable.serve``);
        requests are routed to their pods at submission."""
        if not queue:
            return {}
        if self.strategy == "mixture":
            return self.core.serve(queue, max_steps=max_steps)
        self.reset_stats()
        for req in queue:
            self.add_request(req)
        finished: Dict[int, List[int]] = {}
        reasons: Dict[int, str] = {}
        for _ in range(max_steps):
            for out in self.step():
                if out.finished:
                    finished[out.rid] = out.token_ids
                    reasons[out.rid] = out.finish_reason
            if not self.has_unfinished():
                break
        dropped = [f"{r.rid} (queued)"
                   for pod in self.pods for r in pod.waiting] + \
            [d for pod in self.pods for d in pod._drop_details()]
        if dropped:
            _raise_dropped(dropped, len(finished), max_steps)
        logger.info("serve: %d finished (finish_reasons %s), pods %s",
                    len(finished), reasons, self.occupancy())
        return finished

    def occupancy(self) -> List[Dict[str, Any]]:
        """Per-pod serving stats (one dict per top-1 pod, or one for the
        mixture core): ``active`` slots, and — when paged —
        ``pool_free_blocks`` / ``pool_blocks``, plus the prefix-cache
        counters (``prefix_hit_rate``, ``prefix_skipped_tokens``, …) when
        the cache is on."""
        pods = [self.core] if self.strategy == "mixture" else self.pods
        return [p.stats() for p in pods]

    # ------------------------------------------------------------------
    # Observability (see docs/observability.md)
    # ------------------------------------------------------------------

    def _engines(self) -> List[_SlotTable]:
        return [self.core] if self.strategy == "mixture" else self.pods

    def reset_stats(self) -> None:
        """Per-run counter hygiene across every pod (see
        ``_SlotTable.reset_stats``); ``serve()`` calls this at entry."""
        for p in self._engines():
            p.reset_stats()

    def export_trace(self, path: Optional[str] = None) -> Dict[str, Any]:
        """Merged Chrome/Perfetto trace over every pod — each pod keeps
        its own ``pid``, so ui.perfetto.dev shows one process group per
        expert pod. Written to ``path`` when given."""
        doc = merge_chrome([p.obs.trace for p in self._engines()])
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc

    def export_metrics(self, path: Optional[str] = None) -> Dict[str, Any]:
        """Merged metrics snapshot over every pod's registry (series stay
        distinguished by their ``pod`` label)."""
        doc = _obs_metrics.snapshot([p.obs.registry
                                     for p in self._engines()])
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f, indent=2)
        return doc

    def prometheus_metrics(self) -> str:
        """Prometheus text exposition over every pod's registry."""
        return _obs_metrics.prometheus([p.obs.registry
                                        for p in self._engines()])


def make_engine(model: Model, params: Any = None, *,
                experts: Optional[List[Any]] = None, router=None,
                config: Optional[EngineConfig] = None):
    """Build the serving engine a deployment needs from ONE validated
    ``EngineConfig`` — replacing the three hand-wired constructors.

    * ``make_engine(model, params, config=cfg)`` — a single-model
      ``SlotServer``.
    * ``make_engine(model, experts=[...], router=r, config=cfg)`` — the
      decentralized deployment (paper §5.2): ``cfg.strategy == "top1"``
      builds one pod per expert behind the Eq. 28 front-end router
      (sharing the jitted serve/chunk fns across pods);
      ``"mixture"`` builds the stacked-expert Eq. 27 core.

    Every engine returned speaks the same incremental API:
    ``add_request`` / ``step`` / ``abort`` / ``has_unfinished`` (plus the
    legacy ``serve(queue)`` drain wrapper).
    """
    config = config if config is not None else EngineConfig()
    config.validate(model)
    if experts is not None:
        if router is None:
            raise ValueError(
                "decentralized serving routes on the centroid router — "
                "pass router= alongside experts=")
        return DecentralizedSlotServer(model, experts, router,
                                       config=config)
    if params is None:
        raise ValueError(
            "single-model serving needs the model's params (or pass "
            "experts= and router= for the decentralized deployment)")
    return SlotServer(model, params, config=config)
