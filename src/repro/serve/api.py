"""The online serving API: the typed objects every engine speaks.

After PRs 1–4 the serving stack could page, chunk, and share KV — but the
public surface was still batch-drain only: ``serve(queue)`` consumed a
pre-built list of ``Request``s to completion, callers saw tokens only at
retirement, and every feature rode in as another constructor kwarg
validated ad hoc. This module is the stable client-facing contract the
schedulers now implement:

* ``SamplingParams`` — per-request decoding controls (budget, temperature,
  top-k, seed, stop/eos token ids). Replaces the ad-hoc fields scattered
  on ``Request``.
* ``EngineConfig`` — per-engine deployment knobs (slots, context, paging,
  chunked prefill, prefix cache, kernels) with ONE ``validate()`` that
  owns the whole feature-dependency matrix and raises actionable errors
  naming the missing prerequisite.
* ``TokenDelta`` / ``RequestOutput`` — what ``step()`` streams back: the
  tokens newly decoded for a request this step (each stamped for TTFT /
  inter-token-latency measurement), the cumulative output ids, and — once
  finished — a ``finish_reason`` in {``length``, ``stop``, ``aborted``,
  ``truncated``}.

The engines themselves (``SlotServer``, ``MixtureSlotServer``,
``DecentralizedSlotServer`` and the ``make_engine`` factory) live in
``repro.serve.scheduler``; they expose the incremental request-lifecycle
primitives

    rid = engine.add_request(prompt, SamplingParams(...), features=...)
    for out in engine.step(): ...      # per-token deltas, not retirements
    engine.abort(rid)                  # frees slot/blocks/prefix refs
    engine.has_unfinished()

and the legacy ``serve(queue)`` is a thin drain loop over exactly these.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import FrozenSet, List, Optional, Tuple

import jax
import numpy as np

from repro.serve.qos import QoSConfig

__all__ = ["EngineConfig", "QoSConfig", "RequestOutput", "SamplingParams",
           "TokenDelta", "FINISH_REASONS", "STOP_PAD",
           "effective_page_block", "stop_id_row"]

#: Pad value for the device-side per-slot stop-id matrix. Token ids are
#: non-negative, so pad entries can never match a decoded token.
STOP_PAD = -1

#: The closed set of reasons a request can finish with.
#:   length    — decoded its full ``max_new`` budget
#:   stop      — emitted a stop/eos token id before the budget
#:   aborted   — ``abort(rid)`` cancelled it (queued, mid-prefill or
#:               mid-decode)
#:   truncated — hit the serving context bound ``cache_len`` first
#:   rejected  — admission control refused it at submission (queue depth
#:               or predicted-TTFT SLO, see ``QoSConfig``); no tokens
FINISH_REASONS = ("length", "stop", "aborted", "truncated", "rejected")


@dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding controls.

    ``temperature <= 0`` is greedy decoding (the parity-exact default);
    otherwise sampling is seeded per request — token ``i`` draws from
    ``fold_in(PRNGKey(seed), i)``, so a request's continuation depends
    only on (seed, scores), never on slot placement or co-scheduled
    traffic. ``top_k == 0`` samples the full vocabulary; ``top_k == 1``
    is exactly greedy.

    ``stop_token_ids`` (plus the conventional ``eos_token_id``, folded
    into the same set) retire the request as soon as one is *generated*
    (prompt tokens never trigger), with ``finish_reason == "stop"``. The
    stop token itself is kept in the output.

    ``tenant`` names the fair-share accounting bucket and ``priority``
    (higher = more urgent) arms preemption: under pool pressure a
    strictly-lower-priority decoding request may be parked to make room
    (see ``QoSConfig`` / ``EngineConfig.preemption``). Neither affects
    the tokens a request produces — seeded sampling draws from
    ``fold_in(seed, token_index)``, so a preempted-and-resumed request
    replays token-for-token.
    """

    max_new: int = 16
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0
    stop_token_ids: Tuple[int, ...] = ()
    eos_token_id: Optional[int] = None
    priority: int = 0
    tenant: str = "default"

    def __post_init__(self):
        if self.max_new < 1:
            raise ValueError(
                f"max_new must be >= 1 (every request emits at least its "
                f"prefill token), got {self.max_new}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 = full vocabulary), "
                             f"got {self.top_k}")
        if not self.tenant:
            raise ValueError("tenant must be a non-empty string")
        stops = frozenset(int(t) for t in self.stop_token_ids)
        if self.eos_token_id is not None:
            stops |= {int(self.eos_token_id)}
        object.__setattr__(self, "stop_set", stops)

    stop_set: FrozenSet[int] = field(init=False, repr=False, compare=False,
                                     default=frozenset())


def stop_id_row(params: SamplingParams, width: int) -> np.ndarray:
    """The (width,) int32 device encoding of ``params.stop_set``: the stop
    ids sorted and left-aligned, the remainder padded with ``STOP_PAD``.
    The fused decode step checks membership with one broadcast compare
    against this row — the device half of the stop semantics documented on
    ``SamplingParams`` (the scheduler only ever consults it for tokens the
    request *generated*, so prompt tokens still never trigger)."""
    ids = sorted(params.stop_set)
    if len(ids) > width:
        raise ValueError(
            f"stop-id row width {width} cannot hold {len(ids)} stop ids")
    row = np.full(width, STOP_PAD, np.int32)
    row[:len(ids)] = ids
    return row


@dataclass(frozen=True)
class EngineConfig:
    """Engine deployment knobs + the ONE place their dependency matrix is
    enforced.

    ``validate()`` replaces the checks that used to be scattered across
    ``_SlotTable.__init__``, ``_validate_chunked`` and the launcher: a bad
    combination raises a single ``ValueError`` that names the missing
    prerequisite. The config-only rules always run; passing the model runs
    the model-dependent ones too (cache-family paging, recurrent chunk
    alignment, sliding windows).
    """

    n_slots: int = 8
    cache_len: int = 128
    # -- paged KV cache (PR 2)
    paged: bool = False
    page_block: int = 16
    pool_blocks: int = 0          # 0 → full capacity (never admission-blocks)
    # -- chunked-prefill continuous batching (PR 3)
    chunked_prefill: bool = False
    chunk: int = 16
    token_budget: int = 0         # 0 → n_slots + chunk (always co-schedules)
    # -- radix prefix cache (PR 4)
    prefix_cache: bool = False
    # -- fused single-dispatch decode step (PR 6)
    fused_step: bool = True       # False → legacy host epilogue (parity ref)
    # -- PoolSanitizer (PR 7): debug-mode per-step ownership scan over the
    #    paged pool (repro.analysis.sanitizer); violations raise
    sanitize: bool = False
    # -- speculative decoding (PR 8): draft spec_len - 1 candidate tokens
    #    ("ngram": prompt-lookup from the request's own history; "expert":
    #    the stacked mixture's expert 0 drafts on-device) and verify the
    #    whole span in one dispatch — token-for-token identical outputs,
    #    fewer dispatches per token. Families whose decode state cannot be
    #    positionally rolled back (ssm/hybrid, sliding windows) degrade to
    #    vanilla decode; spec_len == 1 IS vanilla decode.
    speculative: Optional[str] = None   # None | "ngram" | "expert"
    spec_len: int = 4
    # -- telemetry (PR 9): per-request span tracing + metrics exposition
    #    (repro.obs). ``trace=True`` attaches a bounded ring-buffer span
    #    recorder (Chrome/Perfetto trace_event export via
    #    ``engine.export_trace``); ``metrics=True`` publishes the engine's
    #    private registry to the process-global exposition set
    #    (``repro.obs.default_registry``). Both default off; the internal
    #    registry itself is always on (near-zero cost) so ``stats()`` can
    #    be a view over it.
    trace: bool = False
    trace_ring: int = 65536       # span ring capacity (oldest events drop)
    metrics: bool = False
    # -- multi-tenant QoS (PR 10): deficit-round-robin fair sharing over
    #    tenants + SLO-aware admission control (repro.serve.qos), and
    #    priority preemption of decoding requests under pool pressure:
    #    "swap" parks the victim's private KV blocks host-side, "recompute"
    #    drops them and replays through chunked prefill + the prefix cache.
    #    Either way resumed requests are token-for-token identical.
    qos: Optional[QoSConfig] = None
    preemption: str = "off"       # "off" | "recompute" | "swap"
    # -- misc
    use_kernel: bool = False
    strategy: str = "top1"        # decentralized engines: "top1" | "mixture"

    def validate(self, model=None) -> None:
        """Raise ``ValueError`` on an inconsistent configuration. Pass the
        model to additionally run the model-dependent checks (they need
        the cache descriptor / architecture config)."""
        if self.n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {self.n_slots}")
        if self.cache_len < 2:
            raise ValueError(
                f"cache_len must be >= 2 (one prompt position plus one "
                f"decodable position), got {self.cache_len}")
        if self.paged and self.page_block < 1:
            raise ValueError(
                f"paged serving needs page_block >= 1 positions per KV "
                f"block, got {self.page_block}")
        if self.pool_blocks and not self.paged:
            raise ValueError(
                "pool_blocks sizes the paged block pool — it needs "
                "paged=True (page_block > 0)")
        if self.paged and self.pool_blocks == 1:
            raise ValueError(
                "pool_blocks=1 is only the reserved scratch block — a "
                "paged pool needs >= 2 blocks (or 0 for full capacity)")
        if self.chunked_prefill and self.chunk < 1:
            raise ValueError(
                f"chunked prefill needs chunk >= 1 prompt positions per "
                f"step, got {self.chunk}")
        if self.token_budget < 0:
            raise ValueError(
                f"token_budget must be >= 0, got {self.token_budget}")
        if self.token_budget and not self.chunked_prefill:
            raise ValueError(
                "token_budget bounds the chunked-prefill step loop — it "
                "needs chunked_prefill=True (chunk > 0)")
        if self.prefix_cache and not (self.paged and self.chunked_prefill):
            raise ValueError(
                "the prefix cache shares prompt KV through the paged pool "
                "and fills misses with chunked prefill — enable paging "
                "(page_block > 0) and chunked prefill (chunk > 0)")
        if self.sanitize and not self.paged:
            raise ValueError(
                "sanitize=True runs the PoolSanitizer, which shadows the "
                "paged KV block pool — enable paging (page_block > 0)")
        if self.strategy not in ("top1", "mixture"):
            raise ValueError(
                f"strategy must be 'top1' or 'mixture', got "
                f"{self.strategy!r}")
        if self.speculative is not None:
            if self.speculative not in ("ngram", "expert"):
                raise ValueError(
                    f"speculative must be 'ngram' or 'expert', got "
                    f"{self.speculative!r}")
            if not self.paged:
                raise ValueError(
                    "speculative decoding verifies a multi-token span "
                    "through the paged block pool — enable paging "
                    "(page_block > 0)")
            if not self.fused_step:
                raise ValueError(
                    "speculative decoding runs draft + verify + accept "
                    "inside the fused dispatch — it needs fused_step=True")
            if self.speculative == "expert" and self.strategy != "mixture":
                raise ValueError(
                    "speculative='expert' drafts with the stacked "
                    "mixture's expert 0 — it needs strategy='mixture' "
                    "(single-model and top-1 engines have no expert "
                    "stack to draft from; use speculative='ngram')")
        if self.spec_len < 1:
            raise ValueError(
                f"spec_len must be >= 1 (1 = vanilla decode, L > 1 "
                f"verifies L - 1 drafts per step), got {self.spec_len}")
        if self.trace_ring < 1:
            raise ValueError(
                f"trace_ring must be >= 1 (the span recorder is a bounded "
                f"ring buffer), got {self.trace_ring}")
        if self.preemption not in ("off", "recompute", "swap"):
            raise ValueError(
                f"preemption must be 'off', 'recompute' or 'swap', got "
                f"{self.preemption!r}")
        if self.preemption != "off" and not self.paged:
            raise ValueError(
                "preemption parks/drops a victim's paged KV blocks — "
                "enable paging (page_block > 0)")
        if self.preemption == "recompute" and not self.chunked_prefill:
            raise ValueError(
                "preemption='recompute' resumes victims through chunked "
                "prefill — enable chunked_prefill (chunk > 0), or use "
                "preemption='swap'")
        if self.qos is not None and self.qos.max_predicted_ttft_s > 0 \
                and not self.chunked_prefill:
            raise ValueError(
                "the predicted-TTFT admission model meters the chunked-"
                "prefill token budget — max_predicted_ttft_s needs "
                "chunked_prefill=True (max_waiting works without it)")
        if model is not None:
            self._validate_model(model)

    def _validate_model(self, model) -> None:
        cfg = model.cfg
        eff_block = effective_page_block(
            model, self.page_block if self.paged else 0)
        if self.preemption != "off":
            if cfg.sliding_window > 0:
                raise ValueError(
                    "preemption does not support sliding-window (ring) "
                    "caches — a ring slot's blocks are positionally "
                    "wrapped, not droppable; serve windowed configs with "
                    "preemption='off'")
            if eff_block == 0:
                raise ValueError(
                    f"preemption parks/drops paged KV blocks but family "
                    f"'{cfg.family}' has no pageable cache leaves — serve "
                    f"it with preemption='off'")
        if not self.chunked_prefill:
            return
        if cfg.sliding_window > 0:
            raise ValueError(
                "chunked prefill does not support sliding-window (ring) "
                "caches yet — serve windowed configs with monolithic "
                "admission")
        has_pool = any(a >= 0 for a in
                       jax.tree.leaves(model.cache_spec(1).paged.seq_axes))
        if has_pool and eff_block == 0:
            raise ValueError(
                "chunked prefill writes prompt KV through the paged pool — "
                "enable paging (page_block > 0)")
        if cfg.family in ("ssm", "hybrid") and self.chunk % cfg.ssm.chunk:
            raise ValueError(
                f"prefill chunk {self.chunk} must be a multiple of the "
                f"chunkwise-scan length {cfg.ssm.chunk} for exact "
                f"chunked-vs-monolithic parity on family '{cfg.family}'")


def effective_page_block(model, page_block: int) -> int:
    """0 when the model has no pageable cache leaves (ssm: recurrent state
    only) — paging such a family would run pool accounting that backs no
    memory, so it degrades to the direct path instead."""
    if page_block <= 0:
        return 0
    seq_axes = model.cache_spec(page_block).paged.seq_axes
    return page_block if any(a >= 0 for a in jax.tree.leaves(seq_axes)) \
        else 0


@dataclass(frozen=True)
class TokenDelta:
    """One newly decoded token: its id, its 0-based index in the request's
    output stream, and the ``perf_counter`` stamp it was emitted at (the
    raw material for TTFT / inter-token latency)."""

    token: int
    index: int
    t: float


@dataclass
class RequestOutput:
    """One request's streaming update from ``step()`` (or ``abort()``).

    ``deltas`` holds only the tokens NEW since the last update for this
    request; ``token_ids`` is the full cumulative output. ``finished`` is
    terminal — after it, the request emits nothing further and its slot,
    pool blocks and prefix-cache references are already released.
    ``t_submit``/``t_admit``/``t_first``/``t_done`` are ``perf_counter``
    stamps (``t_admit``/``t_done`` are 0.0 until admitted/finished): TTFT
    is ``t_first - t_submit`` — measured from *submission*, so admission-
    backlogged requests report their queue wait, not a flattering
    from-admission number — and inter-token latencies are the diffs of
    consecutive delta stamps. ``queued_s`` isolates the queue-delay
    component of TTFT.
    """

    rid: int
    deltas: List[TokenDelta]
    token_ids: List[int]
    finished: bool
    finish_reason: Optional[str]     # one of FINISH_REASONS when finished
    t_submit: float
    t_first: float
    t_done: float
    t_admit: float = 0.0

    @property
    def ttft(self) -> float:
        """Seconds from submission to the first emitted token — NaN while
        (or if) no token was ever emitted, e.g. a request aborted straight
        out of the waiting queue."""
        return self.t_first - self.t_submit if self.t_first > 0 \
            else float("nan")

    # the explicit-unit alias; ``ttft`` predates the _s convention
    @property
    def ttft_s(self) -> float:
        return self.ttft

    @property
    def queued_s(self) -> float:
        """Seconds the request waited for admission (queue delay — the
        slice of TTFT spent before the engine even owned it). NaN until
        admitted; a pool-starved queue shows up here, not as missing
        TTFT."""
        return self.t_admit - self.t_submit if self.t_admit > 0 \
            else float("nan")
