from .engine import ServeEngine, serve_step_fn
from .ensemble_engine import DecentralizedServer
from .scheduler import (DecentralizedSlotServer, MixtureSlotServer, Request,
                        SlotServer)

__all__ = ["DecentralizedServer", "DecentralizedSlotServer",
           "MixtureSlotServer", "Request", "ServeEngine", "SlotServer",
           "serve_step_fn"]
