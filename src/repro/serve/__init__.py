from .engine import ServeEngine, serve_step_fn
from .ensemble_engine import DecentralizedServer
from .prefix_cache import PrefixCache, block_keys
from .scheduler import (DecentralizedSlotServer, MixtureSlotServer, Request,
                        SlotServer)

__all__ = ["DecentralizedServer", "DecentralizedSlotServer",
           "MixtureSlotServer", "PrefixCache", "Request", "ServeEngine",
           "SlotServer", "block_keys", "serve_step_fn"]
