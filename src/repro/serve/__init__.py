from .engine import ServeEngine, serve_step_fn
from .ensemble_engine import DecentralizedServer
from .scheduler import Request, SlotServer
