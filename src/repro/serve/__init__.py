from .api import (EngineConfig, RequestOutput, SamplingParams, TokenDelta,
                  FINISH_REASONS)
from .engine import ServeEngine, serve_step_fn
from .ensemble_engine import DecentralizedServer
from .prefix_cache import PrefixCache, block_keys
from .scheduler import (DecentralizedSlotServer, MixtureSlotServer, Request,
                        SlotServer, make_engine)

__all__ = ["DecentralizedServer", "DecentralizedSlotServer", "EngineConfig",
           "FINISH_REASONS", "MixtureSlotServer", "PrefixCache", "Request",
           "RequestOutput", "SamplingParams", "ServeEngine", "SlotServer",
           "TokenDelta", "block_keys", "make_engine", "serve_step_fn"]
