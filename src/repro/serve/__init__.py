from .api import (EngineConfig, RequestOutput, SamplingParams, TokenDelta,
                  FINISH_REASONS)
from .engine import ServeEngine, serve_step_fn
from .ensemble_engine import DecentralizedServer
from .fused import DONE_REASONS, decode_epilogue, pick_first, sample_tokens
from .prefix_cache import PrefixCache, block_keys
from .scheduler import (DecentralizedSlotServer, MixtureSlotServer, Request,
                        SlotServer, make_engine)

__all__ = ["DONE_REASONS", "DecentralizedServer", "DecentralizedSlotServer",
           "EngineConfig", "FINISH_REASONS", "MixtureSlotServer",
           "PrefixCache", "Request", "RequestOutput", "SamplingParams",
           "ServeEngine", "SlotServer", "TokenDelta", "block_keys",
           "decode_epilogue", "make_engine", "pick_first", "sample_tokens",
           "serve_step_fn"]
