"""Draft-free speculative proposers: where candidate tokens come from.

Speculative decoding splits a decode step into a cheap DRAFT of the next
``spec_len - 1`` tokens and one multi-token VERIFY dispatch that scores
every candidate position at once (``Model.verify_step_paged``); the
accept rule (``serve.fused.verify_epilogue``) keeps the longest prefix
that matches the vanilla trajectory, so the output stream is token-for-
token identical to unspeculated decode and drafting is purely a latency
lever. This repo drafts WITHOUT a separate draft model:

* ``NGramProposer`` (here) — prompt-lookup drafting on the host: match
  the request's most recent n-gram against its own earlier history
  (prompt + generated tokens) and propose the tokens that followed the
  previous occurrence. Free, model-agnostic, and strong exactly where
  speculation pays most — repetitive text (code, templates, retrieval
  echoes), where a single match often yields a full accepted span.
* expert-0 drafting (``core.ensemble.make_stacked_verify``) — the
  mixture core's K-expert stack already contains K cheap approximations
  of the Eq. 27 ensemble; expert 0 drafts greedily on its own slice of
  the shared paged cache (which mixture decode keeps warm for free) and
  the full mixture verifies. Lives on-device inside the fused dispatch;
  this module only provides the host-side n-gram half.

Both proposers are interchangeable behind ``EngineConfig(speculative=
"ngram" | "expert", spec_len=L)``; the scheduler feeds n-gram drafts
into the verify dispatch as a (n_slots, L-1) argument and falls back to
the vanilla one-token step whenever a step cannot speculate (chunk
co-scheduling, pool pressure, non-capable model families).
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

__all__ = ["NGramProposer"]


class NGramProposer:
    """Prompt-lookup drafting from a request's own token history.

    To propose, find the most recent EARLIER occurrence of the history's
    final ``n``-gram and replay the ``spec_len - 1`` tokens that followed
    it. No occurrence (or a too-short history) pads by repeating the last
    token — a deliberately bad draft that costs nothing when rejected
    (the verify step always emits at least the vanilla token).
    """

    def __init__(self, spec_len: int, n: int = 2):
        if spec_len < 2:
            raise ValueError(
                f"spec_len must be >= 2 to draft anything, got {spec_len}")
        if n < 1:
            raise ValueError(f"n-gram length must be >= 1, got {n}")
        self.spec_len = spec_len
        self.n = n

    def propose(self, history: Sequence[int]) -> np.ndarray:
        """history: the request's prompt + generated tokens, oldest first.
        Returns (spec_len - 1,) int32 draft tokens."""
        want = self.spec_len - 1
        h = np.asarray(history, dtype=np.int32)
        pad = np.full(want, h[-1] if h.size else 0, np.int32)
        if h.size <= self.n:
            return pad
        tail = h[-self.n:]
        # scan candidate start positions right-to-left: most recent
        # earlier occurrence wins (locality beats frequency for the
        # repetitive workloads speculation targets)
        windows = np.lib.stride_tricks.sliding_window_view(h[:-1], self.n)
        hits = np.nonzero((windows == tail).all(axis=1))[0]
        if hits.size == 0:
            return pad
        start = int(hits[-1]) + self.n      # first token AFTER the match
        cont = h[start:start + want]
        if cont.size < want:
            cont = np.concatenate(
                [cont, np.full(want - cont.size,
                               cont[-1] if cont.size else h[-1], np.int32)])
        return cont.astype(np.int32)

    def propose_batch(self, histories: List[Sequence[int]]) -> np.ndarray:
        """Stacked drafts for a batch of histories: (len, spec_len - 1)."""
        return np.stack([self.propose(h) for h in histories]) \
            if histories else np.zeros((0, self.spec_len - 1), np.int32)
