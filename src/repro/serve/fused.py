"""The fused decode-step epilogue: everything a scheduler used to do on the
host after the model's forward — pick the next token (greedy or seeded
sampling), check stop/eos ids, check the token budget and the context
bound, and advance the per-slot position — expressed as pure device ops so
the whole decode token is ONE jitted dispatch.

The host's per-step work shrinks to a single ``device_get`` of the
``(next_token, done)`` pair: ``next_token`` feeds the per-request output
streams, and ``done`` is a small per-slot bitmap (0 = keep decoding, else
a ``DONE_REASONS`` code) that replaces per-slot Python token inspection
for retirement detection.

The per-slot sampling state (temps / top_ks / seeds / counts / stop ids /
budgets) lives in a dict of persistent device arrays — see
``_SlotTable._device_state`` — rebuilt only when admission, retirement or
block-table growth changes it, never per step.

Semantics are kept EXACTLY equal to the unfused host epilogue
(``_SlotTable._advance`` + ``Request.reason_now``):

* stop ids match only *generated* tokens (the state is consulted for the
  token decoded this step — prompt tokens never reach it);
* reason precedence is stop > length > truncated;
* the capacity bound is position-exact: position ``cache_len - 1`` is
  decodable, the write that would land at ``cache_len`` is not.

This module is a leaf: it imports only jax and the shared ``PROB_FLOOR``
so every consumer (schedulers, the model's fused entry point, the stacked
mixture core) can pull it in without import cycles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ensemble import PROB_FLOOR

__all__ = ["DONE_REASONS", "argmax_tokens", "decode_epilogue", "pick_first",
           "sample_tokens", "sample_tokens_probs", "_sample_tokens"]

#: ``done`` bitmap code → finish reason (0 means "keep decoding").
DONE_REASONS = {1: "stop", 2: "length", 3: "truncated"}


def _sample_tokens(scores, temps, top_ks, seeds, counts):
    """Per-slot seeded sampling step (jitted once, batched over slots).

    scores: (B, V) next-token logits (or log-probabilities — argmax and
    categorical are both invariant to the difference up to the temperature
    semantics documented on ``Request``); temps: (B,) float32, ≤ 0 rows
    take the greedy argmax; top_ks: (B,) int32, 0 → full vocabulary;
    seeds/counts: (B,) uint32/int32 — token ``counts[b]`` of request
    ``seeds[b]`` draws from ``fold_in(PRNGKey(seed), count)``, so a
    request's sampled continuation depends only on (seed, scores), never
    on slot placement or co-scheduled traffic.
    """
    V = scores.shape[-1]
    greedy = jnp.argmax(scores, axis=-1).astype(jnp.int32)
    k = jnp.where(top_ks <= 0, V, jnp.minimum(top_ks, V))
    srt = jnp.sort(scores, axis=-1)                      # ascending
    thresh = jnp.take_along_axis(srt, (V - k)[:, None], axis=-1)
    masked = jnp.where(scores >= thresh, scores, -jnp.inf)
    scaled = masked / jnp.maximum(temps, 1e-6)[:, None]
    keys = jax.vmap(lambda s, c: jax.random.fold_in(
        jax.random.PRNGKey(s), c))(seeds, counts)
    sampled = jax.vmap(jax.random.categorical)(keys, scaled)
    return jnp.where(temps > 0, sampled.astype(jnp.int32), greedy)


sample_tokens = jax.jit(_sample_tokens)


def _sample_tokens_probs(probs, temps, top_ks, seeds, counts):
    """``sample_tokens`` over Eq. 27 mixture *probabilities*: the floor +
    log transform runs inside the same dispatch, so callers holding probs
    (the stacked mixture core) pay no eager ``jnp.log`` on the host path."""
    return _sample_tokens(jnp.log(jnp.maximum(probs, PROB_FLOOR)),
                          temps, top_ks, seeds, counts)


sample_tokens_probs = jax.jit(_sample_tokens_probs)

#: Greedy next-token pick as ONE jitted dispatch — the all-greedy fast path
#: of ``_SlotTable._next_tokens``. The eager ``jnp.argmax`` it replaces was
#: an un-fused device dispatch (and implicit sync) per step on the host
#: side of the legacy epilogue (the PR 6 incident repro-lint now flags).
argmax_tokens = jax.jit(
    lambda scores: jnp.argmax(scores, axis=-1).astype(jnp.int32))


def pick_first(row, temp, top_k, seed, *, from_probs: bool = False):
    """First token from a prefill's last-position scores (``row``: (1, V))
    — count 0 of the request's seeded stream, greedy when ``temp <= 0``.
    Pure (meant to be fused into the prefill/chunk dispatch); returns the
    (1,) int32 token on device."""
    if from_probs:
        row = jnp.log(jnp.maximum(row, PROB_FLOOR))
    return _sample_tokens(row, temp, top_k, seed,
                          jnp.zeros((1,), jnp.int32))


def decode_epilogue(scores, state, *, cache_len: int,
                    from_probs: bool = False):
    """One lockstep decode step's host epilogue as device ops.

    scores: (n_slots, V) this step's next-token scores; state: the per-slot
    device-state dict (see ``_SlotTable._device_state``) with at least

        tok/pos (int32), active (bool), temps (f32), top_ks (i32),
        seeds (u32), counts (i32), max_new (i32), stop_ids (i32, padded
        with -1 — token ids are non-negative, so pad rows never match)

    Returns ``(new_state, next_tok, done)``: the state advanced for the
    next step (finished rows parked at tok/pos 0 — the scratch-writing
    idle configuration — and deactivated), the (n_slots,) tokens decoded
    this step (inactive rows keep their input token and must be ignored),
    and the (n_slots,) ``DONE_REASONS`` bitmap.
    """
    if from_probs:
        scores = jnp.log(jnp.maximum(scores, PROB_FLOOR))
    nxt = _sample_tokens(scores, state["temps"], state["top_ks"],
                         state["seeds"], state["counts"])
    active = state["active"]
    nxt = jnp.where(active, nxt, state["tok"]).astype(jnp.int32)
    counts = state["counts"] + active.astype(jnp.int32)
    pos = state["pos"] + active.astype(jnp.int32)
    # reason precedence mirrors Request.reason_now + _advance exactly:
    # stop > length > truncated, each gated on the slot being active
    is_stop = active & jnp.any(nxt[:, None] == state["stop_ids"], axis=-1)
    is_len = active & (counts >= state["max_new"])
    is_trunc = active & (pos >= cache_len)
    done = jnp.where(is_stop, 1,
                     jnp.where(is_len, 2,
                               jnp.where(is_trunc, 3, 0))).astype(jnp.int32)
    fin = done > 0
    new_state = dict(state,
                     tok=jnp.where(fin, 0, nxt).astype(jnp.int32),
                     pos=jnp.where(fin, 0, pos).astype(jnp.int32),
                     counts=counts,
                     active=active & ~fin)
    return new_state, nxt, done
