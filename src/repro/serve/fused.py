"""The fused decode-step epilogue: everything a scheduler used to do on the
host after the model's forward — pick the next token (greedy or seeded
sampling), check stop/eos ids, check the token budget and the context
bound, and advance the per-slot position — expressed as pure device ops so
the whole decode token is ONE jitted dispatch.

The host's per-step work shrinks to a single ``device_get`` of the
``(next_token, done)`` pair: ``next_token`` feeds the per-request output
streams, and ``done`` is a small per-slot bitmap (0 = keep decoding, else
a ``DONE_REASONS`` code) that replaces per-slot Python token inspection
for retirement detection.

The per-slot sampling state (temps / top_ks / seeds / counts / stop ids /
budgets) lives in a dict of persistent device arrays — see
``_SlotTable._device_state`` — rebuilt only when admission, retirement or
block-table growth changes it, never per step.

Semantics are kept EXACTLY equal to the unfused host epilogue
(``_SlotTable._advance`` + ``Request.reason_now``):

* stop ids match only *generated* tokens (the state is consulted for the
  token decoded this step — prompt tokens never reach it);
* reason precedence is stop > length > truncated;
* the capacity bound is position-exact: position ``cache_len - 1`` is
  decodable, the write that would land at ``cache_len`` is not.

``verify_epilogue`` is the speculative sibling: given the scores of L
candidate positions and the L-1 draft tokens that produced them, it
computes the deterministic seeded-sampling accept rule (the token the
vanilla trajectory WOULD emit at each offset — count ``c0 + j`` of the
request's seeded stream — accepted while the draft matches it), the
per-offset stop/budget/context checks with the same precedence, and the
variable-length position advance, still as pure device ops.

**The single-dispatch contract** (shared with ``serve/scheduler.py``):

* on device, per step: the model forward (decode, verify or co-scheduled
  chunk), Eq. 27 mixture mixing, seeded sampling / the speculative accept
  rule, stop/eos/budget/context checks, and the position advance — one
  jitted dispatch, no intermediate host sync;
* the host may read back ONE ``jax.device_get`` per step — the
  ``(next_token, done)`` pair (vanilla) or ``(tokens, n_emit, done)``
  triple (speculative) — plus nothing else on the hot path;
* host-side state mutation (slot tables, block allocator, request
  streams) is driven entirely by that readback; the persistent device
  state dict is rebuilt only on admission/retirement/growth events.

repro-lint enforces the contract: the step loop and this module are
``# repro: hot-path`` scope (eager device ops and implicit syncs are
flagged), the dispatch entry points are ``# repro: jit`` scope (retrace
hazards are flagged), and the kernels' index maps carry
``# repro: bounds`` justifications.

This module is a leaf: it imports only jax and the shared ``PROB_FLOOR``
so every consumer (schedulers, the model's fused entry point, the stacked
mixture core) can pull it in without import cycles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ensemble import PROB_FLOOR

__all__ = ["DONE_REASONS", "argmax_tokens", "decode_epilogue", "pick_first",
           "sample_tokens", "sample_tokens_probs", "verify_epilogue",
           "_sample_tokens"]

#: ``done`` bitmap code → finish reason (0 means "keep decoding").
DONE_REASONS = {1: "stop", 2: "length", 3: "truncated"}


def _sample_tokens(scores, temps, top_ks, seeds, counts):
    """Per-slot seeded sampling step (jitted once, batched over slots).

    scores: (B, V) next-token logits (or log-probabilities — argmax and
    categorical are both invariant to the difference up to the temperature
    semantics documented on ``Request``); temps: (B,) float32, ≤ 0 rows
    take the greedy argmax; top_ks: (B,) int32, 0 → full vocabulary;
    seeds/counts: (B,) uint32/int32 — token ``counts[b]`` of request
    ``seeds[b]`` draws from ``fold_in(PRNGKey(seed), count)``, so a
    request's sampled continuation depends only on (seed, scores), never
    on slot placement or co-scheduled traffic.
    """
    V = scores.shape[-1]
    greedy = jnp.argmax(scores, axis=-1).astype(jnp.int32)
    k = jnp.where(top_ks <= 0, V, jnp.minimum(top_ks, V))
    srt = jnp.sort(scores, axis=-1)                      # ascending
    thresh = jnp.take_along_axis(srt, (V - k)[:, None], axis=-1)
    masked = jnp.where(scores >= thresh, scores, -jnp.inf)
    scaled = masked / jnp.maximum(temps, 1e-6)[:, None]
    keys = jax.vmap(lambda s, c: jax.random.fold_in(
        jax.random.PRNGKey(s), c))(seeds, counts)
    sampled = jax.vmap(jax.random.categorical)(keys, scaled)
    return jnp.where(temps > 0, sampled.astype(jnp.int32), greedy)


sample_tokens = jax.jit(_sample_tokens)


def _sample_tokens_probs(probs, temps, top_ks, seeds, counts):
    """``sample_tokens`` over Eq. 27 mixture *probabilities*: the floor +
    log transform runs inside the same dispatch, so callers holding probs
    (the stacked mixture core) pay no eager ``jnp.log`` on the host path."""
    return _sample_tokens(jnp.log(jnp.maximum(probs, PROB_FLOOR)),
                          temps, top_ks, seeds, counts)


sample_tokens_probs = jax.jit(_sample_tokens_probs)

#: Greedy next-token pick as ONE jitted dispatch — the all-greedy fast path
#: of ``_SlotTable._next_tokens``. The eager ``jnp.argmax`` it replaces was
#: an un-fused device dispatch (and implicit sync) per step on the host
#: side of the legacy epilogue (the PR 6 incident repro-lint now flags).
argmax_tokens = jax.jit(
    lambda scores: jnp.argmax(scores, axis=-1).astype(jnp.int32))


def pick_first(row, temp, top_k, seed, *, from_probs: bool = False):
    """First token from a prefill's last-position scores (``row``: (1, V))
    — count 0 of the request's seeded stream, greedy when ``temp <= 0``.
    Pure (meant to be fused into the prefill/chunk dispatch); returns the
    (1,) int32 token on device."""
    if from_probs:
        row = jnp.log(jnp.maximum(row, PROB_FLOOR))
    return _sample_tokens(row, temp, top_k, seed,
                          jnp.zeros((1,), jnp.int32))


def decode_epilogue(scores, state, *, cache_len: int,
                    from_probs: bool = False):
    """One lockstep decode step's host epilogue as device ops.

    scores: (n_slots, V) this step's next-token scores; state: the per-slot
    device-state dict (see ``_SlotTable._device_state``) with at least

        tok/pos (int32), active (bool), temps (f32), top_ks (i32),
        seeds (u32), counts (i32), max_new (i32), stop_ids (i32, padded
        with -1 — token ids are non-negative, so pad rows never match)

    Returns ``(new_state, next_tok, done)``: the state advanced for the
    next step (finished rows parked at tok/pos 0 — the scratch-writing
    idle configuration — and deactivated), the (n_slots,) tokens decoded
    this step (inactive rows keep their input token and must be ignored),
    and the (n_slots,) ``DONE_REASONS`` bitmap.
    """
    if from_probs:
        scores = jnp.log(jnp.maximum(scores, PROB_FLOOR))
    nxt = _sample_tokens(scores, state["temps"], state["top_ks"],
                         state["seeds"], state["counts"])
    active = state["active"]
    nxt = jnp.where(active, nxt, state["tok"]).astype(jnp.int32)
    counts = state["counts"] + active.astype(jnp.int32)
    pos = state["pos"] + active.astype(jnp.int32)
    # reason precedence mirrors Request.reason_now + _advance exactly:
    # stop > length > truncated, each gated on the slot being active
    is_stop = active & jnp.any(nxt[:, None] == state["stop_ids"], axis=-1)
    is_len = active & (counts >= state["max_new"])
    is_trunc = active & (pos >= cache_len)
    done = jnp.where(is_stop, 1,
                     jnp.where(is_len, 2,
                               jnp.where(is_trunc, 3, 0))).astype(jnp.int32)
    fin = done > 0
    new_state = dict(state,
                     tok=jnp.where(fin, 0, nxt).astype(jnp.int32),
                     pos=jnp.where(fin, 0, pos).astype(jnp.int32),
                     counts=counts,
                     active=active & ~fin)
    return new_state, nxt, done


def verify_epilogue(scores, drafts, state, *, cache_len: int,
                    from_probs: bool = False):
    """The speculative span's accept/reject + bookkeeping as device ops.

    scores: (n_slots, L, V) — row j is the model's next-token scores at
    position ``pos + j``, i.e. after feeding the slot's committed token
    (offset 0) and draft tokens ``drafts[:, :j]`` (offsets 1..j);
    drafts: (n_slots, L-1) int32 candidate tokens; state: the same
    device-state dict as ``decode_epilogue``.

    The accept rule is DETERMINISTIC token-match: seeded sampling makes
    the vanilla trajectory a pure function of (seed, count, scores), so
    the "true" token at offset j is ``_sample_tokens(scores[:, j], ...,
    counts + j)`` — exactly what a vanilla step with the same prefix
    would emit — and a draft is accepted iff it EQUALS it. This is
    standard rejection sampling degenerated to its deterministic special
    case (the proposal is accepted with probability 1 when it matches
    the target draw, 0 otherwise), which is what makes the token-for-
    token parity invariant hold for sampled requests, not just greedy.
    Offset j's scores are only consulted when drafts 1..j all matched,
    so every emitted token saw exactly the vanilla prefix.

    Per-offset finish checks replay ``decode_epilogue`` at each emitted
    offset (count ``c0+j+1`` vs budget, position ``p0+j+1`` vs context,
    stop-id membership; precedence stop > length > truncated): the span
    is truncated at the FIRST halting offset, so a stop token accepted
    mid-span retires the request once and the rest of the draft is
    discarded on device — the host never sees the dead tail.

    Returns ``(new_state, toks, n_emit, done)``: ``toks`` (n_slots, L)
    holds the emitted tokens left-aligned (rows of inactive slots are
    zeroed), ``n_emit`` (n_slots,) how many of them are real — at least
    1 for an active slot (offset 0 never needs a draft: all-reject spans
    still make forward progress), at most L — and ``done`` the
    ``DONE_REASONS`` bitmap. One ``device_get`` of the triple is the
    step's entire host readback.
    """
    B, L, V = scores.shape
    if from_probs:
        scores = jnp.log(jnp.maximum(scores, PROB_FLOOR))
    active = state["active"]
    offs = jnp.arange(L, dtype=jnp.int32)
    # the vanilla trajectory's token at each offset: count c0 + j of the
    # request's seeded stream (greedy rows take the argmax, same as ever)
    true = jnp.stack(
        [_sample_tokens(scores[:, j], state["temps"], state["top_ks"],
                        state["seeds"], state["counts"] + j)
         for j in range(L)], axis=1)                          # (B, L)
    if L > 1:
        match = (drafts == true[:, :L - 1]).astype(jnp.int32)
        n_acc = jnp.sum(jnp.cumprod(match, axis=1), axis=1)   # (B,)
    else:
        n_acc = jnp.zeros((B,), jnp.int32)
    m_max = n_acc + 1            # accepted drafts + the free bonus token
    cnt_after = state["counts"][:, None] + 1 + offs[None, :]  # (B, L)
    pos_after = state["pos"][:, None] + 1 + offs[None, :]
    is_stop = jnp.any(true[:, :, None] == state["stop_ids"][:, None, :],
                      axis=-1)
    is_len = cnt_after >= state["max_new"][:, None]
    is_trunc = pos_after >= cache_len
    halt = is_stop | is_len | is_trunc                        # (B, L)
    first_halt = jnp.where(jnp.any(halt, axis=1),
                           jnp.argmax(halt, axis=1), L).astype(jnp.int32)
    m = jnp.minimum(m_max, first_halt + 1)
    m = jnp.where(active, m, 0).astype(jnp.int32)
    halted = active & (first_halt < m_max)
    code = jnp.where(is_stop, 1, jnp.where(is_len, 2, 3))
    h = jnp.clip(first_halt, 0, L - 1)
    done = jnp.where(halted,
                     jnp.take_along_axis(code, h[:, None], axis=1)[:, 0],
                     0).astype(jnp.int32)
    fin = done > 0
    counts = state["counts"] + m
    pos = state["pos"] + m
    last = jnp.take_along_axis(
        true, jnp.maximum(m - 1, 0)[:, None], axis=1)[:, 0]
    nxt = jnp.where(active, last, state["tok"]).astype(jnp.int32)
    new_state = dict(state,
                     tok=jnp.where(fin, 0, nxt).astype(jnp.int32),
                     pos=jnp.where(fin, 0, pos).astype(jnp.int32),
                     counts=counts,
                     active=active & ~fin)
    toks = jnp.where(active[:, None], true, 0).astype(jnp.int32)
    return new_state, toks, m, done
