"""Decentralized ensemble serving (paper §5.2).

Deployment model mirrors the paper: each expert lives on its own pod; the
parameter-free centroid router runs at the front end on the request's frozen
-encoder features. Two in-process strategies:

* ``grouped_top1`` — the paper's main (compute-matched) setting: requests
  are grouped by their routed expert and each group is decoded by exactly
  one expert (host-side dispatcher, per-expert engines).
* ``mixture`` — the general top-k path: expert parameters are stacked on a
  K (``dexpert``) dim (decode layout: K after each scanned stack's layer
  dim, transpose-free) and ONE jitted step vmaps ``decode_step`` over
  it with the exact Eq. 27 probability mixture (``mix_expert_logits``)
  fused in — no per-expert Python loop in the hot path. With the dexpert
  dim sharded over the ``pod`` mesh axis (sharding/rules.py) each expert's
  slice of the step runs on its own pod.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ensemble import (PROB_FLOOR, make_stacked_serving,
                                 mix_expert_logits)
from repro.core.router import CentroidRouter
from repro.models.model import Model
from repro.serve.api import SamplingParams
from .engine import ServeEngine, resolve_sampling

Array = jnp.ndarray


@dataclass
class DecentralizedServer:
    model: Model
    expert_params: List[Any]            # K parameter pytrees
    router: CentroidRouter
    cache_len: int
    use_kernel: bool = False

    def __post_init__(self):
        self.engine = ServeEngine(self.model, self.cache_len,
                                  use_kernel=self.use_kernel)
        self._core = None        # stacked decode core, built on first use
        self._mix = jax.jit(mix_expert_logits)

    def _stacked_core(self):
        """Lazily build the stacked-expert core — the top-1 path never pays
        the K× stacked-parameter copy."""
        if self._core is None:
            stacked, axes, prefill_all, mix_decode = make_stacked_serving(
                self.model, self.expert_params, self.cache_len,
                use_kernel=self.use_kernel)
            model, use_kernel = self.model, self.use_kernel
            forward_all = jax.jit(lambda sp, batch: jax.vmap(
                lambda p: model.forward(p, batch, use_kernel=use_kernel),
                in_axes=(axes,))(sp))
            self._core = (stacked, prefill_all, mix_decode, forward_all)
        return self._core

    @property
    def K(self) -> int:
        return len(self.expert_params)

    # ------------------------------------------------------------------
    # Routing
    # ------------------------------------------------------------------

    def route(self, features: Array) -> Array:
        """(B, D) → top-k-filtered weights (B, K)."""
        return self.router.route(features)

    # ------------------------------------------------------------------
    # grouped top-1 (compute-matched, the paper's main tables)
    # ------------------------------------------------------------------

    def generate_top1(self, batch: Dict[str, Array],
                      n_new: int | SamplingParams, key=None,
                      temperature: float = 1.0) -> np.ndarray:
        """``n_new`` may be a ``SamplingParams`` — the same object the
        slot engines consume (max_new/temperature/seed batch-wide)."""
        n_new, key, temperature = resolve_sampling(n_new, key, temperature)
        feats = batch["features"]
        expert_of = np.asarray(self.router.top1(feats))       # (B,)
        B = expert_of.shape[0]
        out = np.zeros((B, n_new), dtype=np.int32)
        for k in range(self.K):
            sel = np.where(expert_of == k)[0]
            if len(sel) == 0:
                continue
            sub = {name: v[sel] for name, v in batch.items()
                   if name != "features"}
            key, gk = jax.random.split(key)
            toks = self.engine.generate(self.expert_params[k], sub, n_new,
                                        gk, temperature)
            out[sel] = np.asarray(toks)
        return out

    # ------------------------------------------------------------------
    # mixture (general top-k, exact Eq. 27, stacked-vmap decode core)
    # ------------------------------------------------------------------

    def mixture_next_probs(self, batch: Dict[str, Array]) -> Array:
        """Stacked prefill over every expert + mix last-position
        distributions. Returns (B, V) ensemble next-token probabilities."""
        weights = self.route(batch["features"])               # (B, K)
        sub = {k: v for k, v in batch.items() if k != "features"}
        stacked, prefill_all, _, _ = self._stacked_core()
        logits, _ = prefill_all(stacked, sub)
        return self._mix(logits[:, :, -1], weights)           # (K,B,V)→(B,V)

    def generate_mixture(self, batch: Dict[str, Array],
                         n_new: int | SamplingParams, key=None,
                         temperature: float = 1.0) -> Array:
        """Top-k mixture decoding: ONE vmapped decode step over the stacked
        expert params per token, mixture fused into the jitted step.
        ``n_new`` may be a ``SamplingParams`` (see ``generate_top1``)."""
        n_new, key, temperature = resolve_sampling(n_new, key, temperature)
        weights = self.route(batch["features"])               # (B, K)
        sub = {k: v for k, v in batch.items() if k != "features"}
        stacked, prefill_all, mix_decode, _ = self._stacked_core()
        logits, caches = prefill_all(stacked, sub)
        probs = self._mix(logits[:, :, -1], weights)          # (B, V)
        prompt_len = logits.shape[2]
        out = []
        for i in range(n_new):
            key, sk = jax.random.split(key)
            if temperature == 0:
                tok = jnp.argmax(probs, axis=-1).astype(jnp.int32)
            else:
                logp = jnp.log(jnp.maximum(probs, PROB_FLOOR)) / temperature
                tok = jax.random.categorical(sk, logp, -1).astype(jnp.int32)
            out.append(tok)
            if i == n_new - 1:
                break
            probs, caches = mix_decode(
                stacked, caches, tok, prompt_len + i, weights)
        return jnp.stack(out, axis=1)

    def ensemble_eval_nll(self, batch: Dict[str, Array]) -> Array:
        """Teacher-forced per-token NLL of the router-weighted mixture —
        the metric the parity benchmarks report."""
        weights = self.route(batch["features"])               # (B, K)
        sub = {k: v for k, v in batch.items() if k != "features"}
        stacked, _, _, forward_all = self._stacked_core()
        all_logits = forward_all(stacked, sub)                # (K,B,S,V)
        probs = self._mix(
            all_logits, weights[:, None, :].repeat(all_logits.shape[2], 1))
        logp = jnp.log(jnp.maximum(probs, PROB_FLOOR))
        labels = sub["labels"]
        nll = -jnp.take_along_axis(logp[:, :-1], labels[:, 1:, None],
                                   axis=-1)[..., 0]
        return nll.mean()
