"""Decentralized ensemble serving (paper §5.2).

Deployment model mirrors the paper: each expert lives on its own pod; the
parameter-free centroid router runs at the front end on the request's frozen
-encoder features. Two in-process strategies:

* ``grouped_top1`` — the paper's main (compute-matched) setting: requests
  are grouped by their routed expert and each group is decoded by exactly
  one expert (host-side dispatcher, per-expert engines).
* ``mixture`` — the general top-k path: run the top-k experts and mix their
  next-token *probabilities* with the renormalized router weights — the
  exact Eq. 27 recomposition (validated against the theory tests).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ensemble import mix_expert_logits
from repro.core.router import CentroidRouter
from repro.models.model import Model
from .engine import ServeEngine

Array = jnp.ndarray


@dataclass
class DecentralizedServer:
    model: Model
    expert_params: List[Any]            # K parameter pytrees
    router: CentroidRouter
    cache_len: int
    use_kernel: bool = False

    def __post_init__(self):
        self.engine = ServeEngine(self.model, self.cache_len,
                                  use_kernel=self.use_kernel)

    @property
    def K(self) -> int:
        return len(self.expert_params)

    # ------------------------------------------------------------------
    # Routing
    # ------------------------------------------------------------------

    def route(self, features: Array) -> Array:
        """(B, D) → top-k-filtered weights (B, K)."""
        return self.router.route(features)

    # ------------------------------------------------------------------
    # grouped top-1 (compute-matched, the paper's main tables)
    # ------------------------------------------------------------------

    def generate_top1(self, batch: Dict[str, Array], n_new: int, key,
                      temperature: float = 1.0) -> np.ndarray:
        feats = batch["features"]
        expert_of = np.asarray(self.router.top1(feats))       # (B,)
        B = expert_of.shape[0]
        out = np.zeros((B, n_new), dtype=np.int32)
        for k in range(self.K):
            sel = np.where(expert_of == k)[0]
            if len(sel) == 0:
                continue
            sub = {name: v[sel] for name, v in batch.items()
                   if name != "features"}
            key, gk = jax.random.split(key)
            toks = self.engine.generate(self.expert_params[k], sub, n_new,
                                        gk, temperature)
            out[sel] = np.asarray(toks)
        return out

    # ------------------------------------------------------------------
    # mixture (general top-k, exact Eq. 27)
    # ------------------------------------------------------------------

    def mixture_next_probs(self, batch: Dict[str, Array]) -> Array:
        """Run every expert's prefill and mix last-position distributions.
        Returns (B, V) ensemble next-token probabilities."""
        weights = self.route(batch["features"])               # (B, K)
        sub = {k: v for k, v in batch.items() if k != "features"}
        last_logits = []
        for params in self.expert_params:
            logits, _ = self.engine.prefill(params, sub)
            last_logits.append(logits[:, -1])
        stacked = jnp.stack(last_logits)                      # (K, B, V)
        return mix_expert_logits(stacked, weights)

    def generate_mixture(self, batch: Dict[str, Array], n_new: int, key,
                         temperature: float = 1.0) -> Array:
        """Top-k mixture decoding: every kept expert decodes in lockstep and
        distributions are mixed each step."""
        weights = self.route(batch["features"])               # (B, K)
        sub = {k: v for k, v in batch.items() if k != "features"}
        states = []
        for params in self.expert_params:
            logits, cache = self.engine.prefill(params, sub)
            states.append((logits[:, -1], cache))
        prompt_len = sub["tokens"].shape[1] + (
            self.model.cfg.n_patches if self.model.cfg.family == "vlm" else 0)
        out = []
        for i in range(n_new):
            probs = mix_expert_logits(
                jnp.stack([s[0] for s in states]), weights)   # (B, V)
            key, sk = jax.random.split(key)
            if temperature == 0:
                tok = jnp.argmax(probs, axis=-1).astype(jnp.int32)
            else:
                logp = jnp.log(jnp.maximum(probs, 1e-30)) / temperature
                tok = jax.random.categorical(sk, logp, -1).astype(jnp.int32)
            out.append(tok)
            if i == n_new - 1:
                break
            states = [
                self.engine.decode_step(p, c, tok, prompt_len + i)
                for p, (_, c) in zip(self.expert_params,
                                     [(s[0], s[1]) for s in states])]
        return jnp.stack(out, axis=1)

    def ensemble_eval_nll(self, batch: Dict[str, Array]) -> Array:
        """Teacher-forced per-token NLL of the router-weighted mixture —
        the metric the parity benchmarks report."""
        weights = self.route(batch["features"])               # (B, K)
        sub = {k: v for k, v in batch.items() if k != "features"}
        all_logits = jnp.stack([self.model.forward(p, sub)
                                for p in self.expert_params])  # (K,B,S,V)
        probs = mix_expert_logits(
            all_logits, weights[:, None, :].repeat(all_logits.shape[2], 1))
        logp = jnp.log(jnp.maximum(probs, 1e-30))
        labels = sub["labels"]
        nll = -jnp.take_along_axis(logp[:, :-1], labels[:, 1:, None],
                                   axis=-1)[..., 0]
        return nll.mean()
