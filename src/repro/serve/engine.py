"""Serving runtime: prefill + single-token decode (``serve_step``) with KV
caches / recurrent state, plus a sampled generation loop."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple, Union

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.serve.api import SamplingParams

Array = jnp.ndarray


def resolve_sampling(n_new: Union[int, SamplingParams], key,
                     temperature: float) -> Tuple[int, Any, float]:
    """Normalize the whole-batch generators' sampling arguments: callers
    pass either the legacy ``(n_new, key, temperature)`` triple or ONE
    ``SamplingParams`` (the same object the slot engines consume) — whose
    ``seed`` derives the key when none is given. Stop-token early exit is
    a per-request notion; the whole-batch engines decode the full budget
    (use the slot engines for stop/abort semantics)."""
    if isinstance(n_new, SamplingParams):
        sp = n_new
        if key is None:
            key = jax.random.PRNGKey(sp.seed)
        return sp.max_new, key, sp.temperature
    if key is None:
        key = jax.random.PRNGKey(0)
    return n_new, key, temperature


@dataclass
class ServeEngine:
    model: Model
    cache_len: int
    use_kernel: bool = False

    def __post_init__(self):
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, self.cache_len,
                                            use_kernel=self.use_kernel))
        self._decode = jax.jit(
            lambda p, c, t, pos: self.model.decode_step(
                p, c, t, pos, use_kernel=self.use_kernel))

    def prefill(self, params, batch):
        return self._prefill(params, batch)

    def decode_step(self, params, cache, tokens: Array, pos) -> Tuple[Array, Any]:
        return self._decode(params, cache, tokens, jnp.asarray(pos))

    def generate(self, params, batch, n_new: Union[int, SamplingParams],
                 key=None, temperature: float = 1.0) -> Array:
        """Prefill on the prompt then sample ``n_new`` tokens. Returns
        (B, n_new). Sampling is the Eq. 13 rule restricted (by 1-sparsity)
        to the single active position — ordinary AR decoding. ``n_new``
        may be a ``SamplingParams`` (its max_new/temperature/seed apply to
        the whole batch)."""
        n_new, key, temperature = resolve_sampling(n_new, key, temperature)
        logits, cache = self.prefill(params, batch)
        prompt_len = logits.shape[1]
        last = logits[:, -1]
        out = []
        tok = None
        for i in range(n_new):
            key, sub = jax.random.split(key)
            if temperature == 0:
                tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
            else:
                tok = jax.random.categorical(sub, last / temperature, axis=-1
                                             ).astype(jnp.int32)
            out.append(tok)
            if i == n_new - 1:
                break
            last, cache = self.decode_step(params, cache, tok,
                                           prompt_len + i)
        return jnp.stack(out, axis=1)


def serve_step_fn(model: Model, *, use_kernel: bool = False):
    """The raw (params, cache, tokens, pos) → (logits, cache) function that
    the dry-run lowers for decode shapes."""
    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos,
                                 use_kernel=use_kernel)
    return serve_step
