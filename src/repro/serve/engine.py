"""Serving runtime: prefill + single-token decode (``serve_step``) with KV
caches / recurrent state, plus a sampled generation loop."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model

Array = jnp.ndarray


@dataclass
class ServeEngine:
    model: Model
    cache_len: int
    use_kernel: bool = False

    def __post_init__(self):
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, self.cache_len,
                                            use_kernel=self.use_kernel))
        self._decode = jax.jit(
            lambda p, c, t, pos: self.model.decode_step(
                p, c, t, pos, use_kernel=self.use_kernel))

    def prefill(self, params, batch):
        return self._prefill(params, batch)

    def decode_step(self, params, cache, tokens: Array, pos) -> Tuple[Array, Any]:
        return self._decode(params, cache, tokens, jnp.asarray(pos))

    def generate(self, params, batch, n_new: int, key,
                 temperature: float = 1.0) -> Array:
        """Prefill on the prompt then sample ``n_new`` tokens. Returns
        (B, n_new). Sampling is the Eq. 13 rule restricted (by 1-sparsity)
        to the single active position — ordinary AR decoding."""
        logits, cache = self.prefill(params, batch)
        prompt_len = logits.shape[1]
        last = logits[:, -1]
        out = []
        tok = None
        for i in range(n_new):
            key, sub = jax.random.split(key)
            if temperature == 0:
                tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
            else:
                tok = jax.random.categorical(sub, last / temperature, axis=-1
                                             ).astype(jnp.int32)
            out.append(tok)
            if i == n_new - 1:
                break
            last, cache = self.decode_step(params, cache, tok,
                                           prompt_len + i)
        return jnp.stack(out, axis=1)


def serve_step_fn(model: Model, *, use_kernel: bool = False):
    """The raw (params, cache, tokens, pos) → (logits, cache) function that
    the dry-run lowers for decode shapes."""
    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos,
                                 use_kernel=use_kernel)
    return serve_step
