"""Radix prefix cache: shared-prefix KV reuse over the paged block pool.

Most production traffic shares long common prefixes — system prompts,
few-shot templates, fixed multimodal instruction preambles — and the
per-expert routing of the decentralized deployment concentrates similar
requests on the same pods, which makes prefix reuse *more* likely under
the Eq. 27 mixture than in a centralized server. Yet without this module
every admission re-prefills its full prompt into freshly allocated blocks.

The cache makes paged KV blocks content-addressed and shareable:

* **Keying** — a radix tree over *full-block* token chunks
  (``block_keys``): the key of logical block ``i`` is the tuple of token
  ids occupying its ``block_size`` positions, rooted at a digest of the
  request's modality extras (image patches / audio frames), since every
  decoder position's KV depends on them. A block is only ever cached once
  its whole extent is prompt content, so cached blocks are immutable —
  decode writes always land past the prompt, in private blocks.
* **Sharing** — ``match`` walks the tree for the longest cached run of
  full-block keys, capped at ``(width - 1) // block_size`` so at least one
  position is always re-prefilled (the last position's logits produce the
  first token, and — when a block-aligned prompt is fully cached — the
  re-prefilled suffix recomputes the final block into a fresh private
  block instead of writing a shared one: the copy-on-write rule, realized
  as recompute-into-private since the suffix is recomputed anyway).
  Matched blocks are spliced read-only into the request's block table
  (``acquire`` → refcount++) and chunked prefill starts at the first
  uncached position, so a hit's TTFT is roughly one chunk.
* **Insertion** — when a request's prefill completes, its full prompt
  blocks enter the tree (``insert``); the private ones become tracked with
  the owner's reference. Two requests racing the same new prefix both
  prefill privately; the first insert wins the tree slot, the loser's
  blocks stay untracked and return to the free list at retirement.
* **Eviction** — a tracked block whose last reference drops joins an LRU
  list instead of the free list (``release``); under pool pressure
  ``evict`` returns least-recently-used *leaf* blocks to the allocator
  (a non-leaf still backs longer cached prefixes; live holders of a child
  always hold its parent, so leaves-first eviction never strands a path).

The tree, refcounts, and LRU are host state, exactly like the block
tables: the only device-visible artifact is the block table each step
already uploads (see ``sharding/rules.block_table_pspec``).
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from types import MappingProxyType
from typing import Any, Dict, Hashable, List, Mapping, Optional

import numpy as np

from repro.obs.metrics import MetricsRegistry


def block_keys(tokens: np.ndarray, extras: Dict[str, np.ndarray],
               block_size: int, n_blocks: int, *,
               n_prefix: int = 0) -> List[Hashable]:
    """Content keys for the first ``n_blocks`` full blocks of a prompt.

    ``n_prefix`` is the modality-prefix width (VLM image patches occupy
    decoder positions before the tokens); positions inside it contribute no
    token ids — their content is pinned by the extras digest, which roots
    the key path (key 0), so prompts with different patches/frames can
    never share a block even when their token ids agree.
    """
    if n_blocks <= 0:
        return []
    ext = tuple(sorted(
        (name, hashlib.sha1(np.ascontiguousarray(v).tobytes()).hexdigest())
        for name, v in extras.items()))
    keys: List[Hashable] = []
    for i in range(n_blocks):
        lo = max(i * block_size - n_prefix, 0)
        hi = max((i + 1) * block_size - n_prefix, 0)
        chunk = tuple(int(t) for t in tokens[lo:hi])
        keys.append((ext, chunk) if i == 0 else chunk)
    return keys


class _Node:
    __slots__ = ("key", "parent", "children", "block")

    def __init__(self, key: Optional[Hashable], parent: Optional["_Node"],
                 block: int = -1):
        self.key = key
        self.parent = parent
        self.children: Dict[Hashable, "_Node"] = {}
        self.block = block


class PrefixCache:
    """Host-side radix tree + refcounts + LRU over one ``BlockAllocator``.

    The scheduler owns the protocol: ``match`` at admission (pure),
    ``acquire`` once the reservation succeeds, ``record`` for the stats,
    ``insert`` when the prefill completes, ``release`` per block at
    retirement (True → the cache keeps the block; False → free it), and
    ``evict`` when the allocator runs dry.
    """

    def __init__(self, allocator, block_size: int,
                 registry: Optional[MetricsRegistry] = None):
        self.allocator = allocator
        self.block_size = block_size
        self._root = _Node(None, None)
        self._by_block: Dict[int, _Node] = {}
        self._ref: Dict[int, int] = {}
        self._lru: "OrderedDict[int, None]" = OrderedDict()  # oldest first
        # hit/evict counters live in the engine's metrics registry (PR 9)
        # so the exposition endpoints see them; the legacy int attributes
        # (``lookups``, ``skipped_tokens``, …) remain as read properties
        # over the same series. A standalone cache gets a private registry.
        reg = registry if registry is not None else MetricsRegistry()
        self._c_lookups = reg.counter(
            "serve_prefix_lookups_total", "admissions matched vs the tree")
        self._c_lookup_tokens = reg.counter(
            "serve_prefix_lookup_tokens_total",
            "prompt positions those admissions carried")
        self._c_hit_blocks = reg.counter(
            "serve_prefix_hit_blocks_total",
            "cached blocks spliced read-only into admissions")
        self._c_skipped_tokens = reg.counter(
            "serve_prefix_skipped_tokens_total",
            "prompt positions served from cache (prefill skipped)")
        self._c_inserted = reg.counter(
            "serve_prefix_inserted_blocks_total",
            "blocks newly tracked at prefill completion")
        self._c_evicted = reg.counter(
            "serve_prefix_evicted_blocks_total",
            "LRU blocks returned to the pool under pressure")

    # legacy counter surface — read-only views over the registry series
    @property
    def lookups(self) -> int:
        return int(self._c_lookups.value)

    @property
    def lookup_tokens(self) -> int:
        return int(self._c_lookup_tokens.value)

    @property
    def hit_blocks(self) -> int:
        return int(self._c_hit_blocks.value)

    @property
    def skipped_tokens(self) -> int:
        return int(self._c_skipped_tokens.value)

    @property
    def inserted_blocks(self) -> int:
        return int(self._c_inserted.value)

    @property
    def evicted_blocks(self) -> int:
        return int(self._c_evicted.value)

    # ------------------------------------------------------------------
    # Tree
    # ------------------------------------------------------------------

    @property
    def n_cached(self) -> int:
        return len(self._by_block)

    @property
    def n_evictable(self) -> int:
        return len(self._lru)

    @property
    def refcounts(self) -> Mapping[int, int]:
        """Read-only ``block -> refcount`` view over every cache-tracked
        block — the PoolSanitizer's contract for cross-checking slot
        tables against cache ownership without reaching into the tree."""
        return MappingProxyType(self._ref)

    @property
    def evictable_blocks(self) -> Mapping[int, None]:
        """Read-only view of the LRU set (refcount-0 blocks, oldest
        first). The conservation invariant the sanitizer enforces:
        a block is here if and only if its refcount is 0."""
        return MappingProxyType(self._lru)

    def match(self, keys: List[Hashable], width: int) -> List[int]:
        """Longest cached run of full-block keys, capped so at least one
        prompt position is always re-prefilled. Pure — admission may retry
        after a failed reservation without skewing the stats; call
        ``acquire`` + ``record`` once the blocks are actually mapped."""
        limit = (width - 1) // self.block_size
        node, blocks = self._root, []
        for key in keys[:limit]:
            child = node.children.get(key)
            if child is None:
                break
            blocks.append(child.block)
            node = child
        return blocks

    def insert(self, keys: List[Hashable], blocks) -> int:
        """Walk/extend the tree with a completed prefill's full prompt
        blocks. Existing nodes (the matched prefix, or a concurrent
        identical prefill that inserted first) are kept — the caller's
        block for such a position stays untracked and is freed at
        retirement. Newly created nodes take the caller's block with one
        reference (the caller still maps it). Returns blocks tracked."""
        node, created = self._root, 0
        for key, b in zip(keys, blocks):
            child = node.children.get(key)
            if child is None:
                b = int(b)
                child = _Node(key, node, b)
                node.children[key] = child
                self._by_block[b] = child
                self._ref[b] = 1
                created += 1
                self._c_inserted.inc()
            node = child
        return created

    # ------------------------------------------------------------------
    # References / LRU
    # ------------------------------------------------------------------

    def acquire(self, blocks: List[int]) -> None:
        """A request mapped these cached blocks into its table."""
        for b in blocks:
            self._ref[b] += 1
            self._lru.pop(b, None)

    def release(self, block: int) -> bool:
        """Drop one reference. True → the cache tracks the block (it stays
        in the pool; refcount 0 parks it on the LRU list, most recent
        last). False → untracked: the caller returns it to the free list."""
        if block not in self._ref:
            return False
        self._ref[block] -= 1
        assert self._ref[block] >= 0, block
        if self._ref[block] == 0:
            self._lru[block] = None
        return True

    def record(self, width: int, cached: int) -> None:
        """Stats for one successful admission: ``cached`` of the request's
        ``width`` prompt positions were served from the tree."""
        self._c_lookups.inc()
        self._c_lookup_tokens.inc(width)
        self._c_hit_blocks.inc(cached // self.block_size)
        self._c_skipped_tokens.inc(cached)

    # ------------------------------------------------------------------
    # Eviction
    # ------------------------------------------------------------------

    def _pop_node(self, node: _Node) -> None:
        del self._by_block[node.block]
        del self._lru[node.block]
        del self._ref[node.block]
        node.parent.children.pop(node.key)
        self._c_evicted.inc()

    def evict(self, n: int) -> int:
        """Return up to ``n`` least-recently-used unreferenced cached
        blocks to the allocator, pruning their tree nodes. Only leaves are
        eligible (an interior node still backs longer cached prefixes, and
        any live holder of a child also holds its parent — so leaves
        always free up first). One walk over the LRU list in recency
        order: each leaf met is evicted, then its parent chain follows
        while parents become childless and are themselves unreferenced —
        a parent enters the LRU list immediately before its last-released
        child, so chain-following keeps the old strictly-LRU order while
        staying linear (no head-rescan per freed block)."""
        freed: List[int] = []
        for victim in list(self._lru):
            if len(freed) >= n:
                break
            node = self._by_block.get(victim)
            if node is None or node.children:   # chain-evicted / interior
                continue
            self._pop_node(node)
            freed.append(victim)
            parent = node.parent
            while len(freed) < n and parent is not self._root \
                    and not parent.children and parent.block in self._lru:
                self._pop_node(parent)
                freed.append(parent.block)
                parent = parent.parent
        if freed:
            self.allocator.free(freed)
        return len(freed)

    # ------------------------------------------------------------------
    # Observability
    # ------------------------------------------------------------------

    @property
    def hit_rate(self) -> float:
        """Fraction of admitted prompt tokens served from the cache."""
        return self.skipped_tokens / self.lookup_tokens \
            if self.lookup_tokens else 0.0

    def stats(self) -> Dict[str, Any]:
        return {
            "prefix_lookups": self.lookups,
            "prefix_hit_rate": round(self.hit_rate, 4),
            "prefix_skipped_tokens": self.skipped_tokens,
            "prefix_cached_blocks": self.n_cached,
            "prefix_evictable_blocks": self.n_evictable,
            "prefix_inserted_blocks": self.inserted_blocks,
            "prefix_evicted_blocks": self.evicted_blocks,
        }
