"""QoS policy layer: tenant fairness, preemption state, admission control.

The slot scheduler (``serve/scheduler.py``) is a mechanism: slots, a
paged block pool, chunked prefill co-scheduled with decode. This module
holds the *policy* that arbitrates those mechanisms between tenants —
the pieces the ROADMAP's "Multi-tenant QoS" item names:

* **Weighted fair sharing** (``TenantScheduler``) — classic deficit
  round robin over tenants. Each tenant accrues credit in proportion to
  its configured weight and spends it on prompt tokens (admission charges
  the request's prefill width, a chunk pick charges one chunk), so a
  bursty tenant can never starve a streaming one of prefill bandwidth.
  FCFS order is preserved *within* a tenant; DRR only decides which
  tenant's head request goes next.
* **Preemption bookkeeping** (``ParkedState``) — the host-side record of
  a preempted request: either the swapped-out contents of its private
  KV blocks (``mode="swap"``) or nothing but its pinned prefix-cache
  references (``mode="recompute"``, the victim re-enters chunked prefill
  and replays its generated tokens through the radix cache).
* **Admission control** (``predict_ttft``) — a first-order TTFT model
  from the live token-budget backlog: every queued/prefilling prompt
  token ahead of a new arrival must flow through the per-step chunk
  budget, so predicted TTFT is (backlog / chunk) x the observed step
  time. ``QoSConfig.max_predicted_ttft_s`` turns that into a reject
  (``finish_reason="rejected"``) instead of a wedged queue.

Everything here is plain host-side Python — no device state, no jit.
The scheduler consumes the policy objects; this module never imports
the scheduler.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Tuple

# Bounded skip-ahead window for admission even when no QoSConfig is set:
# a pool-starved large prompt at the queue head no longer blocks smaller
# admissible requests behind it (head-of-line fix). Kept deliberately
# small so the head request's effective priority degrades by at most
# this many positions.
DEFAULT_ADMIT_LOOKAHEAD = 8


@dataclass(frozen=True)
class QoSConfig:
    """Multi-tenant QoS policy knobs (all optional; frozen/hashable).

    ``tenant_weights`` maps tenant name -> relative fair-share weight as
    a tuple of pairs (a dict would break hashability of the frozen
    ``EngineConfig`` that embeds this). Unlisted tenants weigh 1.0.
    ``quantum`` is the DRR credit per round in prompt tokens (0 -> the
    engine's prefill chunk). ``admit_lookahead`` bounds admission
    skip-ahead past an unservable queue head. ``max_predicted_ttft_s``
    rejects arrivals whose predicted TTFT exceeds it (0 -> disabled);
    ``max_waiting`` rejects on queue depth (0 -> unbounded).
    """
    tenant_weights: Tuple[Tuple[str, float], ...] = ()
    quantum: int = 0
    admit_lookahead: int = DEFAULT_ADMIT_LOOKAHEAD
    max_predicted_ttft_s: float = 0.0
    max_waiting: int = 0

    def __post_init__(self) -> None:
        for name, w in self.tenant_weights:
            if not w > 0:
                raise ValueError(
                    f"tenant_weights[{name!r}] = {w}: weights must be > 0 "
                    "(a zero-weight tenant would never accrue DRR deficit "
                    "and its requests could never be served)")
        if self.quantum < 0:
            raise ValueError("quantum must be >= 0")
        if self.admit_lookahead < 1:
            raise ValueError("admit_lookahead must be >= 1")
        if self.max_predicted_ttft_s < 0:
            raise ValueError("max_predicted_ttft_s must be >= 0")
        if self.max_waiting < 0:
            raise ValueError("max_waiting must be >= 0")

    def weight(self, tenant: str) -> float:
        for name, w in self.tenant_weights:
            if name == tenant:
                return w
        return 1.0


class TenantScheduler:
    """Deficit round robin over tenants, cost unit = prompt tokens.

    ``pick(candidates)`` takes ``{tenant: cost_of_its_head_item}`` and
    returns the tenant whose head item is served next, charging its
    deficit. One call serves one item. Tenants keep their deficit across
    calls (a cost larger than one quantum accumulates over rounds);
    tenants absent from ``candidates`` are idle — their deficit resets
    and they drop out of the rotation, per classic DRR, so a tenant
    cannot bank credit while it has nothing to run.
    """

    def __init__(self, config: Optional[QoSConfig], quantum: int):
        self._cfg = config or QoSConfig()
        self._quantum = max(int(quantum), 1)
        self._deficit: Dict[str, float] = {}
        self._order: List[str] = []      # first-appearance rotation order
        self._ptr = 0
        self._visiting: Optional[str] = None   # tenant granted this visit's
        #                                      # quantum (one grant per visit)

    def _sync(self, candidates: Mapping[str, int]) -> None:
        # prune idle tenants (reset deficit), keeping the pointer aimed
        # at the same surviving tenant; enrol new ones at the rotation end
        keep = [t for t in self._order if t in candidates]
        if len(keep) != len(self._order):
            at = self._order[self._ptr] if self._ptr < len(self._order) \
                else None
            for t in self._order:
                if t not in candidates:
                    self._deficit.pop(t, None)
            self._order = keep
            self._ptr = self._order.index(at) if at in self._order else 0
            if self._visiting not in candidates:
                self._visiting = None
        for t in candidates:
            if t not in self._deficit:
                self._deficit[t] = 0.0
                self._order.append(t)
        if self._order and self._ptr >= len(self._order):
            self._ptr = 0

    def pick(self, candidates: Mapping[str, int]) -> Optional[str]:
        """Next tenant to serve, or None when no candidates exist."""
        if not candidates:
            return None
        self._sync(candidates)
        # Bounded loop: each full rotation adds >= quantum * min_weight
        # (> 0, enforced by QoSConfig) to every candidate's deficit, so
        # some tenant's deficit reaches its head cost in finitely many
        # rounds. Cap defensively anyway.
        max_rounds = len(self._order) * (
            2 + max(candidates.values()) // self._quantum)
        for _ in range(max(max_rounds, 1) * len(self._order)):
            t = self._order[self._ptr]
            # one quantum grant per *visit*: the rotation stays on a
            # tenant while its banked deficit covers further head items,
            # and moves on the moment it cannot — re-granting on every
            # pick would hand the heaviest tenant the whole line
            if self._visiting != t:
                self._deficit[t] += self._quantum * self._cfg.weight(t)
                self._visiting = t
            cost = candidates[t]
            if self._deficit[t] >= cost:
                self._deficit[t] -= cost
                return t
            self._ptr = (self._ptr + 1) % len(self._order)
            self._visiting = None
        raise AssertionError("DRR failed to converge")  # pragma: no cover

    def refund(self, tenant: str, cost: int) -> None:
        """Return a charge taken by ``pick`` whose item was not served.

        ``pick`` debits the head item's cost before the caller knows the
        admit will succeed (slot or pool pressure can still refuse it);
        refunding keeps a tenant's long-run share independent of how
        often its head request bounces.
        """
        if tenant in self._deficit:
            self._deficit[tenant] += cost


def predict_ttft(backlog_tokens: int, chunk: int, step_s: float) -> float:
    """First-order TTFT estimate for a new arrival.

    Every prompt token queued or still prefilling ahead of the arrival
    flows through the per-step chunk budget (one chunk per step), so the
    arrival's first token is about ``ceil(backlog / chunk)`` steps away
    at the observed (EWMA) step time. Deliberately simple — the point is
    a load-shedding signal that tracks the backlog, not a simulator.
    """
    chunk = max(int(chunk), 1)
    steps = -(-int(backlog_tokens) // chunk) + 1     # +1: own first chunk
    return steps * max(step_s, 0.0)


@dataclass
class ParkedState:
    """Host-side record of one preempted (parked) request.

    ``mode`` is "swap" or "recompute". Either way the request keeps its
    prefix-cache references (``pinned``) so shared blocks cannot be
    evicted while it is parked — the resume re-acquires them through the
    normal match path and the pin is dropped then.

    For "swap", ``payload`` holds the host copies of every un-tracked
    (private) block's pool rows plus the slot's direct (non-paged) cache
    leaves, ``private`` the logical order those blocks had in the block
    table, and ``pos``/``last_tok`` the decode cursor; resume allocates
    fresh physical blocks, scatters the payload back, and re-occupies a
    slot with no prefill at all. For "recompute" only the pin survives:
    resume re-enters chunked prefill over prompt + generated tokens.
    """
    req: Any
    mode: str
    pinned: Tuple[int, ...] = ()          # cache-tracked blocks (ref held)
    shared: Tuple[Tuple[int, int], ...] = ()   # (logical idx, phys block)
    private: Tuple[Tuple[int, int], ...] = ()  # (logical idx, phys block)
    payload: Optional[Dict[str, Any]] = None   # swap: host-side contents
    pos: int = 0
    last_tok: int = 0
    n_alloc: int = 0
    extras: Dict[str, Any] = field(default_factory=dict)  # e.g. weights


def tenant_of(req: Any) -> str:
    """Tenant identity of a request (scheduler ``Request`` or raw)."""
    params = getattr(req, "params", None)
    return getattr(params, "tenant", None) or "default"


def priority_of(req: Any) -> int:
    params = getattr(req, "params", None)
    return int(getattr(params, "priority", 0) or 0)
