"""Unified model assembly for every assigned architecture family.

One ``Model`` object per ModelConfig exposes:

* ``param_specs()`` — ParamSpec pytree (single source for init/sharding/dry-run)
* ``init(key)`` — materialized parameters
* ``forward(params, batch)`` — teacher-forced logits (training/eval)
* ``loss(params, batch)`` — next-token CE with masking (VLM/audio aware)
* ``prefill(params, batch)`` — full-sequence forward that also builds the
  decode state (KV caches / recurrent states), right-sized to ``cache_len``
* ``decode_step(params, cache, tokens, pos)`` — ONE new token (serve_step)
* ``init_cache`` / ``cache_shapes`` — zeros or ShapeDtypeStructs (dry-run)

Layer stacks are ``jax.lax.scan``-ed over stacked parameters (compile time
independent of depth — essential for the 126-layer 405B dry-run) with an
optional remat policy. Heterogeneous stacks (xLSTM's periodic sLSTM, Zamba2's
periodically-applied *shared* attention block) scan over homogeneous groups.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import attention as attn
from . import moe as moe_lib
from . import ssm as ssm_lib
from .layers import (cross_entropy_loss, embed, embedding_specs, rms_norm,
                     swiglu, swiglu_specs, unembed)
from .params import ParamSpec, init_params, is_spec

Array = jnp.ndarray


def stack_specs(tree, n: int):
    """Prepend a scanned layer dim to every ParamSpec in the tree."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layer",) + s.logical,
                            s.init, s.scale),
        tree, is_leaf=is_spec)


def _norm_spec(d):
    return ParamSpec((d,), (None,), "ones")


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def scan_layers(body, carry, xs, cfg: ModelConfig):
    """``jax.lax.scan`` over a stacked layer dim — or, when ``cfg.unroll``
    is set (dry-run depth probes), an unrolled python loop producing
    straight-line HLO with identical semantics."""
    if not cfg.unroll:
        return jax.lax.scan(body, carry, xs)
    L = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(L):
        layer = jax.tree.map(lambda a, i=i: a[i], xs)
        carry, y = body(carry, layer)
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *ys)
    return carry, stacked


@dataclass(frozen=True)
class PagedLayout:
    """Block-table indirection descriptor for the pageable cache leaves.

    ``seq_axes`` mirrors the cache pytree: for leaves that live in the
    shared block pool it gives the index of the *sequence* axis in the
    contiguous layout (e.g. attention KV (L, B, S, KV, dh) → 2); leaves
    that stay on the direct per-slot path (recurrent states, enc-dec
    cross-attention KV) carry ``-1``. In the pool layout a paged leaf's
    (B, S) pair is replaced by (n_blocks, block_size) and addressed through
    a per-slot block table — so every slot pays only for the blocks it has
    actually written instead of a full-length cache row.
    """
    block_size: int
    seq_axes: Any


@dataclass(frozen=True)
class CacheSpec:
    """Layout descriptor for a model family's decode cache.

    ``batch_axes`` is a pytree with the same structure as the cache whose
    leaves give the index of the request/slot (batch) axis in the matching
    cache leaf — e.g. attention KV caches are (L, B, S, KV, dh) → 1, Mamba2
    states are (G, gm, B, ...) → 2. Slot servers use it to splice one
    request's prefill state into a batched cache without knowing the family.

    ``paged`` (optional) describes the block-pool variant of the same cache:
    which leaves are addressed through a block table and at what block size.
    """
    batch_axes: Any
    paged: Optional[PagedLayout] = None

    def shifted(self, by: int = 1) -> "CacheSpec":
        """Spec for the same cache with ``by`` extra dims inserted before
        every batch axis (e.g. the stacked-expert K dim of the mixture
        decode core, which sits after each leaf's scan dim). Memoized so
        repeat callers share one spec — and with it the jitted splice
        functions below (a fresh spec would recompile them)."""
        memo = self.__dict__.setdefault("_shifted_memo", {})
        if by not in memo:
            paged = self.paged
            if paged is not None:
                paged = PagedLayout(paged.block_size,
                                    jax.tree.map(lambda a: a + by if a >= 0
                                                 else a, paged.seq_axes))
            memo[by] = CacheSpec(
                jax.tree.map(lambda a: a + by, self.batch_axes), paged)
        return memo[by]

    def insert(self, cache, row_cache, slot: int):
        """Write a single-request cache (batch extent 1 on each leaf's batch
        axis) into ``cache`` at slot index ``slot``."""
        return self._insert_jit(cache, row_cache, jnp.int32(slot))

    @cached_property
    def _insert_jit(self):
        # one jitted splice for ALL slots (the index is a traced scalar):
        # per-leaf unjitted updates each dispatch separately and copy the
        # whole leaf, which shows up as per-admission latency
        def f(cache, row_cache, slot):
            return jax.tree.map(
                lambda full, row, ax: jax.lax.dynamic_update_slice_in_dim(
                    full, row.astype(full.dtype), slot, axis=ax),
                cache, row_cache, self.batch_axes)
        return jax.jit(f)

    def insert_paged(self, cache, row_cache, slot: int, blocks: Array):
        """Splice a single-request contiguous prefill cache into the paged
        cache: pool leaves scatter the first ``len(blocks) * block_size``
        cache-row positions into the physical blocks listed in ``blocks``
        (int32 (nb,)); direct leaves behave exactly like ``insert``."""
        assert self.paged is not None, "insert_paged needs a paged spec"
        return self._insert_paged_jit(cache, row_cache, jnp.int32(slot),
                                      blocks)

    @cached_property
    def _insert_paged_jit(self):
        bs = self.paged.block_size

        # jitted across slots (traced scalar); retraces once per distinct
        # block-count nb — bounded by the slot's table length
        def f(cache, row_cache, slot, blocks):
            nb = blocks.shape[0]

            def one(full, row, b_ax, s_ax):
                if s_ax < 0:
                    return jax.lax.dynamic_update_slice_in_dim(
                        full, row.astype(full.dtype), slot, axis=b_ax)
                # pool leaf: contiguous row is (..., 1, S, ...) with the
                # batch extent-1 at b_ax and the sequence at
                # s_ax == b_ax + 1; the pool is (..., P, bs, ...) at the
                # same axis positions.
                assert s_ax == b_ax + 1, (b_ax, s_ax)
                row = jnp.squeeze(row, axis=b_ax)      # seq now at b_ax
                take = min(nb * bs, row.shape[b_ax])
                row = jax.lax.slice_in_dim(row, 0, take, axis=b_ax)
                if take < nb * bs:                     # cache_len ∤ block
                    pad = [(0, 0)] * row.ndim
                    pad[b_ax] = (0, nb * bs - take)
                    row = jnp.pad(row, pad)
                row = row.reshape(row.shape[:b_ax] + (nb, bs)
                                  + row.shape[b_ax + 1:])
                idx = (slice(None),) * b_ax + (blocks,)
                return full.at[idx].set(row.astype(full.dtype))

            seq = self.paged.seq_axes
            return jax.tree.map(one, cache, row_cache, self.batch_axes, seq)
        return jax.jit(f)

    def insert_direct(self, cache, carry, slot: int):
        """Write a chunked-prefill carry (single-request DIRECT-leaf decode
        states; pool-leaf entries are placeholders — their data was written
        straight into the block pool chunk by chunk) into the batched cache
        at ``slot``. Without a paged layout every leaf is direct."""
        return self._insert_direct_jit(cache, carry, jnp.int32(slot))

    @cached_property
    def _insert_direct_jit(self):
        seq = self.paged.seq_axes if self.paged is not None else \
            jax.tree.map(lambda _: -1, self.batch_axes)

        def f(cache, carry, slot):
            def one(full, row, ax, s_ax):
                if s_ax >= 0:
                    return full
                return jax.lax.dynamic_update_slice_in_dim(
                    full, row.astype(full.dtype), slot, axis=ax)

            return jax.tree.map(one, cache, carry, self.batch_axes, seq)
        return jax.jit(f)

    def take(self, cache, slot: int):
        """Read one slot's cache back out (batch extent 1 preserved)."""
        return self._take_jit(cache, jnp.int32(slot))

    @cached_property
    def _take_jit(self):
        def f(cache, slot):
            return jax.tree.map(
                lambda full, ax: jax.lax.dynamic_slice_in_dim(full, slot, 1,
                                                              axis=ax),
                cache, self.batch_axes)
        return jax.jit(f)

    def swap_out(self, cache, slot: int, blocks):
        """Read one slot's paged decode state out for host-side parking
        (QoS preemption by swap): pool leaves gather the listed physical
        blocks' contents (``take`` cannot do this — it slices batch axes,
        and a pool leaf's batch axis is the *block* axis shared by every
        slot); direct leaves slice the slot's row, extent 1 preserved.
        The payload pytree mirrors the cache and round-trips through
        ``swap_in``. Not jitted: parking is rare and the block count
        varies per victim, so a trace per count would cost more than the
        per-leaf dispatches."""
        assert self.paged is not None, "swap_out needs a paged spec"
        blocks = jnp.asarray(blocks, jnp.int32)

        def one(full, b_ax, s_ax):
            if s_ax < 0:
                return jax.lax.dynamic_slice_in_dim(full, slot, 1,
                                                    axis=b_ax)
            # pool leaf: (..., P, bs, ...) with the block axis at b_ax
            idx = (slice(None),) * b_ax + (blocks,)
            return full[idx]

        return jax.tree.map(one, cache, self.batch_axes,
                            self.paged.seq_axes)

    def swap_in(self, cache, payload, slot: int, blocks):
        """Scatter a ``swap_out`` payload back: pool-leaf contents land in
        the (freshly allocated) physical blocks listed in ``blocks`` —
        positionally matching the payload's gather order — and direct
        leaves overwrite the resumed slot's row. ``slot``/``blocks`` need
        not match the ones swapped out; the block *table* mapping logical
        to physical order is the caller's to rebuild."""
        assert self.paged is not None, "swap_in needs a paged spec"
        blocks = jnp.asarray(blocks, jnp.int32)

        def one(full, row, b_ax, s_ax):
            row = jnp.asarray(row, full.dtype)
            if s_ax < 0:
                return jax.lax.dynamic_update_slice_in_dim(full, row, slot,
                                                           axis=b_ax)
            idx = (slice(None),) * b_ax + (blocks,)
            return full.at[idx].set(row)

        return jax.tree.map(one, cache, payload, self.batch_axes,
                            self.paged.seq_axes)


@dataclass
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------------
    # Parameter specs
    # ------------------------------------------------------------------

    def _block_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        D = cfg.d_model
        if cfg.family in ("dense", "vlm"):
            return {"ln1": _norm_spec(D), "attn": attn.attention_specs(cfg),
                    "ln2": _norm_spec(D), "ffn": swiglu_specs(D, cfg.d_ff)}
        if cfg.family == "moe":
            return {"ln1": _norm_spec(D), "attn": attn.attention_specs(cfg),
                    "ln2": _norm_spec(D), "moe": moe_lib.moe_specs(cfg)}
        if cfg.family == "audio":      # decoder block
            return {"ln1": _norm_spec(D), "self_attn": attn.attention_specs(cfg),
                    "ln2": _norm_spec(D), "cross_attn": attn.attention_specs(cfg),
                    "ln3": _norm_spec(D), "ffn": swiglu_specs(D, cfg.d_ff)}
        if cfg.family == "ssm":        # xLSTM group: (k−1) mLSTM + 1 sLSTM
            gm = self.group_m
            return {
                "m_ln": stack_specs(_norm_spec(D), gm),
                "mlstm": stack_specs(ssm_lib.mlstm_specs(cfg), gm),
                "s_ln": _norm_spec(D),
                "slstm": ssm_lib.slstm_specs(cfg),
            }
        if cfg.family == "hybrid":     # Zamba2 group: k Mamba2 (+ shared attn)
            gm = self.group_m
            return {
                "m_ln": stack_specs(_norm_spec(D), gm),
                "mamba": stack_specs(ssm_lib.mamba2_specs(cfg), gm),
            }
        raise ValueError(cfg.family)

    @cached_property
    def group_m(self) -> int:
        """Homogeneous sub-layers per scanned group (ssm/hybrid)."""
        cfg = self.cfg
        if cfg.family == "ssm":
            k = cfg.ssm.slstm_every or cfg.n_layers
            return max(k - 1, 1)
        if cfg.family == "hybrid":
            return cfg.ssm.shared_attn_every or cfg.n_layers
        return 1

    @cached_property
    def n_groups(self) -> int:
        cfg = self.cfg
        if cfg.family == "ssm":
            return cfg.n_layers // (self.group_m + 1)
        if cfg.family == "hybrid":
            return cfg.n_layers // self.group_m
        return cfg.n_layers

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        D = cfg.d_model
        specs: Dict[str, Any] = {
            "embed": embedding_specs(cfg.padded_vocab, D, cfg.tie_embeddings),
            "blocks": stack_specs(self._block_specs(), self.n_groups),
            "final_norm": _norm_spec(D),
        }
        if cfg.family == "vlm":
            specs["projector"] = {
                "w1": ParamSpec((cfg.vision_dim, D), ("vision", "embed"), "scaled"),
                "w2": ParamSpec((D, D), ("embed", None), "scaled"),
            }
        if cfg.family == "audio":
            enc_block = {"ln1": _norm_spec(D), "attn": attn.attention_specs(cfg),
                         "ln2": _norm_spec(D), "ffn": swiglu_specs(D, cfg.d_ff)}
            specs["encoder"] = {
                "in_proj": ParamSpec((cfg.audio_dim, D), ("audio", "embed"), "scaled"),
                "blocks": stack_specs(enc_block, cfg.n_enc_layers),
                "norm": _norm_spec(D),
            }
        if cfg.family == "hybrid":
            specs["shared_attn"] = {
                "ln1": _norm_spec(D), "attn": attn.attention_specs(cfg),
                "ln2": _norm_spec(D), "ffn": swiglu_specs(D, cfg.d_ff),
            }
        return specs

    def init(self, key, dtype=None):
        return init_params(key, self.param_specs(),
                           dtype or self.cfg.pdtype)

    # ------------------------------------------------------------------
    # Input embedding (modality frontends are stubs per DESIGN.md)
    # ------------------------------------------------------------------

    def _embed_inputs(self, params, batch) -> Array:
        cfg = self.cfg
        x = embed(params["embed"], batch["tokens"], cfg.cdtype)
        if cfg.family == "vlm":
            p = params["projector"]
            patches = batch["patches"].astype(cfg.cdtype)     # (B, Np, Dv)
            proj = jax.nn.gelu(patches @ p["w1"].astype(cfg.cdtype))
            proj = proj @ p["w2"].astype(cfg.cdtype)
            x = jnp.concatenate([proj, x], axis=1)            # image prefix
        return x

    def _encode_audio(self, params, frames: Array) -> Array:
        cfg = self.cfg
        enc = params["encoder"]
        x = frames.astype(cfg.cdtype) @ enc["in_proj"].astype(cfg.cdtype)

        def body(h, layer):
            h = h + attn.full_attention(layer["attn"],
                                        rms_norm(h, layer["ln1"], cfg.norm_eps),
                                        cfg, causal=False)
            h = h + swiglu(layer["ffn"], rms_norm(h, layer["ln2"], cfg.norm_eps))
            return h, None

        x, _ = scan_layers(_maybe_remat(body, cfg), x, enc["blocks"], cfg)
        return rms_norm(x, enc["norm"], cfg.norm_eps)

    # ------------------------------------------------------------------
    # Teacher-forced forward (train / eval)
    # ------------------------------------------------------------------

    def forward(self, params, batch, *, use_kernel: bool = False) -> Array:
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        enc_out = None
        if cfg.family == "audio":
            enc_out = self._encode_audio(params, batch["frames"])

        block = self._train_block(use_kernel, enc_out,
                                  params.get("shared_attn"))
        x, _ = scan_layers(_maybe_remat(block, cfg), x, params["blocks"], cfg)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return unembed(params["embed"], x, cfg.tie_embeddings, cfg.vocab)

    def _train_block(self, use_kernel: bool, enc_out: Optional[Array],
                     shared=None):
        cfg = self.cfg

        if cfg.family in ("dense", "vlm", "moe"):
            def body(x, layer):
                h = x + attn.full_attention(
                    layer["attn"], rms_norm(x, layer["ln1"], cfg.norm_eps),
                    cfg, causal=True, use_kernel=use_kernel)
                y = rms_norm(h, layer["ln2"], cfg.norm_eps)
                if cfg.family == "moe":
                    return h + moe_lib.moe_ffn(layer["moe"], y, cfg), None
                return h + swiglu(layer["ffn"], y), None
            return body

        if cfg.family == "audio":
            def body(x, layer):
                h = x + attn.full_attention(
                    layer["self_attn"], rms_norm(x, layer["ln1"], cfg.norm_eps),
                    cfg, causal=True, use_kernel=use_kernel)
                kv = attn.encode_kv(layer["cross_attn"], enc_out, cfg)
                h = h + attn.cross_attention(
                    layer["cross_attn"], rms_norm(h, layer["ln2"], cfg.norm_eps),
                    kv, cfg)
                return h + swiglu(layer["ffn"],
                                  rms_norm(h, layer["ln3"], cfg.norm_eps)), None
            return body

        if cfg.family == "ssm":
            def body(x, group):
                def m_body(h, m):
                    return h + ssm_lib.mlstm_block(
                        m["core"], rms_norm(h, m["ln"], cfg.norm_eps), cfg,
                        use_kernel=use_kernel), None
                x, _ = scan_layers(
                    m_body, x, {"ln": group["m_ln"], "core": group["mlstm"]}, cfg)
                y, _ = ssm_lib.slstm_scan(
                    group["slstm"], rms_norm(x, group["s_ln"], cfg.norm_eps), cfg)
                return x + y, None
            return body

        if cfg.family == "hybrid":
            def body(x, group):
                def m_body(h, m):
                    return h + ssm_lib.mamba2_block(
                        m["core"], rms_norm(h, m["ln"], cfg.norm_eps), cfg,
                        use_kernel=use_kernel), None
                x, _ = scan_layers(
                    m_body, x, {"ln": group["m_ln"], "core": group["mamba"]}, cfg)
                h = x + attn.full_attention(
                    shared["attn"], rms_norm(x, shared["ln1"], cfg.norm_eps),
                    cfg, causal=True, use_kernel=use_kernel)
                return h + swiglu(shared["ffn"],
                                  rms_norm(h, shared["ln2"], cfg.norm_eps)), None
            return body

        raise ValueError(cfg.family)

    def loss(self, params, batch) -> Tuple[Array, Dict[str, Array]]:
        cfg = self.cfg
        logits = self.forward(params, batch)
        labels = batch["labels"]
        if cfg.family == "vlm":     # image prefix carries no LM loss
            Np = cfg.n_patches
            logits = logits[:, Np:]
        mask = batch.get("loss_mask")
        nll = cross_entropy_loss(logits[:, :-1], labels[:, 1:],
                                 None if mask is None else mask[:, 1:])
        return nll, {"loss": nll}

    # ------------------------------------------------------------------
    # Decode state (KV caches / recurrent states)
    # ------------------------------------------------------------------

    def _cache_struct(self, batch: int, cache_len: int, as_shape: bool):
        """Pytree of zeros (as_shape=False) or ShapeDtypeStructs."""
        cfg = self.cfg
        dt = cfg.cdtype
        L, KV, dh = self.n_groups, cfg.n_kv_heads, cfg.head_dim
        win = cfg.sliding_window
        S_kv = min(cache_len, win) if win > 0 else cache_len
        mk = (lambda s, d=dt: jax.ShapeDtypeStruct(s, d)) if as_shape \
            else (lambda s, d=dt: jnp.zeros(s, d))
        if cfg.family in ("dense", "vlm", "moe"):
            kv = (L, batch, S_kv, KV, dh)
            return {"k": mk(kv), "v": mk(kv)}
        if cfg.family == "audio":
            kv = (L, batch, S_kv, KV, dh)
            xkv = (L, batch, cfg.n_audio_frames, KV, dh)
            return {"k": mk(kv), "v": mk(kv),
                    "xk": mk(xkv), "xv": mk(xkv)}
        if cfg.family == "ssm":
            G, gm = self.n_groups, self.group_m
            m_shape = (G, gm) + ssm_lib.mlstm_state_shape(cfg, batch)
            s_shapes = ssm_lib.slstm_state_shapes(cfg, batch)
            return {"mlstm": mk(m_shape, jnp.float32),
                    "slstm": tuple(mk((G,) + s, jnp.float32)
                                   for s in s_shapes)}
        if cfg.family == "hybrid":
            G, gm = self.n_groups, self.group_m
            ssm_s, conv_s = ssm_lib.mamba2_state_shapes(cfg, batch)
            kv = (G, batch, S_kv, KV, dh)
            return {"ssm": mk((G, gm) + ssm_s, jnp.float32),
                    "conv": mk((G, gm) + conv_s),
                    "k": mk(kv), "v": mk(kv)}
        raise ValueError(cfg.family)

    def init_cache(self, batch: int, cache_len: int):
        return self._cache_struct(batch, cache_len, as_shape=False)

    def cache_shapes(self, batch: int, cache_len: int):
        return self._cache_struct(batch, cache_len, as_shape=True)

    @property
    def prefix_cacheable(self) -> bool:
        """True when a prompt's pool-resident KV fully determines its
        decode state, so the radix prefix cache may splice cached blocks
        into a new request's block table and skip prefilling those
        positions. Attention-only decode state qualifies (dense/moe/vlm;
        audio's cross-attention KV is recomputed per request from the
        frames, independent of decoder positions). Recurrent families
        (ssm, hybrid) carry state that accumulates across EVERY prompt
        position outside the pool — skipping a cached prefix would
        silently corrupt it — so they take the direct (uncached) path."""
        return self.cfg.family not in ("ssm", "hybrid")

    @property
    def speculative_capable(self) -> bool:
        """True when a multi-token verify span can be ROLLED BACK by
        position: rejecting a draft must leave the decode state exactly
        as if the rejected positions were never fed. Paged attention KV
        qualifies — rejected-tail writes sit at positions the causal mask
        hides, and the next span overwrites them before anything attends
        there. Recurrent families (ssm, hybrid) fold every fed token into
        a running state that cannot be positionally unwound, and
        sliding-window (ring) caches overwrite live slots when the span
        wraps — both degrade to the vanilla one-token step instead (the
        scheduler consults this flag; speculation is a pure optimization,
        so degrading costs correctness nothing)."""
        return self.cfg.family not in ("ssm", "hybrid") \
            and self.cfg.sliding_window <= 0

    def cache_spec(self, block_size: int = 0) -> CacheSpec:
        """Batch-axis descriptor matching ``_cache_struct``'s layouts.

        With ``block_size > 0`` the spec also carries the paged layout:
        attention KV leaves page through a block pool; recurrent states and
        enc-dec cross-attention KV (written once, fixed extent) stay on the
        direct per-slot path (seq axis ``-1``).

        Memoized per ``block_size``: every server built on this model gets
        the SAME spec object, so the spec's jitted splice functions
        (``insert``/``insert_paged``/``take``) compile once per model
        instead of once per server.
        """
        memo = self.__dict__.setdefault("_cache_spec_memo", {})
        if block_size in memo:
            return memo[block_size]
        cfg = self.cfg
        if cfg.family in ("dense", "vlm", "moe"):
            axes = {"k": 1, "v": 1}
            seq = {"k": 2, "v": 2}
        elif cfg.family == "audio":
            axes = {"k": 1, "v": 1, "xk": 1, "xv": 1}
            seq = {"k": 2, "v": 2, "xk": -1, "xv": -1}
        elif cfg.family == "ssm":
            n_slstm = len(ssm_lib.slstm_state_shapes(cfg, 1))
            axes = {"mlstm": 2, "slstm": tuple(1 for _ in range(n_slstm))}
            seq = {"mlstm": -1, "slstm": tuple(-1 for _ in range(n_slstm))}
        elif cfg.family == "hybrid":
            axes = {"ssm": 2, "conv": 2, "k": 1, "v": 1}
            seq = {"ssm": -1, "conv": -1, "k": 2, "v": 2}
        else:
            raise ValueError(cfg.family)
        paged = PagedLayout(block_size, seq) if block_size > 0 else None
        memo[block_size] = CacheSpec(axes, paged)
        return memo[block_size]

    def _paged_cache_struct(self, n_slots: int, n_blocks: int,
                            block_size: int, cache_len: int, as_shape: bool):
        """Paged decode cache: pool leaves replace their (B, S) pair with
        (n_blocks, block_size) — one shared pool addressed through per-slot
        block tables; direct leaves keep their n_slots rows."""
        base = self._cache_struct(n_slots, cache_len, as_shape=True)
        spec = self.cache_spec(block_size)

        def one(s, b_ax, s_ax):
            if s_ax < 0:
                return s
            shape = s.shape[:b_ax] + (n_blocks, block_size) \
                + s.shape[s_ax + 1:]
            return jax.ShapeDtypeStruct(shape, s.dtype)

        shapes = jax.tree.map(one, base, spec.batch_axes,
                              spec.paged.seq_axes)
        if as_shape:
            return shapes
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)

    def init_paged_cache(self, n_slots: int, n_blocks: int, block_size: int,
                         cache_len: int):
        return self._paged_cache_struct(n_slots, n_blocks, block_size,
                                        cache_len, as_shape=False)

    def paged_cache_shapes(self, n_slots: int, n_blocks: int,
                           block_size: int, cache_len: int):
        return self._paged_cache_struct(n_slots, n_blocks, block_size,
                                        cache_len, as_shape=True)

    # ------------------------------------------------------------------
    # Prefill: full sequence forward + decode state construction
    # ------------------------------------------------------------------

    def prefill(self, params, batch, cache_len: int, *,
                use_kernel: bool = False):
        """Returns (logits (B,S,V), cache). For windowed configs the cache
        holds the last ``window`` positions (ring layout, slot = pos % win)."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        win = cfg.sliding_window
        S_kv = min(cache_len, win) if win > 0 else cache_len

        def pad_kv(k):
            """(B,S,KV,dh) → ring/right-padded (B,S_kv,KV,dh)."""
            if win > 0 and S >= S_kv:
                tail = k[:, S - S_kv:]
                # ring layout: slot = pos % S_kv
                start = (S - S_kv) % S_kv
                return jnp.roll(tail, start, axis=1)
            return jnp.pad(k, [(0, 0), (0, S_kv - S), (0, 0), (0, 0)])

        if cfg.family in ("dense", "vlm", "moe"):
            def body(x, layer):
                h_in = rms_norm(x, layer["ln1"], cfg.norm_eps)
                a, (k, v) = attn.prefill_attention(layer["attn"], h_in, cfg, S,
                                                   use_kernel=use_kernel)
                h = x + a
                y = rms_norm(h, layer["ln2"], cfg.norm_eps)
                out = h + (moe_lib.moe_ffn(layer["moe"], y, cfg)
                           if cfg.family == "moe" else swiglu(layer["ffn"], y))
                return out, (pad_kv(k[:, :S]), pad_kv(v[:, :S]))
            x, (ks, vs) = scan_layers(body, x, params["blocks"], cfg)
            cache = {"k": ks, "v": vs}

        elif cfg.family == "audio":
            enc_out = self._encode_audio(params, batch["frames"])

            def body(x, layer):
                h_in = rms_norm(x, layer["ln1"], cfg.norm_eps)
                a, (k, v) = attn.prefill_attention(layer["self_attn"], h_in,
                                                   cfg, S, use_kernel=use_kernel)
                h = x + a
                xkv = attn.encode_kv(layer["cross_attn"], enc_out, cfg)
                h = h + attn.cross_attention(
                    layer["cross_attn"], rms_norm(h, layer["ln2"], cfg.norm_eps),
                    xkv, cfg)
                out = h + swiglu(layer["ffn"],
                                 rms_norm(h, layer["ln3"], cfg.norm_eps))
                return out, (pad_kv(k[:, :S]), pad_kv(v[:, :S]),
                             xkv[0], xkv[1])
            x, (ks, vs, xks, xvs) = scan_layers(body, x, params["blocks"], cfg)
            cache = {"k": ks, "v": vs, "xk": xks, "xv": xvs}

        elif cfg.family == "ssm":
            def body(x, group):
                def m_body(h, m):
                    q, k, v, log_f, z = ssm_lib._mlstm_qkvg(
                        m["core"], rms_norm(h, m["ln"], cfg.norm_eps), cfg)
                    v_ext = jnp.concatenate([v, jnp.ones_like(v[..., :1])], -1)
                    y, st = ssm_lib.chunked_linear_attention(
                        q, k, v_ext, log_f, cfg.ssm.chunk,
                        use_kernel=use_kernel)
                    num, den = y[..., :-1], y[..., -1:]
                    hh = (num / (jnp.abs(den) + 1.0)).reshape(B, S, -1)
                    hh = rms_norm(hh, m["core"]["norm"], cfg.norm_eps) \
                        * jax.nn.silu(z)
                    return h + hh @ m["core"]["w_out"].astype(h.dtype), st
                x, m_states = scan_layers(
                    m_body, x, {"ln": group["m_ln"], "core": group["mlstm"]}, cfg)
                y, s_state = ssm_lib.slstm_scan(
                    group["slstm"], rms_norm(x, group["s_ln"], cfg.norm_eps), cfg)
                return x + y, (m_states, s_state)
            x, (m_states, s_states) = scan_layers(body, x, params["blocks"], cfg)
            cache = {"mlstm": m_states, "slstm": s_states}

        elif cfg.family == "hybrid":
            shared = params["shared_attn"]

            def body(x, group):
                def m_body(h, m):
                    y, st = self._mamba2_prefill(m["core"],
                                                 rms_norm(h, m["ln"],
                                                          cfg.norm_eps),
                                                 use_kernel)
                    return h + y, st
                x, m_states = scan_layers(
                    m_body, x, {"ln": group["m_ln"], "core": group["mamba"]}, cfg)
                h_in = rms_norm(x, shared["ln1"], cfg.norm_eps)
                a, (k, v) = attn.prefill_attention(shared["attn"], h_in, cfg, S,
                                                   use_kernel=use_kernel)
                h = x + a
                out = h + swiglu(shared["ffn"],
                                 rms_norm(h, shared["ln2"], cfg.norm_eps))
                return out, (m_states, pad_kv(k[:, :S]), pad_kv(v[:, :S]))
            x, (m_states, ks, vs) = scan_layers(body, x, params["blocks"], cfg)
            cache = {"ssm": m_states[0], "conv": m_states[1],
                     "k": ks, "v": vs}
        else:
            raise ValueError(cfg.family)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], x, cfg.tie_embeddings, cfg.vocab)
        return logits, cache

    # ------------------------------------------------------------------
    # Chunked prefill: consume a prompt in fixed-size chunks
    # ------------------------------------------------------------------

    def embed_prompt(self, params, batch) -> Array:
        """Embedded decoder inputs for chunked prefill: token embeddings
        plus any modality prefix (VLM image projection). (1, W, D)."""
        return self._embed_inputs(params, batch)

    def init_chunk_carry(self, params, batch, cache_len: int):
        """Per-request carry threaded between prefill chunks: the DIRECT
        (non-pool) decode-state leaves at batch extent 1, at their true
        initial values. Pool leaves get (1,)-shaped placeholders — their
        chunk writes go straight into the shared block pool. Audio computes
        its cross-attention KV here, once per request instead of per chunk.
        """
        cfg = self.cfg
        dummy = jnp.zeros((1,), cfg.cdtype)
        if cfg.family in ("dense", "vlm", "moe"):
            return {"k": dummy, "v": dummy}
        if cfg.family == "audio":
            enc_out = self._encode_audio(params, batch["frames"])

            def body(c, layer):
                return c, attn.encode_kv(layer["cross_attn"], enc_out, cfg)

            _, (xks, xvs) = scan_layers(body, 0, params["blocks"], cfg)
            return {"k": dummy, "v": dummy, "xk": xks, "xv": xvs}
        if cfg.family == "ssm":
            G, gm = self.n_groups, self.group_m
            s_shapes = ssm_lib.slstm_state_shapes(cfg, 1)
            slstm = [jnp.zeros((G,) + s, jnp.float32) for s in s_shapes]
            slstm[2] = jnp.full((G,) + s_shapes[2], -1e30, jnp.float32)
            return {"mlstm": jnp.zeros(
                        (G, gm) + ssm_lib.mlstm_state_shape(cfg, 1),
                        jnp.float32),
                    "slstm": tuple(slstm)}
        if cfg.family == "hybrid":
            G, gm = self.n_groups, self.group_m
            ssm_s, conv_s = ssm_lib.mamba2_state_shapes(cfg, 1)
            return {"ssm": jnp.zeros((G, gm) + ssm_s, jnp.float32),
                    "conv": jnp.zeros((G, gm) + conv_s, cfg.cdtype),
                    "k": dummy, "v": dummy}
        raise ValueError(cfg.family)

    def prefill_chunk(self, params, cache, carry, x: Array, start: Array,
                      length: Array, block_table: Array, *,
                      use_kernel: bool = False):
        """Consume one chunk of a prompt. x: (1, C, D) embedded inputs
        (``embed_prompt`` output sliced at ``start``, right-padded to C);
        start: () int32 absolute position of chunk row 0; length: () int32
        valid rows; block_table: (NB,) int32 — this request's block map
        (unused by families without pageable leaves).

        Attention KV leaves are written straight into the paged pool
        (``attn.chunk_attention``) and attend over the previously-inserted
        blocks; recurrent / conv / cross-attention state flows through
        ``carry``. Returns (last_logits (1, V) — the greedy next-token
        distribution at the chunk's final valid position — new_carry,
        new_cache). Padded rows are exact no-ops on carry and pool.
        """
        cfg = self.cfg
        C = x.shape[1]

        if cfg.family in ("dense", "vlm", "moe"):
            def body(xh, layer_and_pool):
                layer, pool = layer_and_pool
                a, pool = attn.chunk_attention(
                    layer["attn"], rms_norm(xh, layer["ln1"], cfg.norm_eps),
                    cfg, pool, start, length, block_table,
                    use_kernel=use_kernel)
                h = xh + a
                y = rms_norm(h, layer["ln2"], cfg.norm_eps)
                out = h + (moe_lib.moe_ffn(layer["moe"], y, cfg)
                           if cfg.family == "moe" else swiglu(layer["ffn"], y))
                return out, pool
            x, (ks, vs) = scan_layers(
                body, x, (params["blocks"], (cache["k"], cache["v"])), cfg)
            new_cache = {"k": ks, "v": vs}
            new_carry = carry

        elif cfg.family == "audio":
            def body(xh, layer_and_c):
                layer, (k, v, xk, xv) = layer_and_c
                a, kv = attn.chunk_attention(
                    layer["self_attn"],
                    rms_norm(xh, layer["ln1"], cfg.norm_eps),
                    cfg, (k, v), start, length, block_table,
                    use_kernel=use_kernel)
                h = xh + a
                h = h + attn.cross_attention(
                    layer["cross_attn"],
                    rms_norm(h, layer["ln2"], cfg.norm_eps), (xk, xv), cfg)
                out = h + swiglu(layer["ffn"],
                                 rms_norm(h, layer["ln3"], cfg.norm_eps))
                return out, kv
            x, (ks, vs) = scan_layers(
                body, x, (params["blocks"],
                          (cache["k"], cache["v"], carry["xk"],
                           carry["xv"])), cfg)
            new_cache = {"k": ks, "v": vs,
                         "xk": cache["xk"], "xv": cache["xv"]}
            new_carry = carry

        elif cfg.family == "ssm":
            valid = jnp.arange(C) < length

            def body(xh, group_and_state):
                group, (m_st, s_st) = group_and_state

                def m_body(h, mc):
                    m, st = mc
                    q, k, v, log_f, z = ssm_lib._mlstm_qkvg(
                        m["core"], rms_norm(h, m["ln"], cfg.norm_eps), cfg)
                    k = k * valid[None, :, None, None].astype(k.dtype)
                    log_f = jnp.where(valid[None, :, None], log_f, 0.0)
                    v_ext = jnp.concatenate(
                        [v, jnp.ones_like(v[..., :1])], -1)
                    y, st = ssm_lib.chunked_linear_attention(
                        q, k, v_ext, log_f, cfg.ssm.chunk, state=st,
                        use_kernel=use_kernel)
                    num, den = y[..., :-1], y[..., -1:]
                    hh = (num / (jnp.abs(den) + 1.0)).reshape(1, C, -1)
                    hh = rms_norm(hh, m["core"]["norm"], cfg.norm_eps) \
                        * jax.nn.silu(z)
                    return h + hh @ m["core"]["w_out"].astype(h.dtype), st
                xh, m_st = scan_layers(
                    m_body, xh,
                    ({"ln": group["m_ln"], "core": group["mlstm"]}, m_st),
                    cfg)
                y, s_st = ssm_lib.slstm_scan(
                    group["slstm"], rms_norm(xh, group["s_ln"], cfg.norm_eps),
                    cfg, state=s_st, length=length)
                return xh + y, (m_st, s_st)
            x, (m_states, s_states) = scan_layers(
                body, x, (params["blocks"],
                          (carry["mlstm"], carry["slstm"])), cfg)
            new_carry = {"mlstm": m_states, "slstm": s_states}
            new_cache = cache

        elif cfg.family == "hybrid":
            shared = params["shared_attn"]

            def body(xh, group_and_c):
                group, (ssm_st, conv_st, k, v) = group_and_c

                def m_body(h, mc):
                    m, st = mc
                    y, st = self._mamba2_chunk(
                        m["core"], rms_norm(h, m["ln"], cfg.norm_eps), st,
                        length, use_kernel)
                    return h + y, st
                xh, (ssm_st, conv_st) = scan_layers(
                    m_body, xh,
                    ({"ln": group["m_ln"], "core": group["mamba"]},
                     (ssm_st, conv_st)), cfg)
                a, kv = attn.chunk_attention(
                    shared["attn"], rms_norm(xh, shared["ln1"], cfg.norm_eps),
                    cfg, (k, v), start, length, block_table,
                    use_kernel=use_kernel)
                h = xh + a
                out = h + swiglu(shared["ffn"],
                                 rms_norm(h, shared["ln2"], cfg.norm_eps))
                return out, (ssm_st, conv_st) + kv
            x, (ssm_s, conv_s, ks, vs) = scan_layers(
                body, x, (params["blocks"],
                          (carry["ssm"], carry["conv"],
                           cache["k"], cache["v"])), cfg)
            new_carry = {"ssm": ssm_s, "conv": conv_s,
                         "k": carry["k"], "v": carry["v"]}
            new_cache = {"ssm": cache["ssm"], "conv": cache["conv"],
                         "k": ks, "v": vs}
        else:
            raise ValueError(cfg.family)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        h_last = jax.lax.dynamic_slice_in_dim(x, length - 1, 1, axis=1)
        logits = unembed(params["embed"], h_last, cfg.tie_embeddings,
                         cfg.vocab)
        return logits[:, 0], new_carry, new_cache

    def _mamba2_chunk(self, p, x, state, length, use_kernel):
        """``_mamba2_prefill`` with an inter-chunk carry: the conv window
        and SSM state flow in from the previous chunk, and padded positions
        (≥ length) are exact no-ops on both (dt → 0 ⇒ zero k and unit
        decay; the conv carry is sliced at the valid end)."""
        cfg = self.cfg
        ssm_state, conv_carry = state
        xs, z, Bm, Cm, dt_raw, (B, S, Di, N, H, P) = \
            ssm_lib._mamba2_inner(p, x, cfg)
        conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
        W = p["conv_w"].shape[0]
        conv_out, _ = ssm_lib._causal_conv(
            conv_in, p["conv_w"].astype(x.dtype), conv_carry)
        if W > 1:
            ext = jnp.concatenate([conv_carry, conv_in], axis=1)
            conv_carry = jax.lax.dynamic_slice_in_dim(ext, length, W - 1,
                                                      axis=1)
        xs, Bm, Cm = jnp.split(conv_out, [Di, Di + N], axis=-1)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                             p["dt_bias"].astype(jnp.float32))
        dt = jnp.where((jnp.arange(S) < length)[None, :, None], dt, 0.0)
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        log_g = dt * A[None, None, :]
        q = jnp.broadcast_to(Cm[:, :, None, :], (B, S, H, N))
        k = jnp.broadcast_to(Bm[:, :, None, :], (B, S, H, N)) * \
            dt[..., None].astype(x.dtype)
        v = xs.reshape(B, S, H, P)
        y, st = ssm_lib.chunked_linear_attention(q, k, v, log_g,
                                                 cfg.ssm.chunk,
                                                 state=ssm_state,
                                                 use_kernel=use_kernel)
        y = y + p["D_skip"].astype(x.dtype)[None, None, :, None] * v
        y = y.reshape(B, S, Di) * jax.nn.silu(z)
        y = rms_norm(y, p["norm"], cfg.norm_eps)
        return y @ p["w_out"].astype(x.dtype), (st, conv_carry)

    def _mamba2_prefill(self, p, x, use_kernel):
        """mamba2_block that also returns (ssm_state, conv_carry)."""
        cfg = self.cfg
        xs, z, Bm, Cm, dt_raw, (B, S, Di, N, H, P) = \
            ssm_lib._mamba2_inner(p, x, cfg)
        conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
        conv_out, conv_carry = ssm_lib._causal_conv(
            conv_in, p["conv_w"].astype(x.dtype))
        W = p["conv_w"].shape[0]
        conv_carry = conv_in[:, -(W - 1):] if W > 1 else conv_carry
        xs, Bm, Cm = jnp.split(conv_out, [Di, Di + N], axis=-1)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                             p["dt_bias"].astype(jnp.float32))
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        log_g = dt * A[None, None, :]
        q = jnp.broadcast_to(Cm[:, :, None, :], (B, S, H, N))
        k = jnp.broadcast_to(Bm[:, :, None, :], (B, S, H, N)) * \
            dt[..., None].astype(x.dtype)
        v = xs.reshape(B, S, H, P)
        y, st = ssm_lib.chunked_linear_attention(q, k, v, log_g, cfg.ssm.chunk,
                                                 use_kernel=use_kernel)
        y = y + p["D_skip"].astype(x.dtype)[None, None, :, None] * v
        y = y.reshape(B, S, Di) * jax.nn.silu(z)
        y = rms_norm(y, p["norm"], cfg.norm_eps)
        return y @ p["w_out"].astype(x.dtype), (st, conv_carry)

    # ------------------------------------------------------------------
    # Decode: ONE new token (serve_step body)
    # ------------------------------------------------------------------

    def decode_step(self, params, cache, tokens: Array, pos: Array, *,
                    use_kernel: bool = False):
        """tokens: (B,) int32; pos: () int32 current position. Returns
        (logits (B, V), new cache)."""
        cfg = self.cfg
        x = embed(params["embed"], tokens[:, None], cfg.cdtype)  # (B,1,D)

        if cfg.family in ("dense", "vlm", "moe"):
            def body(x, layer_and_cache):
                layer, (k, v) = layer_and_cache
                a, kv = attn.decode_attention(
                    layer["attn"], rms_norm(x, layer["ln1"], cfg.norm_eps),
                    cfg, (k, v), pos, use_kernel=use_kernel)
                h = x + a
                y = rms_norm(h, layer["ln2"], cfg.norm_eps)
                out = h + (moe_lib.moe_ffn(layer["moe"], y, cfg)
                           if cfg.family == "moe" else swiglu(layer["ffn"], y))
                return out, kv
            x, (ks, vs) = scan_layers(
                body, x, (params["blocks"], (cache["k"], cache["v"])), cfg)
            new_cache = {"k": ks, "v": vs}

        elif cfg.family == "audio":
            def body(x, layer_and_cache):
                layer, (k, v, xk, xv) = layer_and_cache
                a, kv = attn.decode_attention(
                    layer["self_attn"], rms_norm(x, layer["ln1"], cfg.norm_eps),
                    cfg, (k, v), pos, use_kernel=use_kernel)
                h = x + a
                h = h + attn.cross_attention(
                    layer["cross_attn"], rms_norm(h, layer["ln2"], cfg.norm_eps),
                    (xk, xv), cfg)
                out = h + swiglu(layer["ffn"],
                                 rms_norm(h, layer["ln3"], cfg.norm_eps))
                return out, kv + (xk, xv)
            x, (ks, vs, xks, xvs) = scan_layers(
                body, x, (params["blocks"],
                          (cache["k"], cache["v"], cache["xk"], cache["xv"])), cfg)
            new_cache = {"k": ks, "v": vs, "xk": xks, "xv": xvs}

        elif cfg.family == "ssm":
            def body(x, group_and_cache):
                group, (m_st, s_st) = group_and_cache
                def m_body(h, mc):
                    m, st = mc
                    y, st = ssm_lib.mlstm_step(
                        m["core"], rms_norm(h, m["ln"], cfg.norm_eps), cfg, st)
                    return h + y, st
                x, m_st = scan_layers(
                    m_body, x,
                    (({"ln": group["m_ln"], "core": group["mlstm"]}), m_st), cfg)
                y, s_st = ssm_lib.slstm_scan(
                    group["slstm"], rms_norm(x, group["s_ln"], cfg.norm_eps),
                    cfg, state=s_st)
                return x + y, (m_st, s_st)
            x, (m_states, s_states) = scan_layers(
                body, x, (params["blocks"],
                          (cache["mlstm"], cache["slstm"])), cfg)
            new_cache = {"mlstm": m_states, "slstm": s_states}

        elif cfg.family == "hybrid":
            shared = params["shared_attn"]

            def body(x, group_and_cache):
                group, (ssm_st, conv_st, k, v) = group_and_cache
                def m_body(h, mc):
                    m, st = mc
                    y, st = ssm_lib.mamba2_step(
                        m["core"], rms_norm(h, m["ln"], cfg.norm_eps), cfg, st)
                    return h + y, st
                x, (ssm_st, conv_st) = scan_layers(
                    m_body, x, ({"ln": group["m_ln"], "core": group["mamba"]},
                                (ssm_st, conv_st)), cfg)
                a, kv = attn.decode_attention(
                    shared["attn"], rms_norm(x, shared["ln1"], cfg.norm_eps),
                    cfg, (k, v), pos, use_kernel=use_kernel)
                h = x + a
                out = h + swiglu(shared["ffn"],
                                 rms_norm(h, shared["ln2"], cfg.norm_eps))
                return out, (ssm_st, conv_st) + kv
            x, (ssm_s, conv_s, ks, vs) = scan_layers(
                body, x, (params["blocks"],
                          (cache["ssm"], cache["conv"],
                           cache["k"], cache["v"])), cfg)
            new_cache = {"ssm": ssm_s, "conv": conv_s, "k": ks, "v": vs}
        else:
            raise ValueError(cfg.family)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], x, cfg.tie_embeddings, cfg.vocab)
        return logits[:, 0], new_cache

    def decode_step_paged(self, params, cache, tokens: Array, pos: Array,
                          block_tables: Array, *, use_kernel: bool = False):
        """One-token decode against the paged cache. tokens: (B,) int32;
        pos: (B,) int32 per-slot positions; block_tables: (B, NB) int32
        logical-block → physical-pool-block maps (one table per slot,
        shared by every attention layer). Attention KV leaves gather /
        scatter through the pool; recurrent and cross-attention leaves run
        the direct path unchanged."""
        cfg = self.cfg
        if cfg.family == "ssm":       # no pageable leaves: direct path
            return self.decode_step(params, cache, tokens, pos,
                                    use_kernel=use_kernel)
        x = embed(params["embed"], tokens[:, None], cfg.cdtype)  # (B,1,D)

        if cfg.family in ("dense", "vlm", "moe"):
            def body(x, layer_and_cache):
                layer, (k, v) = layer_and_cache
                a, kv = attn.paged_decode_attention(
                    layer["attn"], rms_norm(x, layer["ln1"], cfg.norm_eps),
                    cfg, (k, v), pos, block_tables, use_kernel=use_kernel)
                h = x + a
                y = rms_norm(h, layer["ln2"], cfg.norm_eps)
                out = h + (moe_lib.moe_ffn(layer["moe"], y, cfg)
                           if cfg.family == "moe" else swiglu(layer["ffn"], y))
                return out, kv
            x, (ks, vs) = scan_layers(
                body, x, (params["blocks"], (cache["k"], cache["v"])), cfg)
            new_cache = {"k": ks, "v": vs}

        elif cfg.family == "audio":
            def body(x, layer_and_cache):
                layer, (k, v, xk, xv) = layer_and_cache
                a, kv = attn.paged_decode_attention(
                    layer["self_attn"], rms_norm(x, layer["ln1"],
                                                 cfg.norm_eps),
                    cfg, (k, v), pos, block_tables, use_kernel=use_kernel)
                h = x + a
                h = h + attn.cross_attention(
                    layer["cross_attn"], rms_norm(h, layer["ln2"],
                                                  cfg.norm_eps),
                    (xk, xv), cfg)
                out = h + swiglu(layer["ffn"],
                                 rms_norm(h, layer["ln3"], cfg.norm_eps))
                return out, kv + (xk, xv)
            x, (ks, vs, xks, xvs) = scan_layers(
                body, x, (params["blocks"],
                          (cache["k"], cache["v"], cache["xk"],
                           cache["xv"])), cfg)
            new_cache = {"k": ks, "v": vs, "xk": xks, "xv": xvs}

        elif cfg.family == "hybrid":
            shared = params["shared_attn"]

            def body(x, group_and_cache):
                group, (ssm_st, conv_st, k, v) = group_and_cache
                def m_body(h, mc):
                    m, st = mc
                    y, st = ssm_lib.mamba2_step(
                        m["core"], rms_norm(h, m["ln"], cfg.norm_eps), cfg,
                        st)
                    return h + y, st
                x, (ssm_st, conv_st) = scan_layers(
                    m_body, x, ({"ln": group["m_ln"], "core": group["mamba"]},
                                (ssm_st, conv_st)), cfg)
                a, kv = attn.paged_decode_attention(
                    shared["attn"], rms_norm(x, shared["ln1"], cfg.norm_eps),
                    cfg, (k, v), pos, block_tables, use_kernel=use_kernel)
                h = x + a
                out = h + swiglu(shared["ffn"],
                                 rms_norm(h, shared["ln2"], cfg.norm_eps))
                return out, (ssm_st, conv_st) + kv
            x, (ssm_s, conv_s, ks, vs) = scan_layers(
                body, x, (params["blocks"],
                          (cache["ssm"], cache["conv"],
                           cache["k"], cache["v"])), cfg)
            new_cache = {"ssm": ssm_s, "conv": conv_s, "k": ks, "v": vs}
        else:
            raise ValueError(cfg.family)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], x, cfg.tie_embeddings, cfg.vocab)
        return logits[:, 0], new_cache

    def verify_step_paged(self, params, cache, tokens: Array, pos: Array,
                          block_tables: Array, *, use_kernel: bool = False):
        """Speculative span verify against the paged cache: score L
        candidate positions per slot in ONE forward. tokens: (B, L) int32
        — column 0 is each slot's committed next token, columns 1..L-1
        its draft tokens; pos: (B,) int32 the position column 0 writes
        at; block_tables: (B, NB). Returns (logits (B, L, V), new cache):
        logits row j is the next-token distribution AFTER feeding tokens
        0..j, i.e. what a vanilla ``decode_step_paged`` at position
        ``pos + j`` would have produced had drafts 0..j-1 been committed.

        Only speculation-capable families run here (see
        ``speculative_capable``) — the span's K/V writes are rolled back
        by overwrite, which recurrent state cannot do."""
        cfg = self.cfg
        if not self.speculative_capable:
            raise ValueError(
                f"family '{cfg.family}' (window={cfg.sliding_window}) "
                "cannot verify speculative spans — check "
                "speculative_capable before dispatching")
        x = embed(params["embed"], tokens, cfg.cdtype)           # (B,L,D)

        if cfg.family in ("dense", "vlm", "moe"):
            def body(x, layer_and_cache):
                layer, (k, v) = layer_and_cache
                a, kv = attn.paged_verify_attention(
                    layer["attn"], rms_norm(x, layer["ln1"], cfg.norm_eps),
                    cfg, (k, v), pos, block_tables, use_kernel=use_kernel)
                h = x + a
                y = rms_norm(h, layer["ln2"], cfg.norm_eps)
                out = h + (moe_lib.moe_ffn(layer["moe"], y, cfg)
                           if cfg.family == "moe" else swiglu(layer["ffn"], y))
                return out, kv
            x, (ks, vs) = scan_layers(
                body, x, (params["blocks"], (cache["k"], cache["v"])), cfg)
            new_cache = {"k": ks, "v": vs}

        elif cfg.family == "audio":
            def body(x, layer_and_cache):
                layer, (k, v, xk, xv) = layer_and_cache
                a, kv = attn.paged_verify_attention(
                    layer["self_attn"], rms_norm(x, layer["ln1"],
                                                 cfg.norm_eps),
                    cfg, (k, v), pos, block_tables, use_kernel=use_kernel)
                h = x + a
                h = h + attn.cross_attention(
                    layer["cross_attn"], rms_norm(h, layer["ln2"],
                                                  cfg.norm_eps),
                    (xk, xv), cfg)
                out = h + swiglu(layer["ffn"],
                                 rms_norm(h, layer["ln3"], cfg.norm_eps))
                return out, kv + (xk, xv)
            x, (ks, vs, xks, xvs) = scan_layers(
                body, x, (params["blocks"],
                          (cache["k"], cache["v"], cache["xk"],
                           cache["xv"])), cfg)
            new_cache = {"k": ks, "v": vs, "xk": xks, "xv": xvs}
        else:
            raise ValueError(cfg.family)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], x, cfg.tie_embeddings, cfg.vocab)
        return logits, new_cache

    def fused_verify_step(self, params, cache, state, drafts: Array, *,
                          cache_len: int, use_kernel: bool = False):
        """One WHOLE speculative step as a single traceable computation:
        the span verify forward over ``[committed token, drafts]``
        followed by the accept/reject epilogue (deterministic token-match
        against the seeded stream, per-offset stop/budget/context checks,
        variable-length position advance) from ``repro.serve.fused``.

        drafts: (B, L-1) int32 draft tokens per slot. Returns
        ``(new_cache, new_state, toks, n_emit, done)`` — the host reads
        back the ``(toks, n_emit, done)`` triple in one ``device_get``.
        """
        # function-level import: repro.serve pulls in the schedulers, which
        # import this module — the epilogue itself is a leaf
        from repro.serve.fused import verify_epilogue
        tokens = jnp.concatenate([state["tok"][:, None], drafts], axis=1)
        scores, cache = self.verify_step_paged(
            params, cache, tokens, state["pos"], state["tables"],
            use_kernel=use_kernel)
        state, toks, n_emit, done = verify_epilogue(
            scores, drafts, state, cache_len=cache_len)
        return cache, state, toks, n_emit, done

    def fused_decode_step(self, params, cache, state, *, cache_len: int,
                          use_kernel: bool = False, paged: bool = False):
        """One WHOLE decode token as a single traceable computation: the
        forward (contiguous or paged — ``state["tables"]`` carries the
        per-slot block tables when paged) followed by the serving epilogue
        (seeded ``sample_tokens``, stop/eos ids, budget and context-bound
        checks, position advance) from ``repro.serve.fused``.

        ``state`` is the scheduler's per-slot device-state dict; returns
        ``(new_cache, new_state, next_tok, done)`` where ``done`` is the
        per-slot ``DONE_REASONS`` bitmap the host reads back instead of
        inspecting tokens per slot.
        """
        # function-level import: repro.serve pulls in the schedulers, which
        # import this module — the epilogue itself is a leaf
        from repro.serve.fused import decode_epilogue
        if paged:
            scores, cache = self.decode_step_paged(
                params, cache, state["tok"], state["pos"], state["tables"],
                use_kernel=use_kernel)
        else:
            scores, cache = self.decode_step(params, cache, state["tok"],
                                             state["pos"],
                                             use_kernel=use_kernel)
        state, nxt, done = decode_epilogue(scores, state,
                                           cache_len=cache_len)
        return cache, state, nxt, done


def build_model(cfg: ModelConfig) -> Model:
    m = Model(cfg)
    return m
