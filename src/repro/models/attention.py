"""Grouped-query attention: train/prefill (full-sequence), decode (one token
against a KV cache), cross-attention (enc-dec), sliding-window masks.

The full-sequence path can route through the Pallas flash-attention kernel
(repro/kernels) — selectable per call so CPU tests use the jnp path and the
TPU dry-run claims the kernel's tiling.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import apply_rope, rms_norm
from .params import ParamSpec

Array = jnp.ndarray

NEG_INF = -1e30


def attention_specs(cfg) -> Dict[str, ParamSpec]:
    D, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    specs = {
        "wq": ParamSpec((D, H, dh), ("embed", "heads", "head_dim"), "scaled"),
        "wk": ParamSpec((D, KV, dh), ("embed", "kv_heads", "head_dim"), "scaled"),
        "wv": ParamSpec((D, KV, dh), ("embed", "kv_heads", "head_dim"), "scaled"),
        "wo": ParamSpec((H, dh, D), ("heads", "head_dim", "embed"), "scaled"),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((dh,), (None,), "ones")
        specs["k_norm"] = ParamSpec((dh,), (None,), "ones")
    return specs


def _qkv(params, x: Array, cfg, positions: Array,
         rope: bool = True) -> Tuple[Array, Array, Array]:
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_sdpa(q: Array, k: Array, v: Array, mask: Optional[Array],
             softmax_dtype=jnp.float32) -> Array:
    """Grouped-query attention WITHOUT materializing repeated KV heads
    (§Perf H1b: a `jnp.repeat` expansion forced XLA to build — and, with a
    sharded cache, all-gather — an H-headed K/V temp; the grouped einsum
    keeps K/V at their native KV heads).

    q: (B, Sq, H, dh); k, v: (B, Sk, KV, dh) with H % KV == 0;
    mask: broadcastable to (B, Sq, Sk) or None.
    """
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    g = H // KV
    qg = q.reshape(B, Sq, KV, g, dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(softmax_dtype)
    logits = logits / jnp.sqrt(jnp.asarray(dh, softmax_dtype))
    if mask is not None:
        logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    weights = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", weights, v)
    return out.reshape(B, Sq, H, dh)


def causal_mask(S: int, window: int = 0) -> Array:
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    m = j <= i
    if window > 0:
        m &= (i - j) < window
    return m[None, :, :]                      # (1, S, S)


def full_attention(params, x: Array, cfg, *, causal: bool = True,
                   use_kernel: bool = False,
                   positions: Optional[Array] = None) -> Array:
    """Train / prefill self-attention over the whole sequence."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(params, x, cfg, positions)
    if use_kernel:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=causal,
                                   window=cfg.sliding_window)
    else:
        mask = causal_mask(S, cfg.sliding_window) if causal else None
        out = gqa_sdpa(q, k, v, mask, jnp.dtype(cfg.attn_softmax_dtype))
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


def prefill_attention(params, x: Array, cfg, cache_len: int,
                      use_kernel: bool = False):
    """Like full_attention but also returns the (K, V) to seed the cache,
    right-padded to ``cache_len``."""
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(params, x, cfg, positions)
    if use_kernel:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=True,
                                   window=cfg.sliding_window)
    else:
        mask = causal_mask(S, cfg.sliding_window)
        out = gqa_sdpa(q, k, v, mask, jnp.dtype(cfg.attn_softmax_dtype))
    proj = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    pad = [(0, 0), (0, cache_len - S), (0, 0), (0, 0)]
    return proj, (jnp.pad(k, pad), jnp.pad(v, pad))


def decode_attention(params, x: Array, cfg, cache: Tuple[Array, Array],
                     pos: Array, *, use_kernel: bool = False,
                     rope: bool = True):
    """One-token decode. x: (B, 1, D); cache K/V: (B, S_cache, KV, dh);
    pos: () or (B,) current position. Returns (out (B,1,D), new cache).

    With ``cfg.sliding_window > 0`` the cache is a ring buffer of size
    S_cache = window (positions wrap); otherwise it is the full context.
    """
    B, _, D = x.shape
    k_cache, v_cache = cache
    S_cache = k_cache.shape[1]
    pos = jnp.asarray(pos)
    pos_b = jnp.broadcast_to(pos, (B,))
    q, k_new, v_new = _qkv(params, x, cfg, pos_b[:, None], rope=rope)
    if pos.ndim == 0:
        # §Perf H1: scalar position (the serve_step case) — in-place
        # dynamic_update_slice touches ONE cache slot instead of the
        # masked-rewrite of the whole cache (which forced SPMD to fully
        # rematerialize/replicate the cache every step).
        slot = pos % S_cache if cfg.sliding_window > 0 else pos
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k_new.astype(k_cache.dtype), (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v_new.astype(v_cache.dtype), (0, slot, 0, 0))
    else:
        slot = pos_b % S_cache if cfg.sliding_window > 0 else pos_b
        oh = jax.nn.one_hot(slot, S_cache, dtype=k_cache.dtype)  # (B, S)
        k_cache = k_cache * (1 - oh)[:, :, None, None] + \
            oh[:, :, None, None] * k_new.astype(k_cache.dtype)
        v_cache = v_cache * (1 - oh)[:, :, None, None] + \
            oh[:, :, None, None] * v_new.astype(v_cache.dtype)
    if use_kernel:
        from repro.kernels import ops as kops
        out = kops.decode_attention(q[:, 0], k_cache, v_cache,
                                    pos_b, window=cfg.sliding_window)
        out = out[:, None]
    else:
        idx = jnp.arange(S_cache)[None, :]
        if cfg.sliding_window > 0:
            # ring buffer: every slot is valid once pos >= S_cache; before
            # wrapping only slots ≤ pos have been written.
            valid = (idx <= pos_b[:, None]) | (pos_b[:, None] >= S_cache)
        else:
            valid = idx <= pos_b[:, None]
        mask = valid[:, None, :]              # (B, 1, S_cache)
        out = gqa_sdpa(q, k_cache, v_cache, mask, jnp.dtype(cfg.attn_softmax_dtype))
    proj = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return proj, (k_cache, v_cache)


def paged_decode_attention(params, x: Array, cfg,
                           pool: Tuple[Array, Array], pos: Array,
                           block_tables: Array, *,
                           use_kernel: bool = False, rope: bool = True):
    """One-token decode against a PAGED KV cache. x: (B, 1, D); pool K/V:
    (P, block, KV, dh) shared block pool; pos: (B,) current positions;
    block_tables: (B, NB) logical-block → physical-block map per slot.
    Returns (out (B, 1, D), new pool).

    Logical capacity is NB·block per slot; with ``cfg.sliding_window > 0``
    the slot's logical span is addressed as a ring of that size (the
    scheduler sizes NB so it equals the contiguous ring length). Unallocated
    table entries point at physical block 0 — the reserved scratch block —
    and are masked out by the position rule, so a slot never reads another
    slot's blocks.
    """
    B = x.shape[0]
    k_pool, v_pool = pool
    bs = k_pool.shape[1]
    NB = block_tables.shape[1]
    S_log = NB * bs
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (B,))
    q, k_new, v_new = _qkv(params, x, cfg, pos_b[:, None], rope=rope)
    # scatter the new token's K/V into each slot's current block — physical
    # blocks are uniquely owned, so the batched scatter never collides
    # (inactive slots all write block 0 offset 0, the scratch block).
    r = pos_b % S_log if cfg.sliding_window > 0 else pos_b
    blk = jnp.take_along_axis(block_tables, (r // bs)[:, None], axis=1)[:, 0]
    off = r % bs
    k_pool = k_pool.at[blk, off].set(k_new[:, 0].astype(k_pool.dtype))
    v_pool = v_pool.at[blk, off].set(v_new[:, 0].astype(v_pool.dtype))
    if use_kernel:
        from repro.kernels import ops as kops
        out = kops.paged_decode_attention(q[:, 0], k_pool, v_pool, pos_b,
                                          block_tables,
                                          window=cfg.sliding_window)
        out = out[:, None]
    else:
        kf = k_pool[block_tables].reshape(B, S_log, *k_pool.shape[2:])
        vf = v_pool[block_tables].reshape(B, S_log, *v_pool.shape[2:])
        idx = jnp.arange(S_log)[None, :]
        if cfg.sliding_window > 0:
            valid = (idx <= pos_b[:, None]) | (pos_b[:, None] >= S_log)
        else:
            valid = idx <= pos_b[:, None]
        out = gqa_sdpa(q, kf, vf, valid[:, None, :],
                       jnp.dtype(cfg.attn_softmax_dtype))
    proj = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return proj, (k_pool, v_pool)


def paged_verify_attention(params, x: Array, cfg,
                           pool: Tuple[Array, Array], pos: Array,
                           block_tables: Array, *,
                           use_kernel: bool = False, rope: bool = True):
    """Speculative multi-token verify against a PAGED KV cache.

    x: (B, L, D) — row ℓ of slot b is the candidate token sitting at
    absolute position ``pos[b] + ℓ`` (row 0 is the slot's committed next
    token, rows 1..L-1 are draft tokens); pool K/V: (P, block, KV, dh);
    pos: (B,) each slot's current write position; block_tables: (B, NB).
    Returns (out (B, L, D), new pool).

    All L candidate K/V are scattered into the pool FIRST, then every row
    attends under the span-causal rule ``key position ≤ pos + ℓ`` — the
    same single masking rule as chunked prefill, so a candidate sees the
    committed prefix plus the earlier candidates of its own span.
    Rejected-tail writes are rolled back by OVERWRITE: they sit at
    positions strictly greater than the post-accept position, the mask
    hides them from every later query, and the next span (or vanilla
    step) re-scatters those offsets before anything attends there.
    Positions past the table horizon scatter into the reserved scratch
    block 0 (inactive slots — pos 0, zeroed tables — land there too).
    Sliding-window (ring) addressing is not supported — the scheduler
    only routes speculation-capable (windowless) models here.
    """
    B, L, D = x.shape
    k_pool, v_pool = pool
    bs = k_pool.shape[1]
    NB = block_tables.shape[1]
    S_log = NB * bs
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (B,))
    positions = pos_b[:, None] + jnp.arange(L)[None, :]          # (B, L)
    q, k_new, v_new = _qkv(params, x, cfg, positions, rope=rope)
    flat_pos = positions.reshape(-1)                             # (B·L,)
    rows = jnp.repeat(jnp.arange(B), L)
    safe = flat_pos < S_log
    blk = jnp.where(
        safe, block_tables[rows, jnp.clip(flat_pos // bs, 0, NB - 1)], 0)
    off = jnp.where(safe, flat_pos % bs, 0)
    k_pool = k_pool.at[blk, off].set(
        k_new.reshape(B * L, *k_new.shape[2:]).astype(k_pool.dtype))
    v_pool = v_pool.at[blk, off].set(
        v_new.reshape(B * L, *v_new.shape[2:]).astype(v_pool.dtype))
    if use_kernel:
        from repro.kernels import ops as kops
        out = kops.paged_verify_attention(q, k_pool, v_pool, pos_b,
                                          block_tables)
    else:
        kf = k_pool[block_tables].reshape(B, S_log, *k_pool.shape[2:])
        vf = v_pool[block_tables].reshape(B, S_log, *v_pool.shape[2:])
        idx = jnp.arange(S_log)[None, None, :]
        valid = idx <= positions[:, :, None]                # (B, L, S_log)
        out = gqa_sdpa(q, kf, vf, valid, jnp.dtype(cfg.attn_softmax_dtype))
    proj = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return proj, (k_pool, v_pool)


def chunk_attention(params, x: Array, cfg, pool: Tuple[Array, Array],
                    start: Array, length: Array, block_table: Array, *,
                    use_kernel: bool = False):
    """Chunked-prefill self-attention THROUGH the paged pool.

    x: (1, C, D) chunk hidden states whose row c sits at absolute position
    ``start + c``; pool K/V: (P, block, KV, dh) shared block pool;
    ``length``: () int32 valid rows in this chunk (a final partial chunk is
    right-padded to C); block_table: (NB,) int32 — THIS request's logical →
    physical block map. Returns (out (1, C, D), new pool).

    The chunk's K/V are scattered into the pool *first*, so within-chunk
    causality flows through the same block-table read as the prefix written
    by earlier chunks — one masking rule (key position ≤ query position)
    covers both. Padded rows scatter into the reserved scratch block 0 and
    their outputs are garbage the caller discards; padded keys sit at
    positions no valid query can attend, so they never leak.
    """
    B, C, D = x.shape
    k_pool, v_pool = pool
    bs = k_pool.shape[1]
    NB = block_table.shape[0]
    S_log = NB * bs
    offs = jnp.arange(C)
    pos_c = start + offs                                     # (C,)
    q, k_new, v_new = _qkv(params, x, cfg, pos_c[None, :])
    valid = offs < length
    blk = jnp.where(valid,
                    block_table[jnp.clip(pos_c // bs, 0, NB - 1)], 0)
    off = jnp.where(valid, pos_c % bs, 0)
    k_pool = k_pool.at[blk, off].set(k_new[0].astype(k_pool.dtype))
    v_pool = v_pool.at[blk, off].set(v_new[0].astype(v_pool.dtype))
    if use_kernel:
        from repro.kernels import ops as kops
        out = kops.chunk_prefill_attention(q[0], k_pool, v_pool, start,
                                           block_table)[None]
    else:
        kf = k_pool[block_table].reshape(S_log, *k_pool.shape[2:])[None]
        vf = v_pool[block_table].reshape(S_log, *v_pool.shape[2:])[None]
        mask = (jnp.arange(S_log)[None, :] <= pos_c[:, None])[None]
        out = gqa_sdpa(q, kf, vf, mask, jnp.dtype(cfg.attn_softmax_dtype))
    proj = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return proj, (k_pool, v_pool)


# ---------------------------------------------------------------------------
# Cross-attention (enc-dec)
# ---------------------------------------------------------------------------

def cross_attention(params, x: Array, enc_kv: Tuple[Array, Array],
                    cfg) -> Array:
    """x: (B, S_dec, D); enc_kv: precomputed (K, V) each (B, S_enc, KV, dh).
    No RoPE on cross-attention queries (content-based addressing)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
    k, v = enc_kv
    out = gqa_sdpa(q, k.astype(dt), v.astype(dt), None, jnp.dtype(cfg.attn_softmax_dtype))
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))


def encode_kv(params, enc_out: Array, cfg) -> Tuple[Array, Array]:
    """Project encoder output once into cross-attention K/V."""
    dt = enc_out.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(dt))
    return k, v
