"""Mixture-of-Experts FFN: shared + routed experts, top-k token routing with
capacity-based dispatch (MaxText/Mixtral-style einsum dispatch so the expert
dim shards cleanly over the ``model`` mesh axis — expert parallelism).

Note the two *different* "expert" notions in this system:
  * these internal MoE experts (architecture detail of qwen3-moe/deepseek);
  * the paper's decentralized experts (full model replicas on the ``pod``
    axis). They compose: a decentralized expert may itself be an MoE.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import swiglu, swiglu_specs
from .params import ParamSpec

Array = jnp.ndarray


def moe_specs(cfg) -> Dict[str, ParamSpec]:
    D, E, Fe = cfg.d_model, cfg.moe.n_experts, cfg.moe.d_ff_expert
    specs = {
        "router": ParamSpec((D, E), ("embed", None), "scaled"),
        "w_gate": ParamSpec((E, D, Fe), ("expert", "embed", "expert_mlp"), "scaled"),
        "w_up": ParamSpec((E, D, Fe), ("expert", "embed", "expert_mlp"), "scaled"),
        "w_down": ParamSpec((E, Fe, D), ("expert", "expert_mlp", "embed"), "scaled"),
    }
    if cfg.moe.n_shared > 0:
        specs["shared"] = swiglu_specs(D, cfg.moe.n_shared * Fe)
    return specs


def _capacity(n_tokens: int, n_experts: int, top_k: int,
              factor: float) -> int:
    cap = int(n_tokens * top_k * factor / n_experts)
    return max(cap, 1)


def route_topk(router_logits: Array, top_k: int) -> Tuple[Array, Array]:
    """Per-token top-k routing. logits: (..., E) → (weights (..., k),
    idx (..., k)). Weights are softmaxed over the selected k
    (DeepSeek/Qwen convention).

    §Perf H5: implemented as an unrolled argmax-and-mask loop instead of
    ``jax.lax.top_k`` — the SPMD partitioner handles per-step argmax
    reductions without resharding, whereas a vmapped ``top_k`` forced an
    all-gather of the router logits across the decentralized-expert (pod)
    dim (1 GiB/layer of spurious cross-pod traffic).
    """
    E = router_logits.shape[-1]
    work = router_logits.astype(jnp.float32)
    gates, idxs = [], []
    for _ in range(top_k):
        idx = jnp.argmax(work, axis=-1)
        oh = jax.nn.one_hot(idx, E, dtype=work.dtype)
        gates.append((work * oh).sum(-1))
        work = work - oh * 1e30          # exclude the chosen expert
        idxs.append(idx)
    gates = jnp.stack(gates, axis=-1)
    idx = jnp.stack(idxs, axis=-1)
    weights = jax.nn.softmax(gates, axis=-1)
    return weights.astype(router_logits.dtype), idx.astype(jnp.int32)


def moe_ffn(params: Dict[str, Array], x: Array, cfg) -> Array:
    """x: (B, S, D) → (B, S, D).

    GShard-style grouped dispatch: each batch row is a routing group with its
    own capacity ``C = S·K·cf/E``, so the dispatch/combine tensors are
    (B, S, E, C) — batch-sharded over (pod, data) while the expert dim shards
    over ``model`` (expert parallelism). The group→expert reshard is the
    all-to-all the roofline's collective term tracks. The top-k axis is
    unrolled (K ≤ 8) to avoid materializing a (B, S, K, E, C) tensor.
    """
    B, S, D = x.shape
    E, K = cfg.moe.n_experts, cfg.moe.top_k
    C = _capacity(S, E, K, cfg.moe.capacity_factor)
    dt = x.dtype

    logits = jnp.einsum("bsd,de->bse", x, params["router"].astype(dt))
    weights, idx = route_topk(logits, K)                      # (B,S,K) ×2

    # position of each (token, choice) within its expert's per-group buffer
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)          # (B, S, K, E)
    flat_oh = onehot.reshape(B, S * K, E)
    pos_flat = jnp.cumsum(flat_oh, axis=1) - flat_oh          # (B, S*K, E)
    pos = (pos_flat * flat_oh).sum(-1).reshape(B, S, K)       # (B, S, K)
    keep = pos < C                                            # capacity drop

    dispatch = jnp.zeros((B, S, E, C), dtype=dt)
    combine = jnp.zeros((B, S, E, C), dtype=dt)
    for k in range(K):                                        # unrolled, K ≤ 8
        oh_e = jax.nn.one_hot(idx[..., k], E, dtype=dt)       # (B, S, E)
        slot = jnp.where(keep[..., k], pos[..., k], C)
        oh_c = jax.nn.one_hot(slot, C + 1, dtype=dt)[..., :C]  # (B, S, C)
        d_k = oh_e[..., :, None] * oh_c[..., None, :]         # (B, S, E, C)
        dispatch = dispatch + d_k
        combine = combine + d_k * weights[..., k, None, None]

    expert_in = jnp.einsum("bsec,bsd->ebcd", dispatch, x)     # (E, B, C, D)
    gate = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", expert_in,
                                  params["w_gate"].astype(dt)))
    up = jnp.einsum("ebcd,edf->ebcf", expert_in, params["w_up"].astype(dt))
    expert_out = jnp.einsum("ebcf,efd->ebcd", gate * up,
                            params["w_down"].astype(dt))      # (E, B, C, D)
    out = jnp.einsum("bsec,ebcd->bsd", combine, expert_out)   # (B, S, D)

    if cfg.moe.n_shared > 0:
        out = out + swiglu(params["shared"], x.reshape(B * S, D)
                           ).reshape(B, S, D)
    return out


def load_balance_stats(router_logits: Array, top_k: int) -> Dict[str, Array]:
    """Aux monitoring: expert load entropy + fraction dropped (roofline for
    the all-to-all term depends on balance)."""
    E = router_logits.shape[-1]
    _, idx = route_topk(router_logits, top_k)
    counts = jnp.bincount(idx.reshape(-1), length=E)
    load = counts / jnp.maximum(counts.sum(), 1)
    entropy = -(load * jnp.log(jnp.maximum(load, 1e-9))).sum() / jnp.log(E)
    return {"load_entropy": entropy, "max_load": load.max()}
