"""Model zoo: dense GQA, MoE, encoder-decoder audio, VLM, xLSTM, Mamba2
hybrid — assembled by family in ``model.build_model``."""
from .model import CacheSpec, Model, build_model

__all__ = ["CacheSpec", "Model", "build_model"]
