"""Shared neural building blocks (pure JAX, no flax): norms, RoPE, SwiGLU,
embeddings. Parameter shapes/shardings come from ParamSpec descriptors."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .params import ParamSpec

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Feed-forward (SwiGLU)
# ---------------------------------------------------------------------------

def swiglu_specs(d_model: int, d_ff: int) -> Dict[str, ParamSpec]:
    return {
        "w_gate": ParamSpec((d_model, d_ff), ("embed", "mlp"), "scaled"),
        "w_up": ParamSpec((d_model, d_ff), ("embed", "mlp"), "scaled"),
        "w_down": ParamSpec((d_ff, d_model), ("mlp", "embed"), "scaled"),
    }


def swiglu(params: Dict[str, Array], x: Array) -> Array:
    dt = x.dtype
    gate = jax.nn.silu(x @ params["w_gate"].astype(dt))
    up = x @ params["w_up"].astype(dt)
    return (gate * up) @ params["w_down"].astype(dt)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embedding_specs(vocab: int, d_model: int, tie: bool) -> Dict[str, ParamSpec]:
    specs = {"embedding": ParamSpec((vocab, d_model), ("vocab", "embed"))}
    if not tie:
        specs["unembed"] = ParamSpec((d_model, vocab), ("embed", "vocab"),
                                     "scaled")
    return specs


def embed(params: Dict[str, Array], tokens: Array, dtype) -> Array:
    return jnp.take(params["embedding"], tokens, axis=0).astype(dtype)


def unembed(params: Dict[str, Array], x: Array, tie: bool,
            true_vocab: int = 0) -> Array:
    if tie:
        w = params["embedding"].T
    else:
        w = params["unembed"]
    # logits in float32 for a stable softmax/loss
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    V = logits.shape[-1]
    if true_vocab and true_vocab < V:      # mask padded vocab rows
        pad_mask = jnp.arange(V) >= true_vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits


def cross_entropy_loss(logits: Array, labels: Array,
                       mask: Optional[Array] = None) -> Array:
    """Mean next-token NLL. logits: (B, S, V) f32; labels: (B, S) int."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return nll.mean()
    mask = mask.astype(nll.dtype)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
