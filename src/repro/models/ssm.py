"""Recurrent / state-space blocks: chunkwise linear attention (the shared
TPU-native machinery), mLSTM + sLSTM (xLSTM), and Mamba2 (SSD).

TPU adaptation (see DESIGN.md): instead of porting CUDA selective-scan, all
parallel-in-time recurrences use the *chunkwise* formulation — intra-chunk
work is dense MXU matmuls, the inter-chunk carry is a short ``lax.scan`` over
(seq/chunk) states. The intra-chunk part has a Pallas kernel
(repro/kernels/chunk_scan.py); this module is the reference/jnp path, and the
decode path is the O(1)-per-token state update.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import rms_norm
from .params import ParamSpec

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# Chunkwise linear attention:  y_t = q_t · Σ_{s≤t} (Π_{r=s+1..t} g_r) k_s v_sᵀ
# ---------------------------------------------------------------------------

def chunked_linear_attention(q: Array, k: Array, v: Array, log_g: Array,
                             chunk: int,
                             state: Optional[Array] = None,
                             use_kernel: bool = False
                             ) -> Tuple[Array, Array]:
    """q,k: (B,S,H,dk); v: (B,S,H,dv); log_g: (B,S,H) per-step log decay ≤ 0.

    Returns (y (B,S,H,dv), final_state (B,H,dk,dv)).
    """
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    pad = (-S) % chunk
    if pad:
        zpad = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        q, k, v, log_g = zpad(q), zpad(k), zpad(v), zpad(log_g)
    Sp = S + pad
    NC = Sp // chunk
    cshape = lambda a: a.reshape(B, NC, chunk, *a.shape[2:])
    qc, kc, vc, gc = cshape(q), cshape(k), cshape(v), cshape(log_g)

    cum = jnp.cumsum(gc.astype(jnp.float32), axis=2)          # (B,NC,L,H)
    total = cum[:, :, -1]                                     # (B,NC,H)

    if use_kernel:
        from repro.kernels import ops as kops
        intra, chunk_kv = kops.chunk_scan(qc, kc, vc, cum)
    else:
        # intra-chunk: D[t,s] = exp(cum_t − cum_s) for s ≤ t. Mask BEFORE the
        # exp — masking after leaks inf into the where-gradient (NaN).
        decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,NC,L,L,H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        D = jnp.exp(jnp.where(tri[None, None, :, :, None], decay, -jnp.inf))
        scores = jnp.einsum("bclhd,bcmhd->bclmh", qc, kc).astype(jnp.float32)
        intra = jnp.einsum("bclmh,bcmhv->bclhv", scores * D,
                           vc.astype(jnp.float32))
        # per-chunk kv outer product with decay-to-chunk-end on k
        k_dec = kc.astype(jnp.float32) * jnp.exp(total[:, :, None, :]
                                                 - cum)[..., None]
        chunk_kv = jnp.einsum("bclhd,bclhv->bchdv", k_dec,
                              vc.astype(jnp.float32))          # (B,NC,H,dk,dv)

    if state is None:
        state = jnp.zeros((B, H, dk, dv), jnp.float32)

    def step(s, inputs):
        q_i, cum_i, total_i, kv_i = inputs
        # contribution of the carried state to every position in the chunk
        y_i = jnp.einsum("blhd,bhdv->blhv",
                         q_i.astype(jnp.float32) * jnp.exp(cum_i)[..., None],
                         s)
        s_next = jnp.exp(total_i)[:, :, None, None] * s + kv_i
        return s_next, y_i

    xs = (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(cum, 1, 0),
          jnp.moveaxis(total, 1, 0), jnp.moveaxis(chunk_kv, 1, 0))
    state, inter = jax.lax.scan(step, state, xs)
    inter = jnp.moveaxis(inter, 0, 1)                          # (B,NC,L,H,dv)

    y = (intra + inter).reshape(B, Sp, H, dv)[:, :S]
    return y.astype(v.dtype), state


def linear_attention_step(state: Array, q: Array, k: Array, v: Array,
                          g: Array) -> Tuple[Array, Array]:
    """O(1) decode update. state: (B,H,dk,dv); q,k: (B,H,dk); v: (B,H,dv);
    g: (B,H) decay. Returns (y (B,H,dv), new_state)."""
    state = g[..., None, None] * state + k[..., None] * v[..., None, :]
    y = jnp.einsum("bhd,bhdv->bhv", q.astype(jnp.float32), state)
    return y.astype(v.dtype), state


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM) — matrix memory, parallelizable
# ---------------------------------------------------------------------------

def _d_inner(cfg) -> int:
    return cfg.ssm.expand * cfg.d_model


def mlstm_specs(cfg) -> Dict[str, ParamSpec]:
    D, Di, H = cfg.d_model, _d_inner(cfg), cfg.n_heads
    return {
        "w_in": ParamSpec((D, 2 * Di), ("embed", "inner"), "scaled"),
        "w_qkv": ParamSpec((Di, 3 * Di), ("inner", "inner_qkv"), "scaled"),
        "w_gates": ParamSpec((Di, 2 * H), ("inner", None), "scaled"),
        "b_gates": ParamSpec((2 * H,), (None,), "zeros"),
        "w_out": ParamSpec((Di, D), ("inner", "embed"), "scaled"),
        "norm": ParamSpec((Di,), (None,), "ones"),
    }


def _mlstm_qkvg(params, x: Array, cfg):
    dt = x.dtype
    B, S, _ = x.shape
    Di, H = _d_inner(cfg), cfg.n_heads
    dh = Di // H
    h_in = x @ params["w_in"].astype(dt)                       # (B,S,2Di)
    xm, z = jnp.split(h_in, 2, axis=-1)
    qkv = xm @ params["w_qkv"].astype(dt)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    shape = (B, S, H, dh)
    q = q.reshape(shape) / jnp.sqrt(jnp.float32(dh)).astype(dt)
    k, v = k.reshape(shape), v.reshape(shape)
    gates = xm @ params["w_gates"].astype(dt) + params["b_gates"].astype(dt)
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)                # (B,S,H) ×2
    log_f = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    i_gate = jax.nn.sigmoid(i_pre.astype(jnp.float32)).astype(dt)
    return q, k * i_gate[..., None], v, log_f, z


def mlstm_block(params, x: Array, cfg, *, use_kernel: bool = False) -> Array:
    """Full-sequence mLSTM (pre-norm residual handled by the caller)."""
    B, S, _ = x.shape
    Di = _d_inner(cfg)
    q, k, v, log_f, z = _mlstm_qkvg(params, x, cfg)
    # normalizer: extra all-ones value channel (matrix memory n_t)
    v_ext = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    y, _ = chunked_linear_attention(q, k, v_ext, log_f, cfg.ssm.chunk,
                                    use_kernel=use_kernel)
    num, den = y[..., :-1], y[..., -1:]
    h = num / (jnp.abs(den) + 1.0)
    h = h.reshape(B, S, Di)
    h = rms_norm(h, params["norm"], cfg.norm_eps) * jax.nn.silu(z)
    return h @ params["w_out"].astype(x.dtype)


def mlstm_step(params, x: Array, cfg, state: Array):
    """x: (B, 1, D); state: (B, H, dh, dh+1) matrix memory (+normalizer)."""
    B = x.shape[0]
    Di, H = _d_inner(cfg), cfg.n_heads
    q, k, v, log_f, z = _mlstm_qkvg(params, x, cfg)
    v_ext = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    y, state = linear_attention_step(state, q[:, 0], k[:, 0], v_ext[:, 0],
                                     jnp.exp(log_f[:, 0]))
    num, den = y[..., :-1], y[..., -1:]
    h = (num / (jnp.abs(den) + 1.0)).reshape(B, 1, Di)
    h = rms_norm(h, params["norm"], cfg.norm_eps) * jax.nn.silu(z)
    return h @ params["w_out"].astype(x.dtype), state


def mlstm_state_shape(cfg, batch: int) -> Tuple[int, ...]:
    Di, H = _d_inner(cfg), cfg.n_heads
    dh = Di // H
    return (batch, H, dh, dh + 1)


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM) — scalar memory, sequential (exp-gating, stabilized)
# ---------------------------------------------------------------------------

def slstm_specs(cfg) -> Dict[str, ParamSpec]:
    D, Di = cfg.d_model, _d_inner(cfg)
    return {
        "w_gates": ParamSpec((D, 4 * Di), ("embed", "inner"), "scaled"),
        "r_gates": ParamSpec((4 * Di,), (None,), "zeros"),   # diagonal recurrence
        "b_gates": ParamSpec((4 * Di,), (None,), "zeros"),
        "w_out": ParamSpec((Di, D), ("inner", "embed"), "scaled"),
        "norm": ParamSpec((Di,), (None,), "ones"),
    }


def slstm_scan(params, x: Array, cfg, state=None, length=None):
    """Sequential sLSTM with stabilized exponential gating.

    state: (c, n, m, h) each (B, Di). Returns (y (B,S,D), state).
    Recurrence is diagonal (elementwise h_{t-1} feedback) — a documented
    simplification of the paper's block-diagonal recurrent matrix that keeps
    the sequential structure (what matters for sharding/roofline).

    ``length`` (() int32, optional) freezes the state past position
    ``length`` — chunked prefill right-pads its final chunk, and the padded
    steps must be exact no-ops on the carried state.
    """
    dt = x.dtype
    B, S, D = x.shape
    Di = _d_inner(cfg)
    pre = (x @ params["w_gates"].astype(dt) +
           params["b_gates"].astype(dt)).astype(jnp.float32)  # (B,S,4Di)
    r = params["r_gates"].astype(jnp.float32)
    if state is None:
        z0 = jnp.zeros((B, Di), jnp.float32)
        state = (z0, z0, jnp.full((B, Di), -1e30, jnp.float32), z0)

    def step(carry, inputs):
        pre_t, t = inputs
        c, n, m, h = carry
        g = pre_t + r[None, :] * jnp.tile(h, (1, 4))
        i_pre, f_pre, z_pre, o_pre = jnp.split(g, 4, axis=-1)
        log_f = jax.nn.log_sigmoid(f_pre)
        m_new = jnp.maximum(log_f + m, i_pre)
        i_g = jnp.exp(i_pre - m_new)
        f_g = jnp.exp(log_f + m - m_new)
        c_new = f_g * c + i_g * jnp.tanh(z_pre)
        n_new = f_g * n + i_g
        h_new = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1.0)
        if length is not None:
            keep = t < length
            c_new, n_new, m_new, h_new = (
                jnp.where(keep, new, old)
                for new, old in ((c_new, c), (n_new, n), (m_new, m),
                                 (h_new, h)))
        return (c_new, n_new, m_new, h_new), h_new

    state, hs = jax.lax.scan(step, state,
                             (jnp.moveaxis(pre, 1, 0), jnp.arange(S)))
    hs = jnp.moveaxis(hs, 0, 1).astype(dt)                    # (B,S,Di)
    hs = rms_norm(hs, params["norm"], cfg.norm_eps)
    return hs @ params["w_out"].astype(dt), state


def slstm_state_shapes(cfg, batch: int):
    Di = _d_inner(cfg)
    return tuple((batch, Di) for _ in range(4))


# ---------------------------------------------------------------------------
# Mamba2 (SSD) block
# ---------------------------------------------------------------------------

def mamba2_specs(cfg) -> Dict[str, ParamSpec]:
    D, Di, N, H = cfg.d_model, _d_inner(cfg), cfg.ssm.state, cfg.n_heads
    conv_ch = Di + 2 * N
    return {
        "w_in": ParamSpec((D, 2 * Di + 2 * N + H), ("embed", "inner"), "scaled"),
        "conv_w": ParamSpec((cfg.ssm.conv, conv_ch), (None, "inner"), "scaled"),
        "A_log": ParamSpec((H,), (None,), "zeros"),
        "D_skip": ParamSpec((H,), (None,), "ones"),
        "dt_bias": ParamSpec((H,), (None,), "zeros"),
        "norm": ParamSpec((Di,), (None,), "ones"),
        "w_out": ParamSpec((Di, D), ("inner", "embed"), "scaled"),
    }


def _causal_conv(x: Array, w: Array, carry: Optional[Array] = None):
    """Depthwise causal conv1d. x: (B,S,C); w: (W,C). Returns (y, new_carry)
    where carry is the last W−1 inputs (decode state)."""
    W = w.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(W))
    return jax.nn.silu(y), xp[:, -(W - 1):] if W > 1 else carry


def _mamba2_inner(params, x: Array, cfg):
    dt_ = x.dtype
    B, S, D = x.shape
    Di, N, H = _d_inner(cfg), cfg.ssm.state, cfg.n_heads
    P = Di // H
    proj = x @ params["w_in"].astype(dt_)
    xs, z, Bm, Cm, dt_raw = jnp.split(
        proj, [Di, 2 * Di, 2 * Di + N, 2 * Di + 2 * N], axis=-1)
    return xs, z, Bm, Cm, dt_raw, (B, S, Di, N, H, P)


def mamba2_block(params, x: Array, cfg, *, use_kernel: bool = False) -> Array:
    xs, z, Bm, Cm, dt_raw, (B, S, Di, N, H, P) = _mamba2_inner(params, x, cfg)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out, _ = _causal_conv(conv_in, params["conv_w"].astype(x.dtype))
    xs, Bm, Cm = jnp.split(conv_out, [Di, Di + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))  # (B,S,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))             # (H,)
    log_g = dt * A[None, None, :]                                 # (B,S,H)
    q = jnp.broadcast_to(Cm[:, :, None, :], (B, S, H, N))
    k = jnp.broadcast_to(Bm[:, :, None, :], (B, S, H, N)) * \
        dt[..., None].astype(x.dtype)
    v = xs.reshape(B, S, H, P)
    y, _ = chunked_linear_attention(q, k, v, log_g, cfg.ssm.chunk,
                                    use_kernel=use_kernel)
    y = y + params["D_skip"].astype(x.dtype)[None, None, :, None] * v
    y = y.reshape(B, S, Di) * jax.nn.silu(z)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    return y @ params["w_out"].astype(x.dtype)


def mamba2_step(params, x: Array, cfg, state):
    """state: (ssm_state (B,H,N,P), conv_carry (B,W−1,C))."""
    ssm_state, conv_carry = state
    xs, z, Bm, Cm, dt_raw, (B, S, Di, N, H, P) = _mamba2_inner(params, x, cfg)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out, conv_carry = _causal_conv(conv_in,
                                        params["conv_w"].astype(x.dtype),
                                        conv_carry)
    xs, Bm, Cm = jnp.split(conv_out, [Di, Di + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))[:, 0]  # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    g = jnp.exp(dt * A[None, :])                                  # (B,H)
    q = jnp.broadcast_to(Cm[:, 0, None, :], (B, H, N))
    k = jnp.broadcast_to(Bm[:, 0, None, :], (B, H, N)) * \
        dt[..., None].astype(x.dtype)
    v = xs[:, 0].reshape(B, H, P)
    y, ssm_state = linear_attention_step(ssm_state, q, k, v, g)
    y = y + params["D_skip"].astype(x.dtype)[None, :, None] * v
    y = y.reshape(B, 1, Di) * jax.nn.silu(z)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    return y @ params["w_out"].astype(x.dtype), (ssm_state, conv_carry)


def mamba2_state_shapes(cfg, batch: int):
    Di, N, H = _d_inner(cfg), cfg.ssm.state, cfg.n_heads
    P = Di // H
    return ((batch, H, N, P), (batch, cfg.ssm.conv - 1, Di + 2 * N))
