"""Parameter descriptor system: one source of truth for shapes, initializers
and *logical sharding axes* (MaxText-style logical-axis rules).

A model definition builds a pytree of ``ParamSpec``; from it we derive
(1) initialized parameters (``init_params``), (2) ``PartitionSpec`` trees for
pjit (``tree_pspecs``), and (3) ``ShapeDtypeStruct`` trees for the dry-run
(``tree_shapes``) — so the 405B-scale configs never allocate on this host.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jnp.ndarray


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]        # logical axis name per dim
    init: str = "normal"                      # normal | zeros | ones | scaled
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_leaf(key, spec: ParamSpec, dtype) -> Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    scale = spec.scale
    if spec.init == "scaled":                 # fan-in scaled
        fan_in = spec.shape[0] if len(spec.shape) else 1
        scale = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape, dtype) * scale).astype(dtype)


def init_params(key, tree, dtype=jnp.float32):
    """Materialize a ParamSpec tree into arrays."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = [_init_leaf(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, out)


def resolve_axis(logical: Optional[str], dim: int, rules: Dict[str, tuple],
                 mesh: Optional[Mesh]):
    """Map a logical axis to mesh axes, dropping the rule (→ replicate) when
    the dimension is not divisible by the mesh-axis extent."""
    if logical is None or logical not in rules:
        return None
    axes = rules[logical]
    if axes is None:
        return None
    if mesh is not None:
        extent = int(np.prod([mesh.shape[a] for a in (
            axes if isinstance(axes, tuple) else (axes,))]))
        if extent == 0 or dim % extent != 0:
            return None
    return axes


def spec_pspec(spec: ParamSpec, rules: Dict[str, tuple],
               mesh: Optional[Mesh]) -> P:
    resolved = [resolve_axis(l, d, rules, mesh)
                for l, d in zip(spec.logical, spec.shape)]
    # PartitionSpec forbids the same mesh axis appearing twice; keep first use
    used: set = set()
    final = []
    for r in resolved:
        axes = r if isinstance(r, tuple) else ((r,) if r else ())
        if any(a in used for a in axes):
            final.append(None)
        else:
            used.update(axes)
            final.append(r)
    return P(*final)


def tree_pspecs(tree, rules: Dict[str, tuple], mesh: Optional[Mesh] = None):
    """ParamSpec tree → PartitionSpec tree under the given logical rules."""
    return jax.tree.map(lambda s: spec_pspec(s, rules, mesh), tree,
                        is_leaf=is_spec)


def tree_shapes(tree, dtype=jnp.float32, extra_leading: Tuple[int, ...] = ()):
    """ParamSpec tree → ShapeDtypeStruct tree (no allocation; dry-run)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(extra_leading + s.shape, dtype),
        tree, is_leaf=is_spec)


def tree_shardings(tree, rules, mesh: Mesh,
                   extra_leading_axes: Tuple[Optional[str], ...] = ()):
    """ParamSpec tree → NamedSharding tree (dry-run in_shardings).

    ``extra_leading_axes``: logical names for prepended dims (e.g. the
    decentralized-expert dim stacked over ``pod``).
    """
    def one(s: ParamSpec):
        body = spec_pspec(s, rules, mesh)            # divisibility-checked
        used = {a for part in body if part
                for a in (part if isinstance(part, tuple) else (part,))}
        lead = []
        for l in extra_leading_axes:
            r = rules.get(l) if l else None
            axes = r if isinstance(r, tuple) else ((r,) if r else ())
            if any(a in used for a in axes):
                lead.append(None)
            else:
                used.update(axes)
                lead.append(r)
        return NamedSharding(mesh, P(*lead, *body))
    return jax.tree.map(one, tree, is_leaf=is_spec)


def count_params(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_spec)
    return int(sum(np.prod(l.shape) if is_spec(l) else l.size for l in leaves))
