"""Per-request span tracing with Chrome/Perfetto ``trace_event`` export.

Spans are stamped **host-side at scheduler boundaries only** — a stamp is
one ``time.perf_counter()`` call around code the scheduler already runs
(admission, dispatch, ``jax.device_get`` readback, retirement). Nothing
here runs inside jit, touches a traced value, or forces a device sync, so
the single-dispatch contract and the ``repro.analysis`` host-sync lint
both stay intact. This module imports no jax.

Recorder
--------
:class:`TraceRecorder` keeps events in a bounded ring (``deque`` with
``maxlen``): a long serve run retains the most recent ``capacity`` events
and counts the rest in ``dropped``. Track-naming metadata ("M" events)
lives outside the ring so process/thread names survive wrap. The
:class:`NullRecorder` is the off-switch — every emit method is a no-op
``pass`` and ``enabled`` is False so call sites can skip stamp work
entirely; it is what every engine gets unless ``EngineConfig(trace=True)``.

Event vocabulary (Chrome trace_event, the subset Perfetto renders)
------------------------------------------------------------------
* ``"X"`` complete spans — ``ts``/``dur`` in integer microseconds. Used
  for everything slot-serial: admission, prefix_match, prefill_chunk[i],
  prefill/decode phases, and the per-step dispatch/device_get pair.
  Same-track "X" spans must nest (contain or be disjoint) — the schema
  test enforces this.
* ``"b"``/``"e"`` async spans keyed by ``id`` — used for ``queued``,
  which can overlap arbitrarily many slot-resident spans (requests queue
  while other requests decode on the very slot they will land on).
* ``"C"`` counters — pool free blocks, active/waiting; Perfetto renders
  these as timeline graphs.
* ``"i"`` instants — retirement (with ``finish_reason``), aborts.
* ``"M"`` metadata — ``process_name`` per pod, ``thread_name`` per track.

Track scheme: ``pid`` = pod index. ``tid 0`` = the pod's engine-step
track, ``tid 1`` = admission-retired requests (never held a slot),
``tid 1000+slot`` = one track per cache slot.

Export: ``to_chrome()`` returns ``{"traceEvents": [...]}`` — the JSON
object format ``ui.perfetto.dev`` and ``chrome://tracing`` both load.
"""
from __future__ import annotations

import json
import time
from collections import deque
from typing import Dict, Iterable, List, Optional

__all__ = ["NullRecorder", "TraceRecorder", "merge_chrome", "us"]

# Track ids within one pod (pid). Slot tracks start high so slot count
# never collides with the fixed tracks.
STEP_TID = 0
ADMIT_TID = 1
SLOT_TID0 = 1000


def us(t_seconds: float) -> int:
    """perf_counter seconds → integer trace microseconds."""
    return int(round(t_seconds * 1e6))


class NullRecorder:
    """Do-nothing recorder — the default. ``enabled`` gates stamp work.

    Every emit is ``pass`` so a disabled engine pays one attribute load
    and a no-op call per would-be event; sites that need extra stamps
    (``time.perf_counter()`` pairs taken only for tracing) check
    ``enabled`` first and skip them entirely.
    """

    enabled = False

    def __init__(self, pid: int = 0) -> None:
        self.pid = pid
        self.dropped = 0

    # -- emission (all no-ops) -------------------------------------------
    def complete(self, name: str, t0: float, t1: float, tid: int,
                 cat: str = "span", args: Optional[dict] = None) -> None:
        pass

    def async_begin(self, name: str, t0: float, aid: int,
                    cat: str = "request",
                    args: Optional[dict] = None) -> None:
        pass

    def async_end(self, name: str, t1: float, aid: int,
                  cat: str = "request") -> None:
        pass

    def instant(self, name: str, t: float, tid: int,
                args: Optional[dict] = None) -> None:
        pass

    def counter(self, name: str, t: float, values: dict) -> None:
        pass

    def set_process_name(self, name: str) -> None:
        pass

    def set_thread_name(self, tid: int, name: str) -> None:
        pass

    # -- export -----------------------------------------------------------
    def events(self) -> List[dict]:
        return []

    def to_chrome(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)


class TraceRecorder(NullRecorder):
    """Bounded ring-buffer recorder emitting Chrome trace events.

    ``capacity`` bounds the span/instant/counter ring; when it wraps the
    oldest events drop (counted in ``dropped``) and the trace keeps the
    most recent window — the right default for long serve runs. Metadata
    events are stored aside (a handful per engine) so track names always
    survive.
    """

    enabled = True

    def __init__(self, capacity: int = 65536, pid: int = 0) -> None:
        super().__init__(pid)
        if capacity < 1:
            raise ValueError(f"trace ring capacity must be >= 1, "
                             f"got {capacity}")
        self.capacity = capacity
        self._ring: "deque[dict]" = deque(maxlen=capacity)
        self._meta: Dict[tuple, dict] = {}
        self._t0 = time.perf_counter()  # kept for reference; ts are absolute

    def _push(self, ev: dict) -> None:
        if len(self._ring) == self.capacity:
            self.dropped += 1
        self._ring.append(ev)

    # -- emission ---------------------------------------------------------
    def complete(self, name: str, t0: float, t1: float, tid: int,
                 cat: str = "span", args: Optional[dict] = None) -> None:
        ev = {"name": name, "cat": cat, "ph": "X", "ts": us(t0),
              "dur": max(0, us(t1) - us(t0)), "pid": self.pid, "tid": tid}
        if args:
            ev["args"] = args
        self._push(ev)

    def async_begin(self, name: str, t0: float, aid: int,
                    cat: str = "request",
                    args: Optional[dict] = None) -> None:
        ev = {"name": name, "cat": cat, "ph": "b", "id": aid, "ts": us(t0),
              "pid": self.pid, "tid": ADMIT_TID}
        if args:
            ev["args"] = args
        self._push(ev)

    def async_end(self, name: str, t1: float, aid: int,
                  cat: str = "request") -> None:
        self._push({"name": name, "cat": cat, "ph": "e", "id": aid,
                    "ts": us(t1), "pid": self.pid, "tid": ADMIT_TID})

    def instant(self, name: str, t: float, tid: int,
                args: Optional[dict] = None) -> None:
        ev = {"name": name, "cat": "event", "ph": "i", "ts": us(t),
              "pid": self.pid, "tid": tid, "s": "t"}
        if args:
            ev["args"] = args
        self._push(ev)

    def counter(self, name: str, t: float, values: dict) -> None:
        self._push({"name": name, "cat": "counter", "ph": "C",
                    "ts": us(t), "pid": self.pid, "tid": STEP_TID,
                    "args": dict(values)})

    def set_process_name(self, name: str) -> None:
        self._meta[("p",)] = {
            "name": "process_name", "ph": "M", "pid": self.pid, "tid": 0,
            "args": {"name": name}}

    def set_thread_name(self, tid: int, name: str) -> None:
        self._meta[("t", tid)] = {
            "name": "thread_name", "ph": "M", "pid": self.pid, "tid": tid,
            "args": {"name": name}}

    # -- export -----------------------------------------------------------
    def events(self) -> List[dict]:
        return [self._meta[k] for k in sorted(self._meta,
                                              key=lambda k: (len(k), k))] \
            + list(self._ring)

    def to_chrome(self) -> dict:
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}


def merge_chrome(recorders: Iterable[NullRecorder]) -> dict:
    """One Chrome trace over several recorders (one per pod).

    Recorders share the process ``perf_counter`` time base, so their
    timestamps interleave coherently; distinct ``pid``s keep their tracks
    apart in the Perfetto UI.
    """
    events: List[dict] = []
    for r in recorders:
        events.extend(r.events())
    return {"traceEvents": events, "displayTimeUnit": "ms"}
