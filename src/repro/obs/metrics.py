"""Metrics registry — counters, gauges, and log-bucket histograms.

The serving engine's telemetry substrate (ISSUE 9; the QoS and
disaggregation lines emit into it). Pure host-side Python — this module
imports no jax and never touches device values, so it is trivially clean
under the ``repro.analysis`` host-sync lint and adds no retrace hazard.

Model
-----
A :class:`MetricsRegistry` owns a flat set of *series*, each keyed by
``(name, sorted label items)``. Three instrument types:

* :class:`Counter` — monotonically increasing float (``inc``). Resets
  only via the documented ``reset()`` (see below).
* :class:`Gauge` — last-write-wins float (``set``/``inc``).
* :class:`Histogram` — fixed-bound bucket counts + running sum/count.
  Latency histograms use :func:`log_buckets` (powers of two from 10 µs
  to ~10 s) so one bucket layout serves µs-scale host stamps and
  second-scale queue delays alike.

Each engine owns a private registry (labelled with its pod id); a
process-global *default* registry aggregates engines that opted in via
``EngineConfig(metrics=True)`` for single-endpoint exposition.

Exposition
----------
``to_dict()`` emits a JSON-friendly snapshot; ``to_prometheus()`` emits
Prometheus text format (``# TYPE`` once per metric name, ``_bucket``/
``_sum``/``_count`` expansion for histograms, cumulative ``le`` buckets).

Reset semantics (documented contract)
-------------------------------------
``MetricsRegistry.reset()`` zeroes **every** series in the registry —
counters, gauges, and histogram buckets — without dropping the series
themselves (handles held by the engine stay valid). The engine layer
builds its narrower per-run ``reset_stats()`` on top of this; see
``scheduler._SlotTable.reset_stats``.
"""
from __future__ import annotations

import json
import threading
import weakref
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "log_buckets", "default_registry", "snapshot", "prometheus",
]


def log_buckets(lo: float = 1e-5, hi: float = 16.0,
                factor: float = 2.0) -> Tuple[float, ...]:
    """Fixed log-scale bucket bounds: ``lo * factor**i`` up through ``hi``.

    Defaults span 10 µs … ~16 s in powers of two — wide enough that one
    layout covers dispatch stamps, readback stamps, TTFT and e2e latency
    without per-metric tuning (21 buckets + the implicit +Inf).
    """
    if lo <= 0 or factor <= 1:
        raise ValueError("log_buckets needs lo > 0 and factor > 1")
    out: List[float] = []
    b = lo
    while b <= hi * (1 + 1e-12):
        out.append(b)
        b *= factor
    return tuple(out)


LATENCY_BUCKETS = log_buckets()


def _label_key(labels: Optional[Mapping[str, str]]) -> Tuple[Tuple[str, str], ...]:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class _Series:
    """Common bits: identity (name + labels) and the owning lock."""

    kind = "untyped"

    def __init__(self, name: str, help: str,
                 labels: Tuple[Tuple[str, str], ...],
                 lock: threading.Lock) -> None:
        self.name = name
        self.help = help
        self.labels = labels
        self._lock = lock

    @property
    def label_dict(self) -> Dict[str, str]:
        return dict(self.labels)


class Counter(_Series):
    """Monotonic counter. ``inc(n)`` with n >= 0; read via ``.value``."""

    kind = "counter"

    def __init__(self, name: str, help: str,
                 labels: Tuple[Tuple[str, str], ...],
                 lock: threading.Lock) -> None:
        super().__init__(name, help, labels, lock)
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0.0


class Gauge(_Series):
    """Last-write-wins instantaneous value."""

    kind = "gauge"

    def __init__(self, name: str, help: str,
                 labels: Tuple[Tuple[str, str], ...],
                 lock: threading.Lock) -> None:
        super().__init__(name, help, labels, lock)
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0.0


class Histogram(_Series):
    """Fixed-bound histogram with running sum/count.

    ``bounds`` are upper edges of the finite buckets; one extra bucket
    catches overflow (the Prometheus ``+Inf`` bucket). Observation is a
    linear scan — bounds are short (≤ ~24) and the hot path observes a
    handful of values per engine step, so this stays cheaper than the
    dispatch it measures.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str,
                 labels: Tuple[Tuple[str, str], ...],
                 lock: threading.Lock,
                 bounds: Sequence[float] = LATENCY_BUCKETS) -> None:
        super().__init__(name, help, labels, lock)
        b = tuple(float(x) for x in bounds)
        if list(b) != sorted(b) or len(set(b)) != len(b):
            raise ValueError(f"histogram {name}: bounds must be strictly "
                             f"increasing, got {b}")
        self.bounds = b
        self._counts = [0] * (len(b) + 1)
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        i = 0
        n = len(self.bounds)
        while i < n and v > self.bounds[i]:
            i += 1
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    @property
    def value(self) -> float:
        """Mean observation (NaN when empty) — the scalar summary."""
        return self._sum / self._count if self._count else float("nan")

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def counts(self) -> Tuple[int, ...]:
        return tuple(self._counts)

    def reset(self) -> None:
        with self._lock:
            self._counts = [0] * (len(self.bounds) + 1)
            self._sum = 0.0
            self._count = 0


class MetricsRegistry:
    """Flat series store keyed by ``(name, labels)``.

    ``counter``/``gauge``/``histogram`` are get-or-create: calling twice
    with the same name+labels returns the same handle, so engine layers
    can cache handles at init and label-variant call sites (per finish
    reason, per draft source) can resolve lazily. A name is bound to one
    instrument type; re-requesting it as another type raises.

    ``base_labels`` (e.g. ``{"pod": "0"}``) are merged into every series
    created through this registry — this is how per-pod labelling on the
    decentralized server works without threading a pod id through every
    call site.
    """

    def __init__(self, base_labels: Optional[Mapping[str, str]] = None) -> None:
        self.base_labels = dict(base_labels or {})
        self._lock = threading.Lock()
        self._series: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], _Series] = {}
        self._kinds: Dict[str, str] = {}
        self._help: Dict[str, str] = {}

    # -- creation ---------------------------------------------------------
    def _get(self, cls, name: str, help: str,
             labels: Optional[Mapping[str, str]], **kw) -> _Series:
        merged = dict(self.base_labels)
        merged.update(labels or {})
        key = (name, _label_key(merged))
        with self._lock:
            s = self._series.get(key)
            if s is not None:
                if not isinstance(s, cls):
                    raise ValueError(f"metric {name!r} already registered "
                                     f"as {s.kind}, not {cls.kind}")
                return s
            if name in self._kinds and self._kinds[name] != cls.kind:
                raise ValueError(f"metric {name!r} already registered "
                                 f"as {self._kinds[name]}, not {cls.kind}")
            s = cls(name, help or self._help.get(name, ""), key[1],
                    threading.Lock(), **kw)
            self._series[key] = s
            self._kinds[name] = cls.kind
            if help:
                self._help[name] = help
            return s

    def counter(self, name: str, help: str = "",
                labels: Optional[Mapping[str, str]] = None) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Optional[Mapping[str, str]] = None) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Optional[Mapping[str, str]] = None,
                  bounds: Sequence[float] = LATENCY_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, labels, bounds=bounds)

    # -- access -----------------------------------------------------------
    def series(self) -> List[_Series]:
        with self._lock:
            return sorted(self._series.values(),
                          key=lambda s: (s.name, s.labels))

    def get(self, name: str,
            labels: Optional[Mapping[str, str]] = None) -> Optional[_Series]:
        merged = dict(self.base_labels)
        merged.update(labels or {})
        return self._series.get((name, _label_key(merged)))

    def reset(self) -> None:
        """Zero every series (documented contract — see module docstring).

        Series objects survive: handles cached by the engine keep
        working, only their values return to zero. Use this between
        exposition epochs; the engine's per-run hygiene is the narrower
        ``reset_stats()`` built on individual handles.
        """
        for s in self.series():
            s.reset()

    # -- exposition -------------------------------------------------------
    def to_dict(self) -> Dict[str, object]:
        """JSON-friendly snapshot: one entry per series."""
        out: List[Dict[str, object]] = []
        for s in self.series():
            d: Dict[str, object] = {
                "name": s.name, "type": s.kind, "labels": s.label_dict,
            }
            if isinstance(s, Histogram):
                d["sum"] = s.sum
                d["count"] = s.count
                d["bounds"] = list(s.bounds)
                d["buckets"] = list(s.counts)
            else:
                d["value"] = s.value
            out.append(d)
        return {"metrics": out}

    def to_prometheus(self) -> str:
        return prometheus([self])

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)


# -- process-global default registry -------------------------------------
# Engines created with EngineConfig(metrics=True) attach their private
# registries here so one exposition endpoint can serve every live engine
# in the process. WeakSet: an engine that goes away takes its series with
# it instead of leaking into the global view forever.
_DEFAULT = MetricsRegistry()
_ATTACHED: "weakref.WeakSet[MetricsRegistry]" = weakref.WeakSet()


def default_registry() -> MetricsRegistry:
    """The process-global registry (plus ``attached()`` engine views)."""
    return _DEFAULT


def attach(reg: MetricsRegistry) -> None:
    _ATTACHED.add(reg)


def detach(reg: MetricsRegistry) -> None:
    _ATTACHED.discard(reg)


def attached() -> List[MetricsRegistry]:
    return sorted(_ATTACHED, key=lambda r: sorted(r.base_labels.items()))


def _all_default() -> List[MetricsRegistry]:
    return [_DEFAULT] + attached()


def snapshot(regs: Optional[Iterable[MetricsRegistry]] = None) -> Dict[str, object]:
    """Merged JSON snapshot over ``regs`` (default: global + attached)."""
    merged: List[object] = []
    for r in (_all_default() if regs is None else regs):
        merged.extend(r.to_dict()["metrics"])  # type: ignore[arg-type]
    return {"metrics": merged}


def prometheus(regs: Optional[Iterable[MetricsRegistry]] = None) -> str:
    """Prometheus text exposition over ``regs`` (default: global + attached).

    ``# HELP``/``# TYPE`` once per metric name even when the same name
    appears in several registries (one series per pod).
    """
    by_name: Dict[str, List[_Series]] = {}
    kinds: Dict[str, str] = {}
    helps: Dict[str, str] = {}
    for r in (_all_default() if regs is None else regs):
        for s in r.series():
            by_name.setdefault(s.name, []).append(s)
            kinds[s.name] = s.kind
            if s.help:
                helps.setdefault(s.name, s.help)
    lines: List[str] = []
    for name in sorted(by_name):
        if name in helps:
            lines.append(f"# HELP {name} {helps[name]}")
        lines.append(f"# TYPE {name} {kinds[name]}")
        for s in by_name[name]:
            if isinstance(s, Histogram):
                cum = 0
                for bound, c in zip(list(s.bounds) + [float("inf")],
                                    s.counts):
                    cum += c
                    le = "+Inf" if bound == float("inf") else repr(bound)
                    lines.append(f"{name}_bucket"
                                 f"{_fmt_labels(s.labels, ('le', le))} {cum}")
                lines.append(f"{name}_sum{_fmt_labels(s.labels)} {s.sum}")
                lines.append(f"{name}_count{_fmt_labels(s.labels)} {s.count}")
            else:
                lines.append(f"{name}{_fmt_labels(s.labels)} {s.value}")
    return "\n".join(lines) + "\n"


def _fmt_labels(labels: Tuple[Tuple[str, str], ...],
                extra: Optional[Tuple[str, str]] = None) -> str:
    items = list(labels) + ([extra] if extra else [])
    if not items:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in items)
    return "{" + body + "}"
