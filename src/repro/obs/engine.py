"""Per-engine telemetry bundle: registry handles + trace recorder.

One :class:`EngineObs` per ``_SlotTable`` (per pod on the decentralized
server). It owns the engine's private :class:`MetricsRegistry` (labelled
``pod=<k>``), caches every hot-path instrument handle at construction so
the step loop does dict-free attribute loads, and holds either a real
:class:`TraceRecorder` or the :class:`NullRecorder` off-switch.

The metrics side is **always on** — plain-Python counter bumps and a few
``perf_counter`` stamps per engine step, orders of magnitude below the
device dispatch they time (the ``serve_obs`` bench gates the full
trace+metrics overhead at ≤ 1.05×). The trace side is off by default:
every span site checks ``obs.trace.enabled`` (or uses the no-op emit)
before doing any per-event work.

Metric catalog lives in docs/observability.md; names are stable surface.
"""
from __future__ import annotations

import time
from typing import Dict, Optional

from repro.obs import metrics as _m
from repro.obs.trace import (ADMIT_TID, SLOT_TID0, STEP_TID, NullRecorder,
                             TraceRecorder)

__all__ = ["EngineObs"]

# Accept-length histogram: speculative spans commit 1..spec_len tokens
# per verify step; unit-width buckets make the histogram an exact
# distribution over commit lengths for any spec_len <= 16.
ACCEPT_LEN_BUCKETS = tuple(float(i) for i in range(1, 17))
# Per-request accept-rate in [0, 1], tenth-width buckets.
RATE_BUCKETS = tuple(round(0.1 * i, 1) for i in range(0, 11))


class EngineObs:
    """Telemetry handles for one engine/pod.

    Parameters
    ----------
    pod: pod index — becomes the trace ``pid`` and the registry's
        ``pod`` label.
    trace: attach a real ring-buffer recorder (else the no-op recorder).
    trace_ring: ring capacity when tracing.
    publish: attach this registry to the process-global exposition set
        (``EngineConfig(metrics=True)``).
    """

    def __init__(self, *, pod: int = 0, trace: bool = False,
                 trace_ring: int = 65536, publish: bool = False) -> None:
        self.pod = pod
        self.registry = _m.MetricsRegistry(base_labels={"pod": str(pod)})
        self.trace: NullRecorder = (
            TraceRecorder(capacity=trace_ring, pid=pod) if trace
            else NullRecorder(pid=pod))
        if publish:
            _m.attach(self.registry)
        r = self.registry
        # -- request lifecycle (counters) --------------------------------
        self.submitted = r.counter(
            "serve_requests_submitted_total",
            "requests handed to add_request")
        self.admitted = r.counter(
            "serve_admissions_total",
            "requests that won a slot (or retired at admission)")
        self.aborted = r.counter(
            "serve_aborts_total", "requests cancelled via abort()")
        self._retired: Dict[str, _m.Counter] = {}
        # -- step loop ----------------------------------------------------
        self.steps = r.counter("serve_engine_steps_total",
                               "engine step() iterations")
        self.dispatch_s = r.histogram(
            "serve_step_dispatch_seconds",
            "host time to build + launch the fused step dispatch")
        self.readback_s = r.histogram(
            "serve_step_device_get_seconds",
            "host time blocked in the one per-step jax.device_get")
        self.active_g = r.gauge("serve_active_slots",
                                "slots holding a live request")
        self.waiting_g = r.gauge("serve_waiting_requests",
                                 "requests queued for admission")
        self.pool_free_g = r.gauge("serve_pool_free_blocks",
                                   "free physical KV blocks in the pool")
        self.pool_total_g = r.gauge("serve_pool_blocks",
                                    "physical KV blocks in the pool")
        # -- request latency (histograms) --------------------------------
        self.queued_s = r.histogram(
            "serve_request_queued_seconds",
            "submission to admission (queue delay)")
        self.ttft_s = r.histogram(
            "serve_request_ttft_seconds",
            "submission to first emitted token")
        self.e2e_s = r.histogram(
            "serve_request_e2e_seconds", "submission to retirement")
        # -- speculative decoding ----------------------------------------
        self.spec_steps = r.counter(
            "serve_spec_steps_total", "speculative verify dispatches")
        self.spec_tokens = r.counter(
            "serve_spec_tokens_total",
            "tokens committed by speculative verify steps")
        self.accept_len = r.histogram(
            "serve_spec_accept_length",
            "tokens committed per verify step (1 = all drafts rejected)",
            bounds=ACCEPT_LEN_BUCKETS)
        self.req_accept_rate = r.histogram(
            "serve_spec_request_accept_rate",
            "per-request draft acceptance rate at retirement",
            bounds=RATE_BUCKETS)
        self._drafts: Dict[str, Dict[str, _m.Counter]] = {}
        # -- multi-tenant QoS (lazily-resolved tenant-labelled counters) --
        self._tenant_tokens: Dict[str, _m.Counter] = {}
        self._preempted: Dict[str, _m.Counter] = {}
        self._resumed: Dict[str, _m.Counter] = {}
        self._rejected: Dict[str, _m.Counter] = {}

    # -- labelled lazily-resolved counters --------------------------------
    def retired(self, reason: str) -> _m.Counter:
        """`serve_retirements_total{reason=...}` — one per finish reason."""
        c = self._retired.get(reason)
        if c is None:
            c = self.registry.counter(
                "serve_retirements_total",
                "requests retired from a slot, by finish_reason",
                labels={"reason": reason})
            self._retired[reason] = c
        return c

    def drafts(self, source: str, kind: str) -> _m.Counter:
        """`serve_spec_drafts_{proposed,accepted}_total{source=...}`."""
        by_kind = self._drafts.setdefault(source, {})
        c = by_kind.get(kind)
        if c is None:
            c = self.registry.counter(
                f"serve_spec_drafts_{kind}_total",
                f"draft tokens {kind}, by draft source",
                labels={"source": source})
            by_kind[kind] = c
        return c

    def tenant_tokens(self, tenant: str) -> _m.Counter:
        """`serve_tenant_tokens_total{tenant=...}` — tokens emitted for
        the tenant's retired requests (live requests are added by
        ``stats()`` on top of this cumulative base)."""
        c = self._tenant_tokens.get(tenant)
        if c is None:
            c = self.registry.counter(
                "serve_tenant_tokens_total",
                "tokens emitted, by tenant (counted at retirement)",
                labels={"tenant": tenant})
            self._tenant_tokens[tenant] = c
        return c

    def preempted(self, tenant: str, mode: str) -> _m.Counter:
        """`serve_preemptions_total{tenant=,mode=}` — requests parked
        (swap/recompute) or bounced back mid-prefill (requeue)."""
        key = f"{tenant}\x00{mode}"
        c = self._preempted.get(key)
        if c is None:
            c = self.registry.counter(
                "serve_preemptions_total",
                "decoding/prefilling requests preempted, by tenant + mode",
                labels={"tenant": tenant, "mode": mode})
            self._preempted[key] = c
        return c

    def resumed(self, tenant: str) -> _m.Counter:
        """`serve_resumes_total{tenant=...}` — preempted requests
        re-admitted into a slot."""
        c = self._resumed.get(tenant)
        if c is None:
            c = self.registry.counter(
                "serve_resumes_total",
                "preempted requests re-admitted, by tenant",
                labels={"tenant": tenant})
            self._resumed[tenant] = c
        return c

    def rejected(self, tenant: str) -> _m.Counter:
        """`serve_rejections_total{tenant=...}` — submissions refused by
        admission control (finish_reason="rejected")."""
        c = self._rejected.get(tenant)
        if c is None:
            c = self.registry.counter(
                "serve_rejections_total",
                "submissions refused by admission control, by tenant",
                labels={"tenant": tenant})
            self._rejected[tenant] = c
        return c

    # -- aggregate views used by stats() ----------------------------------
    @property
    def n_aborted(self) -> int:
        return int(self.aborted.value)

    @property
    def n_stopped(self) -> int:
        c = self._retired.get("stop")
        return int(c.value) if c is not None else 0

    @property
    def n_spec_steps(self) -> int:
        return int(self.spec_steps.value)

    @property
    def n_spec_tokens(self) -> int:
        return int(self.spec_tokens.value)

    def reset_run_counters(self) -> None:
        """Per-run hygiene: zero the request-lifecycle counters.

        Called at the top of ``serve()`` so back-to-back drain loops on
        one engine report that run's ``aborted``/``stopped`` alone.
        Cumulative series (spec totals, prefix cache, latency
        histograms) are left to the full ``registry.reset()``.
        """
        self.aborted.reset()
        for c in self._retired.values():
            c.reset()

    # -- trace conveniences ------------------------------------------------
    def name_tracks(self, n_slots: int, label: str) -> None:
        """Emit the "M" metadata naming this pod + its fixed tracks."""
        tr = self.trace
        if not tr.enabled:
            return
        tr.set_process_name(label)
        tr.set_thread_name(STEP_TID, "engine steps")
        tr.set_thread_name(ADMIT_TID, "queue / admission-retired")
        for s in range(n_slots):
            tr.set_thread_name(SLOT_TID0 + s, f"slot {s}")

    @staticmethod
    def slot_tid(slot: int) -> int:
        return SLOT_TID0 + slot

    def step_timing(self, kind: str, t0: float, t1: float) -> None:
        """Record one step's dispatch/readback split (t2 = now).

        ``t0`` → dispatch begins, ``t1`` → dispatch returned (device
        launch queued), now → ``jax.device_get`` readback done. The
        histograms always update; the trace gets a nested
        step ⊃ {dispatch, device_get} span triple on the step track.
        """
        t2 = time.perf_counter()
        self.dispatch_s.observe(t1 - t0)
        self.readback_s.observe(t2 - t1)
        tr = self.trace
        if tr.enabled:
            tr.complete(f"step:{kind}", t0, t2, STEP_TID)
            tr.complete("dispatch", t0, t1, STEP_TID)
            tr.complete("device_get", t1, t2, STEP_TID)
        return None
