"""Engine telemetry: metrics registry, span tracing, Perfetto export.

Host-side only — nothing in this package imports jax or runs inside a
jitted scope, so it is clean under the ``repro.analysis`` lint by
construction. See docs/observability.md for the metric catalog and the
span taxonomy.
"""
from repro.obs.engine import EngineObs
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               default_registry, log_buckets, prometheus,
                               snapshot)
from repro.obs.trace import NullRecorder, TraceRecorder, merge_chrome

__all__ = [
    "EngineObs",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "default_registry", "log_buckets", "prometheus", "snapshot",
    "NullRecorder", "TraceRecorder", "merge_chrome",
]
