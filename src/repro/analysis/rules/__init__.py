"""repro-lint rules. Each module exposes RULE (name) and check(ctx)."""
from repro.analysis.rules import host_sync, kernel_bounds, retrace_hazard

RULE_CHECKS = {
    host_sync.RULE: host_sync.check,
    retrace_hazard.RULE: retrace_hazard.check,
    kernel_bounds.RULE: kernel_bounds.check,
}

__all__ = ["RULE_CHECKS", "host_sync", "retrace_hazard", "kernel_bounds"]
