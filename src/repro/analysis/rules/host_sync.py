"""Rule ``host-sync`` — implicit device syncs where they stall the engine.

Two scopes, two severities of mistake:

* **jit-traced functions** (in-module ``jax.jit`` closure + ``# repro:
  jit`` marks): any host coercion of a traced value is wrong — ``int()`` /
  ``float()`` / ``bool()`` / ``.item()`` / ``.tolist()`` / ``np.asarray``
  either errors at trace time (concretization) or silently burns a
  constant into the trace.  ``jax.device_get`` under trace is flagged too.
  Implicit truth-value tests (``if``/``while``/``assert``/``and``/``not``)
  of jnp-derived values are the classic ConcretizationTypeError.

* **host hot-path functions** (the ``_SlotTable`` serving family +
  ``# repro: hot-path`` marks): the sanctioned pattern is ONE pre-jitted
  dispatch then ONE explicit ``jax.device_get``.  What flags here is the
  *implicit* sync — coercing an eagerly-computed device value (PR 6's
  ``np.asarray(jnp.argmax(...))`` greedy fast path did exactly this) — and
  eager ``jnp`` compute ops, each of which is an un-fused device dispatch
  in the per-token loop.  ``jax.device_get`` is NOT flagged on the host:
  it is the explicit sync point the fused step is built around, and
  coercing its result (or the result of a known-jitted function) is free.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Set

from repro.analysis.lint import (COERCION_BUILTINS, COERCION_METHODS,
                                 COERCION_NP, Finding, ModuleCtx, dotted,
                                 expr_taint, tainted_names,
                                 walk_opaque_device_get)

RULE = "host-sync"


def _coercion_call(node: ast.Call) -> str:
    """Name of the host-coercion this call performs, or ''. """
    func = node.func
    if isinstance(func, ast.Name) and func.id in COERCION_BUILTINS:
        return func.id
    name = dotted(func)
    if name:
        root, _, attr = name.rpartition(".")
        if root in ("np", "numpy") and attr in COERCION_NP:
            return name
    if isinstance(func, ast.Attribute) and func.attr in COERCION_METHODS:
        return f".{func.attr}()"
    return ""


def _truth_contexts(fn: ast.AST, ctx: ModuleCtx) -> Iterator[ast.AST]:
    for n in ctx.own_statements(fn):
        if isinstance(n, (ast.If, ast.While, ast.IfExp)):
            yield n.test
        elif isinstance(n, ast.Assert):
            yield n.test
        elif isinstance(n, ast.BoolOp):
            for v in n.values:
                yield v
        elif isinstance(n, ast.UnaryOp) and isinstance(n.op, ast.Not):
            yield n.operand


def _item_method_on(node: ast.Call) -> bool:
    return isinstance(node.func, ast.Attribute) and \
        node.func.attr in COERCION_METHODS


def check(ctx: ModuleCtx) -> List[Finding]:
    findings: List[Finding] = []

    def flag(node: ast.AST, msg: str) -> None:
        findings.append(Finding(RULE, ctx.path, node.lineno,
                                node.col_offset, msg))

    traced = ctx.jit_traced
    hot_only = ctx.hot - traced

    # ---- jit-traced scope ------------------------------------------------
    for fn in traced:
        taint: Set[str] = tainted_names(fn)
        params = {a.arg for a in _args_of(fn)}

        def coerced_traced(node: ast.AST) -> str:
            """Taint reason when coercing ``node`` would concretize.

            Shape/dtype access (``x.shape[0]``) is static under trace, so
            only a *bare* param name (or a subscript of one) counts — not
            any name buried in an attribute path.
            """
            why = expr_taint(node, taint)
            if why:
                return why
            base = node
            while isinstance(base, ast.Subscript):
                base = base.value
            if isinstance(base, ast.Name) and base.id in params:
                return base.id
            return ""

        for n in ctx.own_statements(fn):
            if isinstance(n, ast.Call):
                name = dotted(n.func)
                if name == "jax.device_get":
                    flag(n, "jax.device_get under jit trace: the value is "
                            "abstract here — hoist the sync to the caller")
                    continue
                coercion = _coercion_call(n)
                if coercion and (n.args or _item_method_on(n)):
                    target = n.args[0] if n.args else n.func.value
                    why = coerced_traced(target)
                    if why:
                        flag(n, f"{coercion} of traced value ({why}) "
                                "inside a jit-traced function — "
                                "concretization error or burned-in "
                                "constant; compute it on the device or "
                                "pass it in as a static")
        # params with literal defaults are Python-level config flags
        # (``log_space=False``): static at trace time, never device values
        flag_params = _defaulted_params(fn)
        for test in _truth_contexts(fn, ctx):
            why = expr_taint(test, taint)
            if not why and isinstance(test, ast.Name) and \
                    test.id in params and test.id not in flag_params:
                why = test.id
            if why:
                flag(test, f"implicit truth-value coercion of traced "
                           f"value ({why}) in a jit-traced function — "
                           "use jnp.where / lax.cond instead of Python "
                           "control flow")

    # ---- host hot-path scope --------------------------------------------
    for fn in hot_only:
        taint = tainted_names(fn)
        # eager ops nested inside an already-flagged coercion are the same
        # incident — report the coercion once, not its subexpressions too
        coerced_subtrees: Set[int] = set()
        for n in ctx.own_statements(fn):
            if not isinstance(n, ast.Call):
                continue
            op_why = expr_taint(n, set())
            coercion = _coercion_call(n)
            if coercion and (n.args or _item_method_on(n)):
                target = n.args[0] if n.args else n.func.value
                why = expr_taint(target, taint)
                if why:
                    flag(n, f"{coercion} of device value ({why}) on the "
                            "host hot path — an implicit blocking sync "
                            "per step; fold the compute into the jitted "
                            "step and sync once via jax.device_get")
                    coerced_subtrees.update(id(s) for s in ast.walk(target))
                    continue
            # eager device compute dispatched from the host loop
            if op_why and op_why.startswith("jnp.") and \
                    _is_direct_eager(n) and id(n) not in coerced_subtrees:
                flag(n, f"eager {op_why[:-5]}(...) dispatch on the host "
                        "hot path — each call is an un-fused device "
                        "dispatch per step; move it into a pre-jitted "
                        "function")
        for test in _truth_contexts(fn, ctx):
            why = expr_taint(test, taint)
            if why:
                flag(test, f"implicit truth-value coercion of device "
                           f"value ({why}) on the host hot path — a "
                           "blocking sync; jax.device_get it explicitly")

    # dedupe (a nested eager op can be reached via two walks)
    seen = set()
    out = []
    for f in findings:
        key = (f.line, f.col, f.msg)
        if key not in seen:
            seen.add(key)
            out.append(f)
    return out


def _defaulted_params(fn: ast.AST):
    """Param names with literal (Constant) defaults."""
    a = fn.args
    out = set()
    pos = [*a.posonlyargs, *a.args]
    for arg, dflt in zip(reversed(pos), reversed(a.defaults)):
        if isinstance(dflt, ast.Constant):
            out.add(arg.arg)
    for arg, dflt in zip(a.kwonlyargs, a.kw_defaults):
        if dflt is not None and isinstance(dflt, ast.Constant):
            out.add(arg.arg)
    return out


def _args_of(fn: ast.AST):
    a = fn.args
    return [*a.posonlyargs, *a.args, *a.kwonlyargs] + \
        ([a.vararg] if a.vararg else []) + ([a.kwarg] if a.kwarg else [])


def _is_direct_eager(call: ast.Call) -> bool:
    """True when this Call node itself is the eager jnp op (not merely an
    ancestor expression containing one — those flag at their own node)."""
    from repro.analysis.lint import _eager_op_name
    return _eager_op_name(dotted(call.func)) is not None
