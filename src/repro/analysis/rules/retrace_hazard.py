"""Rule ``retrace-hazard`` — patterns that recompile per call or per value.

The serving stack only stays single-dispatch because every jitted function
is traced a *bounded* number of times (the repo's idioms: pow-of-2 stop
widths, ``_nb_live`` capped at ``nb_slot``).  Three hazards break that:

* **RT1 value-dependent shape** — a host scalar derived from device values
  (``int(jnp.sum(mask))``) flowing into a shape-constructing call
  (``jnp.zeros(n)``): a fresh shape — and a fresh trace of every consumer
  — per distinct value.
* **RT2 unhashable static args** — ``jax.jit(..., static_argnums=...)``
  fed a dict/list/set at the static position: either a TypeError
  (unhashable) or, with custom hashables, a silent cache miss per call.
* **RT3 jit-under-loop** — ``jax.jit(...)`` applied inside a loop or a
  hot-path function: each call wraps a fresh function object, so the trace
  cache never hits and every call pays a full retrace.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.lint import (SHAPE_CONSTRUCTORS, Finding, ModuleCtx,
                                 dotted, expr_taint, tainted_names)

RULE = "retrace-hazard"

_COERCERS = {"int", "float"}
_MUTABLE_CALLS = {"dict", "list", "set"}


def _is_shape_constructor(name: Optional[str]) -> bool:
    if not name:
        return False
    for prefix in ("jnp.", "jax.numpy."):
        if name.startswith(prefix) and \
                name[len(prefix):] in SHAPE_CONSTRUCTORS:
            return True
    return False


def _is_mutable_literal(node: ast.AST) -> bool:
    if isinstance(node, (ast.Dict, ast.List, ast.Set, ast.DictComp,
                         ast.ListComp, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in _MUTABLE_CALLS:
        return True
    return False


def _static_positions(jit_call: ast.Call):
    """(set of static positions, set of static names) from a jax.jit call."""
    nums: Set[int] = set()
    names: Set[str] = set()
    for kw in jit_call.keywords:
        val = kw.value
        items = val.elts if isinstance(val, (ast.Tuple, ast.List)) \
            else [val]
        if kw.arg == "static_argnums":
            for it in items:
                if isinstance(it, ast.Constant) and \
                        isinstance(it.value, int):
                    nums.add(it.value)
        elif kw.arg == "static_argnames":
            for it in items:
                if isinstance(it, ast.Constant) and \
                        isinstance(it.value, str):
                    names.add(it.value)
    return nums, names


def check(ctx: ModuleCtx) -> List[Finding]:
    findings: List[Finding] = []

    def flag(node: ast.AST, msg: str) -> None:
        findings.append(Finding(RULE, ctx.path, node.lineno,
                                node.col_offset, msg))

    # ---- RT1: tainted scalars flowing into shape constructors ------------
    # (an empty taint set still matters: expr_taint recognizes a direct
    # device-op argument like int(jnp.sum(x)) without any named taint)
    for fn in ctx.funcs:
        taint = tainted_names(fn)
        for n in ctx.own_statements(fn):
            if not (isinstance(n, ast.Call)
                    and _is_shape_constructor(dotted(n.func))):
                continue
            shape_args = list(n.args[:1]) + \
                [kw.value for kw in n.keywords if kw.arg == "shape"]
            for arg in shape_args:
                for sub in ast.walk(arg):
                    if isinstance(sub, ast.Call) and \
                            isinstance(sub.func, ast.Name) and \
                            sub.func.id in _COERCERS and sub.args:
                        why = expr_taint(sub.args[0], taint)
                        if why:
                            flag(n, "value-dependent shape: "
                                    f"{sub.func.id}() of device value "
                                    f"({why}) feeds a shape constructor "
                                    "— a fresh shape (and a retrace of "
                                    "every jitted consumer) per distinct "
                                    "value; pad to a bounded set of "
                                    "shapes instead")

    # ---- RT2: unhashable static args -------------------------------------
    # map: name bound from `x = jax.jit(f, static_argnums/argnames=...)`
    jitted_statics: Dict[str, tuple] = {}
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Assign) and \
                isinstance(node.value, ast.Call) and \
                dotted(node.value.func) in ("jax.jit", "jit"):
            nums, names = _static_positions(node.value)
            if not (nums or names):
                continue
            for t in node.targets:
                if isinstance(t, ast.Name):
                    jitted_statics[t.id] = (nums, names, node.value)
            # mutable default on the wrapped def at a static position
            if node.value.args and \
                    isinstance(node.value.args[0], ast.Name):
                for d in ctx._defs_by_name.get(node.value.args[0].id, ()):
                    all_args = d.args.posonlyargs + d.args.args
                    defaults = d.args.defaults
                    offset = len(all_args) - len(defaults)
                    for i, dflt in enumerate(defaults):
                        pos = offset + i
                        if (pos in nums or
                                all_args[pos].arg in names) and \
                                _is_mutable_literal(dflt):
                            flag(dflt, "mutable default for static arg "
                                       f"'{all_args[pos].arg}' of a "
                                       "jitted function — unhashable, "
                                       "TypeError at first call; use a "
                                       "tuple/frozenset")
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id in jitted_statics):
            continue
        nums, names, _ = jitted_statics[node.func.id]
        for i, arg in enumerate(node.args):
            if i in nums and _is_mutable_literal(arg):
                flag(arg, f"dict/list/set passed at static position {i} "
                          f"of jitted '{node.func.id}' — unhashable "
                          "static arg: TypeError, or a cache miss (full "
                          "retrace) per call if made hashable; pass a "
                          "tuple/frozenset")
        for kw in node.keywords:
            if kw.arg in names and _is_mutable_literal(kw.value):
                flag(kw.value, f"dict/list/set passed as static arg "
                               f"'{kw.arg}' of jitted '{node.func.id}' "
                               "— unhashable static arg; pass a "
                               "tuple/frozenset")

    # ---- RT3: jax.jit applied per-call -----------------------------------
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call)
                and dotted(node.func) in ("jax.jit", "jit")):
            continue
        enclosing = ctx.enclosing_function(node)
        in_loop = ctx.in_loop(node)
        hot = enclosing is not None and enclosing in ctx.hot and \
            not _is_setup_method(enclosing)
        if in_loop or hot:
            where = "inside a loop" if in_loop else \
                "in a hot-path function"
            flag(node, f"jax.jit applied {where}: each call wraps a "
                       "fresh function object, so the trace cache never "
                       "hits and every call retraces; jit once at setup "
                       "and reuse the wrapped function")
    return findings


def _is_setup_method(fn: ast.AST) -> bool:
    """__init__ / make_* factories legitimately build jitted closures."""
    name = getattr(fn, "name", "")
    return name == "__init__" or name.startswith("make_") or \
        name.startswith("_make_") or name.startswith("_build")
