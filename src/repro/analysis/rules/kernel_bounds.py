"""Rule ``kernel-bounds`` — Pallas BlockSpec index maps must stay in-range.

A BlockSpec index map turns grid coordinates (plus scalar-prefetch refs)
into a block index per operand axis.  Pallas does not bounds-check it: an
out-of-range index silently reads/writes the wrong pool block — the static
cousin of PR 4's eviction-aliasing bug.  Two checks per index-map return
component:

* **KB1 unclamped arithmetic** — a component that *grows* a grid variable
  (``*`` or ``+``) without a clamp (``jnp.minimum`` / ``jnp.clip`` / ``%``)
  anywhere above it cannot be shown in-range for the declared grid.
  Contracting ops (``//``, ``%``) pass: they only shrink the index (the
  flash kernels' ``h // group`` GQA maps are the canonical negative).
* **KB2 table-resolved index** — a component that subscripts a
  scalar-prefetch ref (``bt_r[b, ki]``) resolves through runtime data; its
  bound is a *pool invariant* the AST cannot see.  These require a
  ``# repro: bounds <why>`` annotation in the enclosing function naming
  the ref — the reviewer-visible statement of the invariant (e.g. "the
  allocator only hands out ids < pool size and unallocated rows are masked
  to the reserved scratch block").
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set, Tuple

from repro.analysis.lint import Finding, ModuleCtx, dotted

RULE = "kernel-bounds"

_CLAMPS = {"jnp.minimum", "jnp.clip", "jax.numpy.minimum",
           "jax.numpy.clip", "min", "pl.cdiv"}


def _blockspec_calls(ctx: ModuleCtx) -> Iterator[ast.Call]:
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            name = dotted(node.func)
            if name and name.split(".")[-1] == "BlockSpec":
                yield node


def _index_map_of(call: ast.Call, ctx: ModuleCtx) -> Optional[ast.AST]:
    """The index-map callable of a BlockSpec call: a lambda / local def
    passed positionally or as ``index_map=``."""
    cands: List[ast.AST] = list(call.args)
    cands += [kw.value for kw in call.keywords if kw.arg == "index_map"]
    for c in cands:
        if isinstance(c, ast.Lambda):
            return c
        if isinstance(c, ast.Name):
            # a def in the same enclosing function (the repo's idiom:
            # ``def imap(...)`` next to the pl.BlockSpec call)
            scope = ctx.enclosing_function(call)
            while scope is not None:
                for n in ast.walk(scope):
                    if isinstance(n, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)) and \
                            n.name == c.id:
                        return n
                scope = ctx.enclosing_function(scope)
            for n in ctx._defs_by_name.get(c.id, ()):
                return n
    return None


def _params_of(fn: ast.AST) -> List[str]:
    a = fn.args
    return [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]


def _return_components(fn: ast.AST) -> Iterator[ast.AST]:
    if isinstance(fn, ast.Lambda):
        body = fn.body
        elems = body.elts if isinstance(body, ast.Tuple) else [body]
        yield from elems
        return
    for n in ast.walk(fn):
        if isinstance(n, ast.Return) and n.value is not None:
            v = n.value
            yield from (v.elts if isinstance(v, ast.Tuple) else [v])


def _has_clamp_above(node: ast.AST, parents) -> bool:
    p = parents.get(node)
    while p is not None:
        if isinstance(p, ast.Call) and dotted(p.func) in _CLAMPS:
            return True
        if isinstance(p, ast.BinOp) and isinstance(p.op, ast.Mod):
            return True
        if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.Lambda)):
            break
        p = parents.get(p)
    return False


def _growing_binops(node: ast.AST,
                    grid: Set[str]) -> Iterator[ast.BinOp]:
    """Outermost Mult/Add chains over a grid variable: ``i * bps + 1`` is
    ONE unclamped expression, not an Add finding plus a Mult finding —
    a matched chain is yielded whole and not descended into."""
    if isinstance(node, ast.BinOp) and \
            isinstance(node.op, (ast.Mult, ast.Add)):
        for leaf in ast.walk(node):
            if isinstance(leaf, ast.Name) and leaf.id in grid:
                yield node
                return
    for child in ast.iter_child_nodes(node):
        yield from _growing_binops(child, grid)


def check(ctx: ModuleCtx) -> List[Finding]:
    findings: List[Finding] = []

    def flag(node: ast.AST, msg: str) -> None:
        findings.append(Finding(RULE, ctx.path, node.lineno,
                                node.col_offset, msg))

    for spec in _blockspec_calls(ctx):
        imap = _index_map_of(spec, ctx)
        if imap is None:
            continue
        params = set(_params_of(imap))
        seen_binops: Set[ast.BinOp] = set()

        # KB1 — scan the whole imap body (components may be built through
        # local assignments like ``ki = kc * bps + j``)
        body_nodes = [imap.body] if isinstance(imap, ast.Lambda) \
            else imap.body
        for stmt in body_nodes:
            for binop in _growing_binops(stmt, params):
                if binop in seen_binops:
                    continue
                seen_binops.add(binop)
                if not _has_clamp_above(binop, ctx.parent):
                    flag(binop, "unclamped index arithmetic over a grid "
                                "variable in a BlockSpec index map — the "
                                "result cannot be shown in-range for the "
                                "declared grid; clamp with jnp.minimum("
                                "..., bound - 1) (Pallas does not bounds-"
                                "check block indices)")

        # KB2 — table-resolved components need a bounds annotation
        for comp in _return_components(imap):
            for n in ast.walk(comp):
                if not isinstance(n, ast.Subscript):
                    continue
                base = n.value
                if isinstance(base, ast.Name) and base.id in params:
                    lo, hi = _annotation_span(ctx, spec, imap)
                    notes = ctx.directives.bounds_in_span(lo, hi)
                    if not any(base.id in t for t in notes):
                        flag(n, f"index map resolves through prefetch "
                                f"ref '{base.id}' — its values are "
                                "runtime data whose bound the AST cannot "
                                "see; add '# repro: bounds ...' naming "
                                f"'{base.id}' and the invariant that "
                                "keeps it < the operand's leading dim")

    return findings


def _annotation_span(ctx: ModuleCtx, spec: ast.Call,
                     imap: ast.AST) -> Tuple[int, int]:
    """Lines where a ``# repro: bounds`` note counts: the enclosing
    function of the BlockSpec (or the module slice around it)."""
    scope = ctx.enclosing_function(spec) or ctx.enclosing_function(imap)
    if scope is not None and hasattr(scope, "end_lineno"):
        return scope.lineno, scope.end_lineno
    return max(1, spec.lineno - 20), spec.lineno + 20
