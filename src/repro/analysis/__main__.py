"""``python -m repro.analysis src/`` — run repro-lint, exit nonzero on
unwaived findings."""
import sys

from repro.analysis.lint import main

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
