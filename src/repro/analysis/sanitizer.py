"""PoolSanitizer — a race detector for the paged KV block pool.

The paged serving stack keeps four views of block ownership that must
agree every step: the allocator's free list, each slot's block table, the
prefix cache's refcounts/LRU, and (since the generation counters) each
table entry's allocation generation.  PR 4's refcount-0 eviction aliasing
— a cached block evicted to the free list while a live request's table
still mapped it, then handed to a second request — was exactly a
disagreement between these views that nothing cross-checked at runtime.

Enabled via ``EngineConfig(sanitize=True)`` / ``--sanitize``, the
sanitizer shadows ``_SlotTable`` around every dispatch:

* ``begin_step``  — records the step's write *plan*: one decode write per
  decoding slot at each position of its step span (vanilla steps write
  one position; a speculative step writes ``_SlotTable._step_span ==
  spec_len`` candidate positions), plus the scheduled prefill chunk's
  position span (replaying the scheduler's own chunk admission
  decision).
* ``check_step``  — resolves the plan through the (post-growth) block
  tables and asserts: every write lands in an owned, non-scratch block;
  no decode write touches a cache-tracked block (cached blocks are
  immutable — a write corrupts every future prefix hit); no chunk write
  touches a shared (refcount > 1) block; the chunk and decode write sets
  are disjoint.  Then runs the full pool scan.
* ``check_pool``  — conservation over the whole pool: every block is free
  XOR owned; a block mapped by two slots must be cache-tracked with a
  refcount equal to its holder count; refcount-0 tracked blocks sit on
  the LRU (and only those); no block is leaked (non-free, untracked,
  unmapped); every mapped entry's generation matches the allocator's
  (use-after-free).  Also called at ``abort``/retirement boundaries.

Violations raise ``PoolSanitizerError`` naming the offending slot/block.
Cost is pure host numpy over (n_slots × nb_slot) tables — small next to a
device dispatch; tier-1 runs a subset with it enabled (``-m sanitize``).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Set, Tuple

import numpy as np


class PoolSanitizerError(AssertionError):
    """A paged-pool ownership invariant was violated."""


class PoolSanitizer:
    """Shadow checker over one ``_SlotTable`` (see module docstring)."""

    def __init__(self, table):
        if not getattr(table, "paged", False):
            raise ValueError("PoolSanitizer shadows the paged block pool — "
                             "the table is not paged")
        self.table = table
        self.checked_steps = 0
        self.violations = 0
        self.owned_blocks = 0
        self._decode_plan: List[Tuple[int, int, int]] = []  # slot, rid, pos
        self._chunk_plan: Optional[Tuple[int, int, int, int]] = None

    # ------------------------------------------------------------------
    # step protocol
    # ------------------------------------------------------------------

    def begin_step(self) -> None:
        t = self.table
        self._decode_plan = [(s, t.slot_req[s].rid, int(t.pos[s]))
                             for s in t.decoding]
        self._chunk_plan = None
        if t.chunked and t.prefill_order and t._schedule_chunk():
            # _pick_chunk_slot caches its pick for the step, so this
            # shadow replay and the dispatch see the same slot without
            # double-charging the QoS tenant scheduler
            slot = t._pick_chunk_slot()
            start = int(t.prefill_pos[slot])
            length = min(t.chunk, int(t.prefill_width[slot]) - start)
            self._chunk_plan = (slot, t.slot_req[slot].rid, start, length)

    def check_step(self) -> None:
        t = self.table
        tracked = t.prefix.refcounts if t.prefix is not None else {}
        decode_writes: Set[int] = set()
        span = int(getattr(t, "_step_span", 1))
        s_log = t.nb_slot * t.block_size
        for slot, rid, pos in self._decode_plan:
            req = t.slot_req[slot]
            if req is None or req.rid != rid:
                continue            # retired this step; blocks already freed
            for p in range(pos, pos + span):
                if not t.ring and p >= s_log:
                    # a speculative span past the logical capacity writes
                    # the scratch block by construction (the verify scatter
                    # routes out-of-horizon positions there)
                    continue
                lb = self._logical_block(p)
                pb = self._owned_entry(slot, rid, lb, p,
                                       kind="decode write")
                if pb in tracked:
                    self._violate(
                        f"slot {slot} (request {rid}) decode write at "
                        f"position {p} lands in cache-tracked block {pb} "
                        f"(refcount {tracked[pb]}) — cached blocks are "
                        "immutable; this write would corrupt every future "
                        "prefix hit")
                decode_writes.add(pb)
        if self._chunk_plan is not None:
            slot, rid, start, length = self._chunk_plan
            req = t.slot_req[slot]
            if req is not None and req.rid == rid and length > 0:
                bs = t.block_size
                for lb in range(start // bs, (start + length - 1) // bs + 1):
                    pb = self._owned_entry(slot, rid, lb, start,
                                           kind="prefill-chunk write")
                    ref = tracked.get(pb)
                    if ref is not None and ref > 1:
                        self._violate(
                            f"slot {slot} (request {rid}) prefill chunk "
                            f"[{start}, {start + length}) writes shared "
                            f"prefix block {pb} (refcount {ref}) — "
                            "matched blocks are read-only; prefill must "
                            "start past the cached run")
                    if pb in decode_writes:
                        self._violate(
                            f"prefill-chunk/decode write overlap on block "
                            f"{pb}: slot {slot} (request {rid}) chunks "
                            "into a block another slot decodes into this "
                            "step")
        self.check_pool()
        self.checked_steps += 1

    # ------------------------------------------------------------------
    # pool-wide conservation scan
    # ------------------------------------------------------------------

    def check_pool(self) -> None:
        t = self.table
        alloc = t.allocator
        free = alloc._free_set
        tracked = t.prefix.refcounts if t.prefix is not None else {}
        lru = t.prefix.evictable_blocks if t.prefix is not None else {}
        holders: Dict[int, List[int]] = {}
        for slot in range(t.n_slots):
            n = int(t.n_alloc[slot])
            row = t.block_tables[slot, :n]
            for i, pb in enumerate(row.tolist()):
                if pb == 0:
                    self._violate(
                        f"slot {slot} maps the reserved scratch block 0 at "
                        f"table entry {i} inside its active region "
                        f"(n_alloc={n})")
                holders.setdefault(pb, []).append(slot)
                gen_held = int(t.block_gens[slot, i])
                gen_now = alloc.gen[pb]
                if gen_held != gen_now:
                    self._violate(
                        f"use-after-free: slot {slot} table entry {i} maps "
                        f"block {pb} at generation {gen_held} but the "
                        f"allocator is at generation {gen_now} — the block "
                        "was freed (and possibly reissued) while still "
                        "mapped")
        for pb, slots in holders.items():
            if pb in free:
                self._violate(
                    f"block {pb} is on the free list but still mapped by "
                    f"slot(s) {slots} — a free/realloc would alias two "
                    "requests onto one physical block")
            if len(slots) > 1 and pb not in tracked:
                self._violate(
                    f"block {pb} mapped writable into {len(slots)} slots "
                    f"({slots}) without a prefix-cache refcount — "
                    "write-aliasing between requests")
        # preemption-parked requests hold prefix references with no slot
        # table mapping them (the pin that keeps their prefix resident
        # across the park) — phantom holders for the drift check below
        pins: Dict[int, int] = {}
        for st in getattr(t, "_parked", {}).values():
            for pb in st.pinned:
                pins[pb] = pins.get(pb, 0) + 1
        for pb, ref in tracked.items():
            n_hold = len(holders.get(pb, ())) + pins.get(pb, 0)
            if pb in free:
                self._violate(
                    f"cache-tracked block {pb} (refcount {ref}) is on the "
                    "free list — eviction/release bookkeeping is corrupt")
            if ref != n_hold:
                self._violate(
                    f"refcount drift on cached block {pb}: refcount {ref} "
                    f"but {n_hold} holder(s) — slot table(s) "
                    f"{holders.get(pb, [])} + {pins.get(pb, 0)} parked "
                    "pin(s)")
            if ref == 0 and pb not in lru:
                self._violate(
                    f"cached block {pb} has refcount 0 but is not on the "
                    "LRU list — it can neither be evicted nor freed")
            if ref > 0 and pb in lru:
                self._violate(
                    f"cached block {pb} has refcount {ref} but sits on "
                    "the LRU list — pool pressure could evict a block a "
                    "live request still maps (the PR 4 aliasing bug)")
        leaked = [pb for pb in range(1, alloc.n_blocks)
                  if pb not in free and pb not in holders
                  and pb not in tracked]
        if leaked:
            self._violate(
                f"leaked block(s) {leaked}: not free, not mapped by any "
                "slot, not cache-tracked — lost to the pool until restart")
        self.owned_blocks = alloc.n_blocks - 1 - alloc.n_free

    # ------------------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        return {"sanitize_checked_steps": self.checked_steps,
                "sanitize_owned_blocks": self.owned_blocks,
                "sanitize_violations": self.violations}

    def _logical_block(self, pos: int) -> int:
        t = self.table
        if t.ring:
            return (pos % (t.nb_slot * t.block_size)) // t.block_size
        return pos // t.block_size

    def _owned_entry(self, slot: int, rid: int, lb: int, pos: int, *,
                     kind: str) -> int:
        t = self.table
        n = int(t.n_alloc[slot])
        if lb >= n:
            self._violate(
                f"slot {slot} (request {rid}) {kind} at position {pos} "
                f"needs logical block {lb} but the slot owns only {n} "
                "block(s) — the write would land outside its reservation")
        pb = int(t.block_tables[slot, lb])
        if pb == 0:
            self._violate(
                f"slot {slot} (request {rid}) {kind} at position {pos} "
                "resolves to the reserved scratch block 0 — the table row "
                "was masked or never reserved")
        return pb

    def _violate(self, msg: str) -> None:
        self.violations += 1
        raise PoolSanitizerError(f"PoolSanitizer: {msg}")
