"""repro-lint: AST static analysis specialized to this repo's JAX hot paths.

Generic linters know nothing about the two failure modes that have actually
bitten this codebase: host/device syncs hiding inside the serving step loop
(PR 6 shipped a greedy-argmax fix for exactly this) and silent retraces /
out-of-range Pallas block indices (PR 4's refcount-0 eviction aliasing was
the dynamic cousin).  repro-lint encodes those incidents as machine checks:

* ``host-sync``      — implicit truth-value / ``int()`` / ``float()`` /
  ``.item()`` / ``np.asarray`` coercion of traced arrays inside jit-traced
  functions, and implicit device syncs or eager ``jnp`` compute on the host
  hot path (the ``_SlotTable`` step loop and friends).
* ``retrace-hazard`` — Python-scalar derivation feeding array shapes,
  ``jax.jit`` applied inside loops / hot functions (fresh trace per call),
  unhashable (dict/list/set) static arguments.
* ``kernel-bounds``  — Pallas ``BlockSpec`` index maps whose components
  can't be shown in-range for the declared grid: unclamped index
  arithmetic, or table-resolved (scalar-prefetch) physical indices without
  a ``# repro: bounds`` annotation stating the out-of-band invariant.

Directives (comments scanned from raw source; a directive on a line of its
own also applies to the next line):

* ``# repro: allow-<rule>``  — waive findings of ``<rule>`` on this line.
* ``# repro: hot-path``      — mark the next ``def``/``class`` as a host
  hot path (scanned like the built-in ``_SlotTable`` family).
* ``# repro: jit``           — mark the next ``def`` as jit-traced even if
  no in-module ``jax.jit`` wraps it (e.g. jitted by a caller elsewhere).
* ``# repro: bounds <why>``  — assert an index-map bound that cannot be
  shown statically (kernel-bounds reads these).

Run ``python -m repro.analysis <paths>``; exits nonzero on any unwaived
finding.  Pure stdlib — no jax import, safe to run anywhere.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

RULES = ("host-sync", "retrace-hazard", "kernel-bounds")

# Classes whose methods form the serving host hot path: every method runs
# between device dispatches of the step loop, so an implicit sync or eager
# compute op here stalls the pipeline for all slots.
HOT_CLASSES = {"_SlotTable", "SlotServer", "MixtureSlotServer",
               "DecentralizedSlotServer"}

# jnp ops that launch device compute when called eagerly from host code.
# Constructors / uploads (asarray, zeros, arange, ...) are excluded: they
# are how host state legitimately enters the device.  ``split`` is excluded
# because admission-time pre-splitting of prefill chunks is a sanctioned
# pattern (the chunks are consumed over many later steps).
EAGER_OPS = {
    "argmax", "argmin", "argsort", "sort", "max", "min", "sum", "mean",
    "prod", "cumsum", "cumprod", "log", "exp", "sqrt", "tanh", "abs",
    "maximum", "minimum", "clip", "where", "stack", "concatenate",
    "take", "take_along_axis", "matmul", "dot", "einsum", "softmax",
    "any", "all", "power", "add", "subtract", "multiply", "divide",
}

# Host-coercion callables: calling one of these on a device value forces a
# blocking device->host transfer.
COERCION_BUILTINS = {"int", "float", "bool", "complex"}
COERCION_NP = {"asarray", "array"}          # np.asarray / np.array
COERCION_METHODS = {"item", "tolist"}       # x.item() / x.tolist()

# Shape-constructing jnp calls: a traced/tainted scalar flowing into one of
# these retraces (or errors) per distinct value.
SHAPE_CONSTRUCTORS = {"zeros", "ones", "full", "empty", "arange",
                      "broadcast_to", "tile", "linspace", "eye"}

_DIRECTIVE_RE = re.compile(r"#\s*repro:\s*(.+?)\s*$")


@dataclasses.dataclass
class Finding:
    rule: str
    path: str
    line: int
    col: int
    msg: str
    waived: bool = False

    def format(self) -> str:
        tag = " (waived)" if self.waived else ""
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}]{tag} " \
               f"{self.msg}"


class Directives:
    """``# repro:`` comment directives, parsed from raw source lines."""

    def __init__(self, lines: Sequence[str]):
        self.allow: Dict[int, Set[str]] = {}       # line -> waived rules
        self.marks: Dict[int, Set[str]] = {}       # line -> {hot-path, jit}
        self.bounds: Dict[int, str] = {}           # line -> annotation text
        for i, raw in enumerate(lines, start=1):
            m = _DIRECTIVE_RE.search(raw)
            if not m:
                continue
            body = m.group(1)
            word, _, rest = body.partition(" ")
            if word.startswith("allow-"):
                self.allow.setdefault(i, set()).add(word[len("allow-"):])
            elif word in ("hot-path", "jit"):
                self.marks.setdefault(i, set()).add(word)
            elif word == "bounds":
                self.bounds[i] = rest.strip()

    def waived(self, rule: str, line: int) -> bool:
        """A finding is waived by a directive on its line or the line
        directly above (comment-on-its-own-line style)."""
        for ln in (line, line - 1):
            if rule in self.allow.get(ln, ()):
                return True
        return False

    def marked(self, mark: str, node: ast.AST) -> bool:
        """``# repro: <mark>`` on the def/class line, a decorator line, or
        the line directly above the first of those."""
        first = min([node.lineno]
                    + [d.lineno for d in getattr(node, "decorator_list",
                                                 [])])
        for ln in range(first - 1, getattr(node, "body", [node])[0].lineno):
            if mark in self.marks.get(ln, ()):
                return True
        return False

    def bounds_in_span(self, lo: int, hi: int) -> List[str]:
        return [txt for ln, txt in self.bounds.items() if lo <= ln <= hi]


# ---------------------------------------------------------------------------
# expression predicates
# ---------------------------------------------------------------------------

def dotted(node: ast.AST) -> Optional[str]:
    """``jnp.argmax`` -> "jnp.argmax"; None for non-name chains."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_device_get(call: ast.Call) -> bool:
    name = dotted(call.func)
    return name in ("jax.device_get", "jax.block_until_ready")


def _device_op_root(name: Optional[str]) -> bool:
    if not name:
        return False
    return (name.startswith("jnp.") or name.startswith("jax.numpy.")
            or name.startswith("jax.lax.") or name.startswith("jax.nn.")
            or name.startswith("jax.random."))


def _eager_op_name(name: Optional[str]) -> Optional[str]:
    """The op if ``name`` is an eager device compute call (jnp.argmax...)."""
    if not name:
        return None
    for prefix in ("jnp.", "jax.numpy."):
        if name.startswith(prefix):
            op = name[len(prefix):]
            if op in EAGER_OPS:
                return op
    return None


#: Attribute accesses that are *static* under trace (and host ints/objects
#: eagerly) — a name reached only through these carries no device value.
STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}


def walk_opaque_device_get(node: ast.AST) -> Iterable[ast.AST]:
    """ast.walk, but do not descend into ``jax.device_get(...)`` calls
    (their results are host values — the sanctioned explicit sync) or
    static attribute accesses (``x.shape[0]`` of a device array is a host
    int, not a device value)."""
    stack = [node]
    while stack:
        n = stack.pop()
        yield n
        for child in ast.iter_child_nodes(n):
            if isinstance(child, ast.Call) and _is_device_get(child):
                continue
            if isinstance(child, ast.Attribute) and \
                    child.attr in STATIC_ATTRS:
                continue
            stack.append(child)


def expr_taint(node: ast.AST, tainted: Set[str]) -> Optional[str]:
    """Why this expression holds an eagerly-computed device value, or None.

    Sources: a ``jnp.<EAGER_OPS>`` call anywhere inside (not shadowed by a
    ``jax.device_get``), or a Name known to be tainted.
    """
    for n in walk_opaque_device_get(node):
        if isinstance(n, ast.Call):
            op = _eager_op_name(dotted(n.func))
            if op is not None:
                return f"jnp.{op}(...)"
        if isinstance(n, ast.Name) and n.id in tainted:
            return n.id
    return None


def _assign_targets(stmt: ast.stmt) -> List[str]:
    names: List[str] = []

    def collect(t: ast.AST) -> None:
        if isinstance(t, ast.Name):
            names.append(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                collect(e)

    if isinstance(stmt, ast.Assign):
        for t in stmt.targets:
            collect(t)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        collect(stmt.target)
    return names


def tainted_names(fn: ast.AST) -> Set[str]:
    """Names in ``fn`` assigned (transitively) from device-op calls.

    Assignment taints if the RHS contains any ``jnp.* / jax.lax.* /
    jax.nn.* / jax.random.*`` call outside a ``jax.device_get`` — a
    conservative 'this local lives on the device' marker.  Fixpoint over
    name-to-name propagation.
    """
    taint: Set[str] = set()
    stmts = [n for n in ast.walk(fn)
             if isinstance(n, (ast.Assign, ast.AugAssign, ast.AnnAssign))]
    for _ in range(4):                       # small fixpoint
        changed = False
        for stmt in stmts:
            value = stmt.value
            if value is None:
                continue
            hit = False
            for n in walk_opaque_device_get(value):
                if isinstance(n, ast.Call) and \
                        _device_op_root(dotted(n.func)):
                    hit = True
                    break
                if isinstance(n, ast.Name) and n.id in taint:
                    hit = True
                    break
            if hit:
                for name in _assign_targets(stmt):
                    if name not in taint:
                        taint.add(name)
                        changed = True
        if not changed:
            break
    return taint


# ---------------------------------------------------------------------------
# module context: parse once, index jit-traced + hot-path functions
# ---------------------------------------------------------------------------

FuncNode = (ast.FunctionDef, ast.AsyncFunctionDef)


class ModuleCtx:
    def __init__(self, path: Path, src: str):
        self.path = str(path)
        self.src = src
        self.lines = src.splitlines()
        self.tree = ast.parse(src, filename=self.path)
        self.directives = Directives(self.lines)
        self.parent: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self.parent[child] = node
        self.funcs: List[ast.AST] = [
            n for n in ast.walk(self.tree)
            if isinstance(n, FuncNode + (ast.Lambda,))]
        self._defs_by_name: Dict[str, List[ast.AST]] = {}
        for n in self.funcs:
            if isinstance(n, FuncNode):
                self._defs_by_name.setdefault(n.name, []).append(n)
        self.jit_traced: Set[ast.AST] = self._find_jit_traced()
        self.hot: Set[ast.AST] = self._find_hot()

    # -- indexing ----------------------------------------------------------

    def _jit_decorated(self, fn: ast.AST) -> bool:
        for dec in getattr(fn, "decorator_list", []):
            name = dotted(dec)
            if name in ("jax.jit", "jit"):
                return True
            if isinstance(dec, ast.Call):
                cname = dotted(dec.func)
                if cname in ("jax.jit", "jit"):
                    return True
                if cname in ("partial", "functools.partial") and dec.args \
                        and dotted(dec.args[0]) in ("jax.jit", "jit"):
                    return True
        return False

    def _find_jit_traced(self) -> Set[ast.AST]:
        traced: Set[ast.AST] = set()
        for fn in self.funcs:
            if self._jit_decorated(fn):
                traced.add(fn)
            elif isinstance(fn, FuncNode) and \
                    self.directives.marked("jit", fn):
                traced.add(fn)
        # jax.jit(<name>) / jax.jit(<lambda>) call sites
        for node in ast.walk(self.tree):
            if not (isinstance(node, ast.Call)
                    and dotted(node.func) in ("jax.jit", "jit")
                    and node.args):
                continue
            target = node.args[0]
            if isinstance(target, ast.Lambda):
                traced.add(target)
            elif isinstance(target, ast.Name):
                traced.update(self._defs_by_name.get(target.id, ()))
            elif isinstance(target, ast.Call):   # jax.jit(partial(f, ...))
                inner = dotted(target.func)
                if inner in ("partial", "functools.partial") and \
                        target.args and isinstance(target.args[0], ast.Name):
                    traced.update(
                        self._defs_by_name.get(target.args[0].id, ()))
        # closure: helpers called by name from a traced function trace too
        for _ in range(8):
            grew = False
            for fn in list(traced):
                for n in ast.walk(fn):
                    if isinstance(n, ast.Call) and \
                            isinstance(n.func, ast.Name):
                        for callee in self._defs_by_name.get(n.func.id, ()):
                            if callee not in traced:
                                traced.add(callee)
                                grew = True
            if not grew:
                break
        return traced

    def _find_hot(self) -> Set[ast.AST]:
        hot: Set[ast.AST] = set()
        hot_classes = set(HOT_CLASSES)
        for node in ast.walk(self.tree):
            if isinstance(node, ast.ClassDef):
                bases = {dotted(b) for b in node.bases}
                if node.name in hot_classes or bases & hot_classes or \
                        self.directives.marked("hot-path", node):
                    hot_classes.add(node.name)
                    for item in node.body:
                        if isinstance(item, FuncNode):
                            hot.add(item)
        for fn in self.funcs:
            if isinstance(fn, FuncNode) and \
                    self.directives.marked("hot-path", fn):
                hot.add(fn)
        # nested defs / lambdas inside a hot function run on the hot path
        for _ in range(8):
            grew = False
            for fn in self.funcs:
                if fn in hot:
                    continue
                p = self.parent.get(fn)
                while p is not None:
                    if p in hot:
                        hot.add(fn)
                        grew = True
                        break
                    p = self.parent.get(p)
            if not grew:
                break
        return hot

    # -- helpers for rules -------------------------------------------------

    def enclosing_function(self, node: ast.AST) -> Optional[ast.AST]:
        p = self.parent.get(node)
        while p is not None:
            if isinstance(p, FuncNode + (ast.Lambda,)):
                return p
            p = self.parent.get(p)
        return None

    def own_statements(self, fn: ast.AST) -> Iterable[ast.AST]:
        """Walk ``fn`` without descending into nested def/lambda bodies
        (those are scanned as their own functions)."""
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        stack: List[ast.AST] = list(body)
        while stack:
            n = stack.pop()
            yield n
            for child in ast.iter_child_nodes(n):
                if isinstance(child, FuncNode + (ast.Lambda,)):
                    continue
                stack.append(child)

    def in_loop(self, node: ast.AST) -> bool:
        p = self.parent.get(node)
        while p is not None and not isinstance(p, FuncNode + (ast.Lambda,)):
            if isinstance(p, (ast.For, ast.While)):
                return True
            p = self.parent.get(p)
        return False


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

def iter_py(paths: Sequence[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            out.append(path)
    return out


def run_paths(paths: Sequence[str],
              rules: Optional[Sequence[str]] = None) -> List[Finding]:
    from repro.analysis.rules import RULE_CHECKS
    selected = [(name, fn) for name, fn in RULE_CHECKS.items()
                if rules is None or name in rules]
    findings: List[Finding] = []
    for path in iter_py(paths):
        try:
            src = path.read_text()
            ctx = ModuleCtx(path, src)
        except (SyntaxError, UnicodeDecodeError) as e:
            findings.append(Finding("parse", str(path), 1, 0,
                                    f"could not parse: {e}"))
            continue
        for name, check in selected:
            for f in check(ctx):
                f.waived = ctx.directives.waived(f.rule, f.line)
                findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def main(argv: Sequence[str]) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint: JAX hot-path static analysis "
                    "(host-sync, retrace-hazard, kernel-bounds)")
    ap.add_argument("paths", nargs="+", help="files or directories")
    ap.add_argument("--rule", action="append", choices=RULES, default=None,
                    help="restrict to one rule (repeatable)")
    ap.add_argument("--show-waived", action="store_true",
                    help="also print waived findings")
    args = ap.parse_args(argv)

    findings = run_paths(args.paths, rules=args.rule)
    unwaived = [f for f in findings if not f.waived]
    waived = [f for f in findings if f.waived]
    for f in findings:
        if not f.waived or args.show_waived:
            print(f.format())
    print(f"repro-lint: {len(unwaived)} finding(s), "
          f"{len(waived)} waived")
    return 1 if unwaived else 0
