"""Correctness tooling for the serving stack.

* ``repro.analysis.lint`` — repro-lint, AST static analysis of the JAX
  hot paths (rules: host-sync, retrace-hazard, kernel-bounds).  Run via
  ``python -m repro.analysis <paths>``.
* ``repro.analysis.sanitizer`` — PoolSanitizer, the debug-mode dynamic
  checker that shadows the paged KV pool (enable with
  ``EngineConfig(sanitize=True)`` / ``--sanitize``).

See docs/analysis.md for the rule catalog and the incidents behind it.
"""
from repro.analysis.sanitizer import PoolSanitizer, PoolSanitizerError

__all__ = ["PoolSanitizer", "PoolSanitizerError"]
