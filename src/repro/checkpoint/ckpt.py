"""Per-expert checkpointing (npz-based; orbax is not available offline).

The paper's fault-isolation claim: each expert checkpoints *independently*
— one expert's node failure never forces a global restart. Layout:

    <dir>/expert_<k>/step_<n>.npz      (params + optimizer state + step)
    <dir>/router.npz                    (centroids — the parameter-free router)
"""
from __future__ import annotations

import os
import re
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


# Empty containers flatten to a zero-length marker entry (``__E<tag>``) so
# the pytree STRUCTURE survives the roundtrip — without it ``_flatten``
# emitted nothing for them and ``load(save(tree))`` silently changed the
# tree's structure (e.g. an optimizer state with an empty extra-args dict).
# Factories, not instances: each load must get FRESH containers, or every
# empty dict/list in every loaded tree would alias one mutable global.
_EMPTY_FACTORIES = {"__ED": dict, "__EL": list, "__ET": tuple}


def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        if not tree:
            out[f"{prefix}__ED"] = np.zeros(0, np.int8)
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)):
        tag = "T" if isinstance(tree, tuple) else "L"
        if not tree:
            out[f"{prefix}__E{tag}"] = np.zeros(0, np.int8)
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}__{tag}{i}/"))
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]):
    tree: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def rebuild(node):
        if not isinstance(node, dict):
            return jnp.asarray(node)
        keys = list(node.keys())
        if len(keys) == 1 and keys[0] in _EMPTY_FACTORIES:
            return _EMPTY_FACTORIES[keys[0]]()
        if keys and all(re.fullmatch(r"__[TL]\d+", k) for k in keys):
            items = sorted(keys, key=lambda k: int(k[3:]))
            seq = [rebuild(node[k]) for k in items]
            return tuple(seq) if keys[0][2] == "T" else list(seq)
        return {k: rebuild(v) for k, v in node.items()}

    return rebuild(tree)


def save(path: str, tree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(jax.device_get(tree))
    np.savez(path, **flat)


def load(path: str):
    with np.load(path, allow_pickle=False) as data:
        return _unflatten({k: data[k] for k in data.files})


def expert_dir(base: str, expert: int) -> str:
    return os.path.join(base, f"expert_{expert}")


def save_expert(base: str, expert: int, step: int, state) -> str:
    path = os.path.join(expert_dir(base, expert), f"step_{step}.npz")
    save(path, state)
    return path


def latest_step(base: str, expert: int) -> Optional[int]:
    d = expert_dir(base, expert)
    if not os.path.isdir(d):
        return None
    steps = [int(m.group(1)) for f in os.listdir(d)
             if (m := re.fullmatch(r"step_(\d+)\.npz", f))]
    return max(steps) if steps else None


def restore_expert(base: str, expert: int,
                   step: Optional[int] = None):
    step = latest_step(base, expert) if step is None else step
    if step is None:
        return None, None
    path = os.path.join(expert_dir(base, expert), f"step_{step}.npz")
    return load(path), step


def save_router(base: str, centroids: np.ndarray,
                temperature: float, top_k: int) -> None:
    os.makedirs(base, exist_ok=True)
    np.savez(os.path.join(base, "router.npz"), centroids=centroids,
             temperature=np.float64(temperature), top_k=np.int64(top_k))


def load_router(base: str):
    with np.load(os.path.join(base, "router.npz")) as d:
        return d["centroids"], float(d["temperature"]), int(d["top_k"])
