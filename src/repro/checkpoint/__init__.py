from .ckpt import (latest_step, load, load_router, restore_expert, save,
                   save_expert, save_router)
