"""Fused centroid-router kernel (Pallas): L2-normalize features and
centroids → cosine similarity matmul → temperature softmax (Eq. 28).

This sits on the critical path of every serving request (the paper's
"routing incurs almost zero overhead" claim assumes it is fused with the
frontend). Grid = (feature_blocks,); the full centroid matrix (K ≤ a few
hundred, D ≤ a few K) lives in VMEM; the feature block rides the MXU with
the lane dim = D padded to 128 by the caller (ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jnp.ndarray


def _router_kernel(x_ref, c_ref, o_ref, *, temperature: float):
    x = x_ref[...].astype(jnp.float32)                 # (bb, D)
    c = c_ref[...].astype(jnp.float32)                 # (K, D)
    xn = x * jax.lax.rsqrt(jnp.maximum((x * x).sum(-1, keepdims=True), 1e-24))
    cn = c * jax.lax.rsqrt(jnp.maximum((c * c).sum(-1, keepdims=True), 1e-24))
    sims = jax.lax.dot_general(xn, cn, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    z = temperature * sims
    z = z - jnp.max(z, axis=-1, keepdims=True)
    e = jnp.exp(z)
    o_ref[...] = (e / e.sum(-1, keepdims=True)).astype(o_ref.dtype)


def router_scores(x: Array, centroids: Array, temperature: float, *,
                  block_b: int = 256, interpret: bool = False) -> Array:
    """x: (B, D); centroids: (K, D) → routing probabilities (B, K)."""
    B, D = x.shape
    K = centroids.shape[0]
    block_b = min(block_b, B)
    pad_b = (-B) % block_b
    if pad_b:
        x = jnp.pad(x, [(0, pad_b), (0, 0)], constant_values=1.0)
    nb = (B + pad_b) // block_b

    kernel = functools.partial(_router_kernel, temperature=temperature)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_b, D), lambda i: (i, 0)),
            pl.BlockSpec((K, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, K), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B + pad_b, K), x.dtype),
        interpret=interpret,
    )(x, centroids)
    return out[:B]
