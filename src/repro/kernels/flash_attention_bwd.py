"""Blocked flash-attention BACKWARD kernels (Pallas TPU).

Standard two-pass formulation from the saved row log-sum-exp:

    p   = exp(q·kᵀ·scale − lse)            (recomputed blockwise, never HBM)
    dv  = pᵀ · do
    ds  = p ⊙ (do·vᵀ − Δ),  Δ = rowsum(do ⊙ o)
    dk  = dsᵀ · q · scale
    dq  = ds · k · scale

Two kernels: ``_dq_kernel`` (grid B×H×nq, accumulating over kv blocks on the
minor axis) and ``_dkv_kernel`` (grid B×H×nk, accumulating over q blocks).
Both produce per-*query*-head dk/dv; the GQA reduction over the group
(H → KV heads) is a cheap jnp sum outside. VMEM working set per step:
4–5 tiles of (block, dh) + one (block_q, block_k) score tile — ≈3 MB at
128×128×128 f32, comfortably under the ~16 MB budget.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jnp.ndarray
NEG_INF = -1e30


def _mask(rows, cols, causal: bool, window: int):
    if not causal:
        return None
    m = rows >= cols
    if window > 0:
        m &= (rows - cols) < window
    return m


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc, *, scale: float, block_q: int, block_k: int,
               causal: bool, window: int):
    qi, ki = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    q = q_ref[0, :, 0, :].astype(jnp.float32)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    do = do_ref[0, :, 0, :].astype(jnp.float32)
    lse = lse_ref[0, :, 0].astype(jnp.float32)
    delta = delta_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    m = _mask(rows, cols, causal, window)
    if m is not None:
        s = jnp.where(m, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None])
    acc[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _done():
        dq_ref[0, :, 0, :] = (acc[...] * scale).astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *, scale: float,
                block_q: int, block_k: int, causal: bool, window: int):
    ki, qi = pl.program_id(2), pl.program_id(3)
    nq = pl.num_programs(3)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0, :, 0, :].astype(jnp.float32)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    do = do_ref[0, :, 0, :].astype(jnp.float32)
    lse = lse_ref[0, :, 0].astype(jnp.float32)
    delta = delta_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    m = _mask(rows, cols, causal, window)
    if m is not None:
        s = jnp.where(m, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])                       # (bq, bk)
    dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None])
    dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)

    @pl.when(qi == nq - 1)
    def _done():
        dk_ref[0, :, 0, :] = (dk_acc[...] * scale).astype(dk_ref.dtype)
        dv_ref[0, :, 0, :] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd(q: Array, k: Array, v: Array, out: Array,
                        lse: Array, do: Array, *, causal: bool = True,
                        window: int = 0, block_q: int = 128,
                        block_k: int = 128, interpret: bool = False
                        ) -> Tuple[Array, Array, Array]:
    """q,do,out: (B,S,H,dh); k,v: (B,S,KV,dh); lse: (B,S,H) →
    (dq (B,S,H,dh), dk (B,S,KV,dh), dv (B,S,KV,dh))."""
    B, S, H, dh = q.shape
    KV = k.shape[2]
    group = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / (dh ** 0.5)
    delta = jnp.einsum("bshd,bshd->bsh", do.astype(jnp.float32),
                       out.astype(jnp.float32))          # Δ (B,S,H)

    q_spec = pl.BlockSpec((1, block_q, 1, dh),
                          lambda b, h, i, j: (b, i, h, 0))
    kv_spec = pl.BlockSpec((1, block_k, 1, dh),
                           lambda b, h, i, j, g=group: (b, j, h // g, 0))
    row_spec = pl.BlockSpec((1, block_q, 1), lambda b, h, i, j: (b, i, h))

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, causal=causal, window=window),
        grid=(B, H, nq, nk),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
        out_specs=pl.BlockSpec((1, block_q, 1, dh),
                               lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, dh), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # dkv: iterate q blocks on the minor axis for a fixed k/v block
    q_spec2 = pl.BlockSpec((1, block_q, 1, dh),
                           lambda b, h, j, i: (b, i, h, 0))
    kv_spec2 = pl.BlockSpec((1, block_k, 1, dh),
                            lambda b, h, j, i, g=group: (b, j, h // g, 0))
    row_spec2 = pl.BlockSpec((1, block_q, 1), lambda b, h, j, i: (b, i, h))
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, causal=causal, window=window),
        grid=(B, H, nk, nq),
        in_specs=[q_spec2, kv_spec2, kv_spec2, q_spec2, row_spec2,
                  row_spec2],
        out_specs=[
            pl.BlockSpec((1, block_k, 1, dh), lambda b, h, j, i: (b, j, h, 0)),
            pl.BlockSpec((1, block_k, 1, dh), lambda b, h, j, i: (b, j, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, dh), k.dtype),
            jax.ShapeDtypeStruct((B, S, H, dh), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((block_k, dh), jnp.float32),
                        pltpu.VMEM((block_k, dh), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # GQA: reduce per-query-head dk/dv over each KV head's group
    dk = dk_h.reshape(B, S, KV, group, dh).sum(3)
    dv = dv_h.reshape(B, S, KV, group, dh).sum(3)
    return dq, dk, dv
