"""Flash attention for TPU (Pallas): blocked online-softmax over KV blocks,
GQA-aware, causal/sliding-window masking.

Tiling: grid = (batch, heads, q_blocks, kv_blocks); the kv axis is the
minor-most (sequential on TPU), carrying the online-softmax state
(m, l, acc) in VMEM scratch. Query/key blocks are MXU-aligned (128) when
the sequence allows. GQA: the key/value BlockSpec index map folds each
query head onto its KV head (h // group) — no materialized repeat.

VMEM working set per step: q(bq·dh) + k,v(bk·dh) + acc(bq·dh) + scores
(bq·bk), all f32 in scratch — ≤ ~2.5 MB at bq=bk=256, dh=128, far under
the ~16 MB/core budget, leaving room for double-buffered pipelines.
"""
from __future__ import annotations

import functools
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jnp.ndarray
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr,
                  acc_scr, *, scale: float, block_q: int, block_k: int,
                  causal: bool, window: int):
    qi, ki = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, dh)
    v = v_ref[0, :, 0, :].astype(jnp.float32)          # (bk, dv)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    if causal:
        mask = rows >= cols
        if window > 0:
            mask &= (rows - cols) < window
        s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                             # (bq, bk)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _done():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)
        # log-sum-exp per query row — the residual the backward pass needs
        lse_ref[0, :, 0] = (m_scr[...] +
                            jnp.log(jnp.maximum(l_scr[...], 1e-30)))[:, 0]


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int = 0, block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> Array:
    out, _ = flash_attention_with_lse(q, k, v, causal=causal, window=window,
                                      block_q=block_q, block_k=block_k,
                                      interpret=interpret)
    return out


def flash_attention_with_lse(q: Array, k: Array, v: Array, *,
                             causal: bool = True, window: int = 0,
                             block_q: int = 128, block_k: int = 128,
                             interpret: bool = False):
    """q: (B,S,H,dh); k,v: (B,S,KV,dh), H % KV == 0 →
    (out (B,S,H,dh), lse (B,S,H) f32)."""
    B, S, H, dh = q.shape
    KV = k.shape[2]
    assert H % KV == 0
    group = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / (dh ** 0.5)

    kernel = functools.partial(_flash_kernel, scale=scale, block_q=block_q,
                               block_k=block_k, causal=causal, window=window)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, dh),
                         lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, dh),
                         lambda b, h, qi, ki, g=group: (b, ki, h // g, 0)),
            pl.BlockSpec((1, block_k, 1, dh),
                         lambda b, h, qi, ki, g=group: (b, ki, h // g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, 1, dh),
                         lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_q, 1),
                         lambda b, h, qi, ki: (b, qi, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((B, S, H), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
