"""Intra-chunk linear-attention kernel (Pallas) — the MXU-heavy inner part
of the chunkwise mLSTM / Mamba2-SSD scan (repro/models/ssm.py). Computes,
per (batch, chunk, head):

    intra[t]  = Σ_{s≤t} exp(cum_t − cum_s) · (q_t·k_s) · v_s      (L×L matmuls)
    chunk_kv  = Σ_s exp(cum_L − cum_s) · k_s v_sᵀ                 (dk×dv matmul)

The O(S)-state inter-chunk carry stays a lax.scan in the caller (it is a
latency chain, not a throughput problem). Grid = (B, NC, H); one chunk of
one head per step: L×dk, L×dv tiles in VMEM (L = 256 → all MXU-aligned).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jnp.ndarray


def _chunk_kernel(q_ref, k_ref, v_ref, cum_ref, intra_ref, kv_ref, *,
                  chunk: int):
    q = q_ref[0, 0, :, 0, :].astype(jnp.float32)       # (L, dk)
    k = k_ref[0, 0, :, 0, :].astype(jnp.float32)       # (L, dk)
    v = v_ref[0, 0, :, 0, :].astype(jnp.float32)       # (L, dv)
    cum = cum_ref[0, 0, :, 0].astype(jnp.float32)      # (L,)

    # decay matrix D[t, s] = exp(cum_t − cum_s) on the lower triangle
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = cum[:, None] - cum[None, :]
    D = jnp.exp(jnp.where(rows >= cols, decay, -jnp.inf))

    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    intra = jax.lax.dot_general(scores * D, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    intra_ref[0, 0, :, 0, :] = intra

    total = cum[-1]
    k_dec = k * jnp.exp(total - cum)[:, None]
    kv = jax.lax.dot_general(k_dec, v, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    kv_ref[0, 0, 0, :, :] = kv


def chunk_scan(qc: Array, kc: Array, vc: Array,
               cum: Array, *, interpret: bool = False
               ) -> Tuple[Array, Array]:
    """qc,kc: (B,NC,L,H,dk); vc: (B,NC,L,H,dv); cum: (B,NC,L,H) f32.
    Returns (intra (B,NC,L,H,dv) f32, chunk_kv (B,NC,H,dk,dv) f32)."""
    B, NC, L, H, dk = qc.shape
    dv = vc.shape[-1]
    kernel = functools.partial(_chunk_kernel, chunk=L)
    return pl.pallas_call(
        kernel,
        grid=(B, NC, H),
        in_specs=[
            pl.BlockSpec((1, 1, L, 1, dk), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, L, 1, dk), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, L, 1, dv), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda b, c, h: (b, c, 0, h)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, 1, dv), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, 1, dk, dv), lambda b, c, h: (b, c, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, NC, L, H, dv), jnp.float32),
            jax.ShapeDtypeStruct((B, NC, H, dk, dv), jnp.float32),
        ],
        interpret=interpret,
    )(qc, kc, vc, cum.astype(jnp.float32))
