"""Single-token GQA decode attention (Pallas): one query vector per head
attends over the KV cache in blocks, online-softmax carried in scratch.

Grid = (batch, kv_heads, kv_blocks). All ``group = H/KV`` query heads that
share a KV head are processed together as a (group, dh) tile — the natural
GQA layout on the MXU (the group dim rides the sublane axis). Position
masking (including the ring-buffer validity rule for sliding-window caches)
is computed from a prefetched per-batch position scalar.

Two cache layouts share the kernel body:

* contiguous — K/V are (B, S, KV, dh) slot rows, the ki-th grid step reads
  the ki-th sequence block of row b directly;
* paged — K/V live in a shared (P, block, KV, dh) block pool and the ki-th
  grid step reads physical block ``block_tables[b, ki]``: the per-slot
  block table is a scalar-prefetch operand, so the index map resolves the
  indirection at DMA-issue time and the body never sees it (the classic
  paged-attention gather). Unallocated table entries point at the reserved
  scratch block 0 and are killed by the position mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jnp.ndarray
NEG_INF = -1e30


def _accum_block(q_ref, k_ref, v_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, block: int, ki, pos, window: int,
                 s_cache: int):
    """Online-softmax accumulation of one KV block — the single source of
    the masking fence and the m/l/acc rescaling recurrence, shared by the
    contiguous and paged kernels so their numerics can never diverge."""
    q = q_ref[0, 0, :, :].astype(jnp.float32)          # (group, dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # (block, dh)
    v = v_ref[0, :, 0, :].astype(jnp.float32)          # (block, dv)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    idx = ki * block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    if window > 0:
        valid = (idx <= pos) | (pos >= s_cache)        # ring buffer
    else:
        valid = idx <= pos
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   scale: float, block_k: int, window: int, s_cache: int):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    _accum_block(q_ref, k_ref, v_ref, m_scr, l_scr, acc_scr, scale=scale,
                 block=block_k, ki=ki, pos=pos_ref[0], window=window,
                 s_cache=s_cache)

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0, 0, :, :] = (acc_scr[...] /
                             jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(q: Array, k: Array, v: Array, pos: Array, *,
                     window: int = 0, block_k: int = 256,
                     interpret: bool = False) -> Array:
    """q: (B,H,dh); k,v: (B,S,KV,dh); pos: (B,) int32 → (B,H,dh)."""
    B, H, dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    assert H % KV == 0
    group = H // KV
    block_k = min(block_k, S)
    assert S % block_k == 0, (S, block_k)
    nk = S // block_k
    scale = 1.0 / (dh ** 0.5)
    # regroup query heads by their KV head: (B, KV, group, dh)
    qg = q.reshape(B, KV, group, dh)

    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                               window=window, s_cache=S)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ki: (b,)),            # pos
            pl.BlockSpec((1, 1, group, dh),
                         lambda b, h, ki: (b, h, 0, 0)),          # q
            pl.BlockSpec((1, block_k, 1, dh),
                         lambda b, h, ki: (b, ki, h, 0)),         # k
            pl.BlockSpec((1, block_k, 1, dh),
                         lambda b, h, ki: (b, ki, h, 0)),         # v
        ],
        out_specs=pl.BlockSpec((1, 1, group, dh),
                               lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, group, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, dh), jnp.float32),
        ],
        interpret=interpret,
    )(pos.astype(jnp.int32), qg, k, v)
    return out.reshape(B, H, dh)


def _paged_decode_kernel(pos_ref, bt_ref, q_ref, *refs,
                         scale: float, block: int, window: int, s_log: int,
                         bps: int, nb: int):
    """Same online-softmax body as ``_decode_kernel``; the physical-block
    indirection already happened in the index maps, so the logical block
    index ``ki`` drives the masking rules unchanged.

    One grid step processes ``bps`` logical blocks: the j-th sub-tile is a
    separate kernel operand whose index map fetched logical block
    ``kc·bps + j`` — clamped to the slot's horizon block ``pos // block``
    (windowless caches), so past-the-horizon sub-tiles re-fetch the
    horizon block and the revisit rule elides their DMAs entirely; the
    body then skips them via ``live``. Sub-tiles accumulate in ascending
    ``ki`` order, so the m/l/acc recurrence is bit-identical to bps=1.
    """
    k_refs, v_refs = refs[:bps], refs[bps:2 * bps]
    o_ref = refs[2 * bps]
    m_scr, l_scr, acc_scr = refs[2 * bps + 1:]
    b = pl.program_id(0)
    kc = pl.program_id(2)
    nkc = pl.num_programs(2)

    @pl.when(kc == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[b]
    for j in range(bps):
        ki = kc * bps + j
        # a logical block is dead when every one of its positions is
        # masked; skipping it saves the two MXU dots (and, clamped, its
        # DMA). The ``ki < nb`` guard kills the padded tail when bps does
        # not divide nb (its clamped fetch aliases a live block).
        live = ((ki * block <= pos) if window <= 0
                else ((ki * block <= pos) | (pos >= s_log))) & (ki < nb)

        @pl.when(live)
        def _accum(j=j, ki=ki):
            _accum_block(q_ref, k_refs[j], v_refs[j], m_scr, l_scr,
                         acc_scr, scale=scale, block=block, ki=ki, pos=pos,
                         window=window, s_cache=s_log)

    @pl.when(kc == nkc - 1)
    def _done():
        o_ref[0, 0, :, :] = (acc_scr[...] /
                             jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def _chunk_prefill_kernel(start_ref, bt_ref, q_ref, *refs,
                          scale: float, block: int, group: int, C: int,
                          bps: int, nb: int):
    """Prefix-aware chunked-prefill flash attention over PAGED blocks.

    Rows are the chunk's (c, group) query pairs flattened c-major; row r is
    the query at absolute position ``start + r // group``. ``ki`` is the
    LOGICAL block index — the physical indirection already happened in the
    index maps (scalar-prefetched block table), exactly like the paged
    decode kernel, with the same ``blocks_per_step`` sub-tiling (the
    horizon here is the last query position's block). The chunk's own K/V
    were scattered into the pool before the call, so the single fence
    ``key position ≤ query position`` covers both the prefix and
    within-chunk causality.
    """
    k_refs, v_refs = refs[:bps], refs[bps:2 * bps]
    o_ref = refs[2 * bps]
    m_scr, l_scr, acc_scr = refs[2 * bps + 1:]
    kc = pl.program_id(1)
    nkc = pl.num_programs(1)

    @pl.when(kc == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    start = start_ref[0]
    for j in range(bps):
        ki = kc * bps + j
        # blocks entirely above the last query position are dead for
        # every row; the ``ki < nb`` guard kills the padded tail
        live = (ki * block <= start + (C - 1)) & (ki < nb)

        @pl.when(live)
        def _accum(j=j, ki=ki):
            q = q_ref[0, :, :].astype(jnp.float32)       # (C·group, dh)
            k = k_refs[j][0, :, 0, :].astype(jnp.float32)  # (block, dh)
            v = v_refs[j][0, :, 0, :].astype(jnp.float32)
            s = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale
            rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // group
            cols = ki * block + \
                jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(cols <= start + rows, s, NEG_INF)
            m_prev = m_scr[...]
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
            alpha = jnp.exp(m_prev - m_new)
            p = jnp.exp(s - m_new)
            l_scr[...] = alpha * l_scr[...] + \
                jnp.sum(p, axis=-1, keepdims=True)
            acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
                p, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            m_scr[...] = m_new

    @pl.when(kc == nkc - 1)
    def _done():
        o_ref[0, :, :] = (acc_scr[...] /
                          jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def chunk_prefill_attention(q: Array, k_pool: Array, v_pool: Array,
                            start: Array, block_table: Array, *,
                            blocks_per_step: int = 1,
                            interpret: bool = False) -> Array:
    """q: (C,H,dh) one request's chunk queries; k_pool,v_pool:
    (P,block,KV,dh) with the chunk's K/V already scattered in; start: ()
    int32 absolute position of chunk row 0; block_table: (NB,) int32 →
    (C,H,dh).

    Grid = (kv_heads, ⌈NB / blocks_per_step⌉ logical-block groups);
    ``start`` and the block table are scalar-prefetch operands so the K/V
    index maps resolve the physical block at DMA-issue time. As in
    ``paged_decode_attention``, each of the ``blocks_per_step`` sub-tiles
    is its own operand whose index map clamps the fetched logical index to
    the chunk's horizon block ``(start + C - 1) // block`` — dead blocks
    alias the horizon block and the DMA revisit rule elides the fetch.
    Unallocated entries alias scratch block 0 and are killed by the
    position fence.
    """
    C, H, dh = q.shape
    block, KV = k_pool.shape[1], k_pool.shape[2]
    NB = block_table.shape[0]
    assert H % KV == 0
    group = H // KV
    scale = 1.0 / (dh ** 0.5)
    bps = max(1, min(blocks_per_step, NB))
    nkc = -(-NB // bps)
    # rows flattened c-major per KV head: (KV, C·group, dh)
    qg = jnp.transpose(q.reshape(C, KV, group, dh), (1, 0, 2, 3)) \
        .reshape(KV, C * group, dh)

    def kv_spec(j):
        def imap(h, kc, start_r, bt_r):
            # repro: bounds bt_r holds pool block ids < P (the pool's
            # leading dim) — the allocator only writes ids it owns and
            # masks unallocated table rows to the reserved scratch block
            # 0; ki is clamped to NB - 1 above, so bt_r[ki] never reads
            # past the table
            ki = jnp.minimum(jnp.minimum(kc * bps + j,
                                         (start_r[0] + C - 1) // block),
                             NB - 1)
            return (bt_r[ki], 0, h, 0)
        return pl.BlockSpec((1, block, 1, dh), imap)

    kernel = functools.partial(_chunk_prefill_kernel, scale=scale,
                               block=block, group=group, C=C,
                               bps=bps, nb=NB)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                        # start, block_table
        grid=(KV, nkc),
        in_specs=[
            pl.BlockSpec((1, C * group, dh),
                         lambda h, kc, start_r, bt_r: (h, 0, 0)),       # q
            *[kv_spec(j) for j in range(bps)],                          # k
            *[kv_spec(j) for j in range(bps)],                          # v
        ],
        out_specs=pl.BlockSpec((1, C * group, dh),
                               lambda h, kc, start_r, bt_r: (h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((C * group, 1), jnp.float32),
            pltpu.VMEM((C * group, 1), jnp.float32),
            pltpu.VMEM((C * group, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((KV, C * group, dh), q.dtype),
        interpret=interpret,
    )(jnp.asarray(start, jnp.int32).reshape(1),
      block_table.astype(jnp.int32), qg,
      *([k_pool] * bps), *([v_pool] * bps))
    return jnp.transpose(out.reshape(KV, C, group, dh),
                         (1, 0, 2, 3)).reshape(C, H, dh)


def _paged_verify_kernel(pos_ref, bt_ref, q_ref, *refs,
                         scale: float, block: int, group: int, L: int,
                         bps: int, nb: int):
    """Speculative span verify over PAGED blocks — the chunk-prefill body
    batched over slots.

    Rows are one slot's (ℓ, group) query pairs flattened ℓ-major; row r is
    the candidate at absolute position ``pos[b] + r // group``. ``ki`` is
    the LOGICAL block index — the physical indirection happened in the
    scalar-prefetched index maps, with the same ``blocks_per_step``
    sub-tiling as the paged decode kernel. The span's own K/V were
    scattered into the pool before the call, so the single fence
    ``key position ≤ pos + row offset`` covers the committed prefix AND
    within-span causality; rejected-tail keys at later offsets are hidden
    from every accepted row by the same rule.
    """
    k_refs, v_refs = refs[:bps], refs[bps:2 * bps]
    o_ref = refs[2 * bps]
    m_scr, l_scr, acc_scr = refs[2 * bps + 1:]
    b = pl.program_id(0)
    kc = pl.program_id(2)
    nkc = pl.num_programs(2)

    @pl.when(kc == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[b]
    for j in range(bps):
        ki = kc * bps + j
        # blocks entirely above the span's LAST position are dead for
        # every row; the ``ki < nb`` guard kills the padded tail
        live = (ki * block <= pos + (L - 1)) & (ki < nb)

        @pl.when(live)
        def _accum(j=j, ki=ki):
            q = q_ref[0, 0, :, :].astype(jnp.float32)    # (L·group, dh)
            k = k_refs[j][0, :, 0, :].astype(jnp.float32)  # (block, dh)
            v = v_refs[j][0, :, 0, :].astype(jnp.float32)
            s = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale
            rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // group
            cols = ki * block + \
                jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(cols <= pos + rows, s, NEG_INF)
            m_prev = m_scr[...]
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
            alpha = jnp.exp(m_prev - m_new)
            p = jnp.exp(s - m_new)
            l_scr[...] = alpha * l_scr[...] + \
                jnp.sum(p, axis=-1, keepdims=True)
            acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
                p, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            m_scr[...] = m_new

    @pl.when(kc == nkc - 1)
    def _done():
        o_ref[0, 0, :, :] = (acc_scr[...] /
                             jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_verify_attention(q: Array, k_pool: Array, v_pool: Array,
                           pos: Array, block_tables: Array, *,
                           blocks_per_step: int = 1,
                           interpret: bool = False) -> Array:
    """q: (B,L,H,dh) span queries (row ℓ of slot b sits at absolute
    position ``pos[b] + ℓ``, its K/V already scattered into the pool);
    k_pool,v_pool: (P,block,KV,dh); pos: (B,) int32; block_tables: (B,NB)
    int32 → (B,L,H,dh).

    Grid = (batch, kv_heads, ⌈NB / blocks_per_step⌉), one (L·group, dh)
    query tile per slot per KV head (span offsets ride the sublane axis
    next to the GQA group, exactly like the chunk-prefill kernel's rows).
    ``pos`` and the block tables are scalar-prefetch operands; each of the
    ``blocks_per_step`` K/V sub-tiles is its own operand whose index map
    clamps the fetched logical index to the span's horizon block
    ``(pos + L - 1) // block`` — dead blocks alias the horizon block and
    the DMA revisit rule elides the fetch. Sliding-window (ring) caches
    are not supported: the scheduler only routes windowless models here.
    """
    B, L, H, dh = q.shape
    P, block, KV = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    NB = block_tables.shape[1]
    assert H % KV == 0
    group = H // KV
    scale = 1.0 / (dh ** 0.5)
    bps = max(1, min(blocks_per_step, NB))
    nkc = -(-NB // bps)
    # rows flattened ℓ-major per slot per KV head: (B, KV, L·group, dh)
    qg = jnp.transpose(q.reshape(B, L, KV, group, dh), (0, 2, 1, 3, 4)) \
        .reshape(B, KV, L * group, dh)

    def kv_spec(j):
        def imap(b, h, kc, pos_r, bt_r):
            # repro: bounds bt_r holds pool block ids < P (the pool's
            # leading dim) — allocator invariant; ki is clamped to NB - 1,
            # so bt_r[b, ki] stays in-table
            ki = jnp.minimum(jnp.minimum(kc * bps + j,
                                         (pos_r[b] + L - 1) // block),
                             NB - 1)
            return (bt_r[b, ki], 0, h, 0)
        return pl.BlockSpec((1, block, 1, dh), imap)

    kernel = functools.partial(_paged_verify_kernel, scale=scale,
                               block=block, group=group, L=L,
                               bps=bps, nb=NB)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                        # pos, block_tables
        grid=(B, KV, nkc),
        in_specs=[
            pl.BlockSpec((1, 1, L * group, dh),
                         lambda b, h, kc, pos_r, bt_r: (b, h, 0, 0)),   # q
            *[kv_spec(j) for j in range(bps)],                          # k
            *[kv_spec(j) for j in range(bps)],                          # v
        ],
        out_specs=pl.BlockSpec((1, 1, L * group, dh),
                               lambda b, h, kc, pos_r, bt_r: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((L * group, 1), jnp.float32),
            pltpu.VMEM((L * group, 1), jnp.float32),
            pltpu.VMEM((L * group, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, L * group, dh), q.dtype),
        interpret=interpret,
    )(pos.astype(jnp.int32), block_tables.astype(jnp.int32), qg,
      *([k_pool] * bps), *([v_pool] * bps))
    return jnp.transpose(out.reshape(B, KV, L, group, dh),
                         (0, 2, 1, 3, 4)).reshape(B, L, H, dh)


def paged_decode_attention(q: Array, k_pool: Array, v_pool: Array,
                           pos: Array, block_tables: Array, *,
                           window: int = 0, blocks_per_step: int = 1,
                           interpret: bool = False) -> Array:
    """q: (B,H,dh); k_pool,v_pool: (P,block,KV,dh); pos: (B,) int32;
    block_tables: (B,NB) int32 → (B,H,dh).

    Grid = (batch, kv_heads, ⌈NB / blocks_per_step⌉). ``pos`` and the
    block table are scalar-prefetch operands: the K/V index maps pick the
    physical block out of the pool, so the gather happens in the DMA
    engine, not the kernel body — amortized over ``blocks_per_step``
    logical blocks per grid step (each sub-tile is its own operand with
    its own index map). Windowless maps clamp the fetched logical index to
    the slot's horizon block ``pos // block``: every past-the-horizon grid
    step re-fetches the horizon block, which the DMA revisit rule elides —
    dead blocks cost neither bandwidth nor MXU work, replacing the old
    fetch-then-mask scheme. ``window > 0`` applies the ring validity rule
    over the slot's logical span NB·block (= the ring size; the whole ring
    stays live once wrapped, so only the NB bound is clamped).
    """
    B, H, dh = q.shape
    P, block, KV = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    NB = block_tables.shape[1]
    assert H % KV == 0
    group = H // KV
    scale = 1.0 / (dh ** 0.5)
    bps = max(1, min(blocks_per_step, NB))
    nkc = -(-NB // bps)
    qg = q.reshape(B, KV, group, dh)

    def kv_spec(j):
        if window <= 0:
            def imap(b, h, kc, pos_r, bt_r):
                # repro: bounds bt_r holds pool block ids < P (the
                # pool's leading dim) — allocator invariant; ki is
                # clamped to NB - 1, so bt_r[b, ki] stays in-table
                ki = jnp.minimum(jnp.minimum(kc * bps + j,
                                             pos_r[b] // block), NB - 1)
                return (bt_r[b, ki], 0, h, 0)
        else:
            def imap(b, h, kc, pos_r, bt_r):
                # repro: bounds bt_r holds pool block ids < P (the
                # pool's leading dim) — allocator invariant; ki is
                # clamped to NB - 1, so bt_r[b, ki] stays in-table
                ki = jnp.minimum(kc * bps + j, NB - 1)
                return (bt_r[b, ki], 0, h, 0)
        return pl.BlockSpec((1, block, 1, dh), imap)

    kernel = functools.partial(_paged_decode_kernel, scale=scale,
                               block=block, window=window, s_log=NB * block,
                               bps=bps, nb=NB)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                        # pos, block_tables
        grid=(B, KV, nkc),
        in_specs=[
            pl.BlockSpec((1, 1, group, dh),
                         lambda b, h, kc, pos_r, bt_r: (b, h, 0, 0)),  # q
            *[kv_spec(j) for j in range(bps)],                         # k
            *[kv_spec(j) for j in range(bps)],                         # v
        ],
        out_specs=pl.BlockSpec((1, 1, group, dh),
                               lambda b, h, kc, pos_r, bt_r: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, group, dh), q.dtype),
        interpret=interpret,
    )(pos.astype(jnp.int32), block_tables.astype(jnp.int32), qg,
      *([k_pool] * bps), *([v_pool] * bps))
    return out.reshape(B, H, dh)
