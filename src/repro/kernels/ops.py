"""Public jit'd wrappers around the Pallas kernels.

On CPU (this container) every kernel runs in ``interpret=True`` — the kernel
body executes in Python per grid step, validating the exact TPU tiling logic
against the ref.py oracles. On a real TPU backend interpret=False compiles
to Mosaic.
"""
from __future__ import annotations

import functools

import jax

from . import chunk_scan as _chunk
from . import decode_attention as _decode
from . import flash_attention as _flash
from . import flash_attention_bwd as _flash_bwd_mod
from . import router_scores as _router


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_vjp(q, k, v, causal, window, block_q, block_k):
    return _flash.flash_attention(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=_interpret())


def _flash_fwd(q, k, v, causal, window, block_q, block_k):
    out, lse = _flash.flash_attention_with_lse(
        q, k, v, causal=causal, window=window, block_q=block_q,
        block_k=block_k, interpret=_interpret())
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, block_q, block_k, res, dout):
    """Blocked Pallas backward from the saved LSE (never materializes the
    S² matrix in HBM) — see kernels/flash_attention_bwd.py."""
    q, k, v, out, lse = res
    return _flash_bwd_mod.flash_attention_bwd(
        q, k, v, out, lse, dout, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interpret())


_flash_vjp.defvjp(_flash_fwd, _flash_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128):
    """Differentiable: forward runs the Pallas kernel; backward uses the
    saved-LSE flash gradient (custom_vjp)."""
    return _flash_vjp(q, k, v, causal, window, block_q, block_k)


@functools.partial(jax.jit, static_argnames=("window", "block_k"))
def decode_attention(q, k, v, pos, *, window: int = 0, block_k: int = 256):
    return _decode.decode_attention(q, k, v, pos, window=window,
                                    block_k=block_k, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("window", "blocks_per_step"))
def paged_decode_attention(q, k_pool, v_pool, pos, block_tables, *,
                           window: int = 0, blocks_per_step: int = 1):
    return _decode.paged_decode_attention(q, k_pool, v_pool, pos,
                                          block_tables, window=window,
                                          blocks_per_step=blocks_per_step,
                                          interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("blocks_per_step",))
def paged_verify_attention(q, k_pool, v_pool, pos, block_tables, *,
                           blocks_per_step: int = 1):
    return _decode.paged_verify_attention(q, k_pool, v_pool, pos,
                                          block_tables,
                                          blocks_per_step=blocks_per_step,
                                          interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("blocks_per_step",))
def chunk_prefill_attention(q, k_pool, v_pool, start, block_table, *,
                            blocks_per_step: int = 1):
    return _decode.chunk_prefill_attention(q, k_pool, v_pool, start,
                                           block_table,
                                           blocks_per_step=blocks_per_step,
                                           interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("temperature", "block_b"))
def router_scores(x, centroids, temperature: float, *, block_b: int = 256):
    return _router.router_scores(x, centroids, temperature, block_b=block_b,
                                 interpret=_interpret())


@jax.jit
def chunk_scan(qc, kc, vc, cum):
    return _chunk.chunk_scan(qc, kc, vc, cum, interpret=_interpret())
