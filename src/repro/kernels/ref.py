"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are *definitions*, not optimizations — O(S²) attention materializes
the full score matrix, etc. Kernel tests sweep shapes/dtypes and assert
against these.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray
NEG_INF = -1e30


def flash_attention_ref(q: Array, k: Array, v: Array, *, causal: bool = True,
                        window: int = 0) -> Array:
    """q: (B,S,H,dh); k,v: (B,S,KV,dh) with H % KV == 0 → (B,S,H,dh)."""
    B, S, H, dh = q.shape
    KV = k.shape[2]
    k = jnp.repeat(k, H // KV, axis=2)
    v = jnp.repeat(v, H // KV, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(jnp.float32(dh))
    if causal:
        i = jnp.arange(S)[:, None]
        j = jnp.arange(S)[None, :]
        m = j <= i
        if window > 0:
            m &= (i - j) < window
        logits = jnp.where(m[None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def decode_attention_ref(q: Array, k: Array, v: Array, pos: Array, *,
                         window: int = 0) -> Array:
    """q: (B,H,dh); k,v: (B,S,KV,dh); pos: (B,) → (B,H,dh).

    window > 0 means the cache is a ring buffer of size S: every slot is
    valid once pos ≥ S, otherwise only slots ≤ pos.
    """
    B, H, dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    kf = jnp.repeat(k, H // KV, axis=2)
    vf = jnp.repeat(v, H // KV, axis=2)
    logits = jnp.einsum("bhd,bkhd->bhk", q, kf).astype(jnp.float32)
    logits = logits / jnp.sqrt(jnp.float32(dh))
    idx = jnp.arange(S)[None, :]
    if window > 0:
        valid = (idx <= pos[:, None]) | (pos[:, None] >= S)
    else:
        valid = idx <= pos[:, None]
    logits = jnp.where(valid[:, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhk,bkhd->bhd", w, vf)


def paged_decode_attention_ref(q: Array, k_pool: Array, v_pool: Array,
                               pos: Array, block_tables: Array, *,
                               window: int = 0) -> Array:
    """q: (B,H,dh); k_pool,v_pool: (P,block,KV,dh); pos: (B,);
    block_tables: (B,NB) → (B,H,dh).

    Definitionally: gather each slot's logical KV span out of the block
    pool, then run the contiguous decode oracle over it. The slot's logical
    cache size is NB·block; ``window > 0`` applies the ring validity rule
    over that span.
    """
    B = q.shape[0]
    NB, block = block_tables.shape[1], k_pool.shape[1]
    k = k_pool[block_tables].reshape(B, NB * block, *k_pool.shape[2:])
    v = v_pool[block_tables].reshape(B, NB * block, *v_pool.shape[2:])
    return decode_attention_ref(q, k, v, pos,
                                window=NB * block if window > 0 else 0)


def chunk_prefill_attention_ref(q: Array, k_pool: Array, v_pool: Array,
                                start: Array, block_table: Array) -> Array:
    """q: (C,H,dh) chunk queries (row c at absolute position start + c);
    k_pool,v_pool: (P,block,KV,dh); block_table: (NB,) → (C,H,dh).

    Definitionally: gather the request's logical KV span out of the pool,
    then run the contiguous decode oracle treating the chunk rows as a
    batch of single queries at positions start..start+C-1.
    """
    C = q.shape[0]
    NB, block = block_table.shape[0], k_pool.shape[1]
    k = k_pool[block_table].reshape(NB * block, *k_pool.shape[2:])
    v = v_pool[block_table].reshape(NB * block, *v_pool.shape[2:])
    kb = jnp.broadcast_to(k[None], (C,) + k.shape)
    vb = jnp.broadcast_to(v[None], (C,) + v.shape)
    pos = start + jnp.arange(C)
    return decode_attention_ref(q, kb, vb, pos)


def router_scores_ref(x: Array, centroids: Array,
                      temperature: float) -> Array:
    """Fused Eq. 28: L2-normalize both → cosine sims → τ-softmax.
    x: (B, D); centroids: (K, D) → (B, K)."""
    xn = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
    cn = centroids / jnp.maximum(
        jnp.linalg.norm(centroids, axis=-1, keepdims=True), 1e-12)
    sims = xn @ cn.T
    return jax.nn.softmax(temperature * sims.astype(jnp.float32), axis=-1
                          ).astype(x.dtype)


def chunk_scan_ref(qc: Array, kc: Array, vc: Array,
                   cum: Array) -> Tuple[Array, Array]:
    """Intra-chunk linear attention + per-chunk KV summary.

    qc,kc: (B,NC,L,H,dk); vc: (B,NC,L,H,dv); cum: (B,NC,L,H) inclusive
    cumulative log-decay. Returns (intra (B,NC,L,H,dv) f32,
    chunk_kv (B,NC,H,dk,dv) f32).
    """
    L = qc.shape[2]
    qc, kc, vc = (a.astype(jnp.float32) for a in (qc, kc, vc))
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,NC,L,L,H)
    tri = jnp.tril(jnp.ones((L, L), bool))
    D = jnp.exp(jnp.where(tri[None, None, :, :, None], decay, -jnp.inf))
    scores = jnp.einsum("bclhd,bcmhd->bclmh", qc, kc)
    intra = jnp.einsum("bclmh,bcmhv->bclhv", scores * D, vc)
    total = cum[:, :, -1]
    k_dec = kc.astype(jnp.float32) * jnp.exp(total[:, :, None, :]
                                             - cum)[..., None]
    chunk_kv = jnp.einsum("bclhd,bclhv->bchdv", k_dec, vc.astype(jnp.float32))
    return intra, chunk_kv
