"""Sharded host data pipeline.

Each decentralized expert consumes ONLY its own shard (zero data exchange —
the paper's training-isolation property). Within an expert, batches are
sliced over the ``data`` mesh axis per host process (standard multi-host
feeding: every process materializes only its slice and forms a global array
with ``jax.make_array_from_process_local_data`` when running multi-host).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from .synthetic import SyntheticMultimodal


@dataclass
class LoaderConfig:
    batch_size: int = 32
    process_index: int = 0
    process_count: int = 1


class ShardLoader:
    """Infinite iterator over one expert's data shard."""

    def __init__(self, dataset: SyntheticMultimodal, cfg: LoaderConfig,
                 subset: Optional[np.ndarray] = None, offset: int = 0):
        assert cfg.batch_size % cfg.process_count == 0
        self.dataset, self.cfg, self.subset = dataset, cfg, subset
        self.offset = offset                       # step-space offset per expert
        self._step = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        batch = self.dataset.sample_batch(cfg.batch_size,
                                          self._step + self.offset,
                                          self.subset)
        self._step += 1
        if cfg.process_count > 1:                  # per-host slice
            per = cfg.batch_size // cfg.process_count
            lo = cfg.process_index * per
            batch = {k: v[lo:lo + per] for k, v in batch.items()}
        return batch


def expert_loaders(dataset: SyntheticMultimodal, shards, batch_size: int,
                   process_index: int = 0, process_count: int = 1):
    """One isolated loader per decentralized expert."""
    cfg = LoaderConfig(batch_size, process_index, process_count)
    return [ShardLoader(dataset, cfg, subset=s, offset=10_000 * k)
            for k, s in enumerate(shards)]
