"""Synthetic clustered multimodal data (the empirical substrate).

The paper's experiments need (image, text) pairs whose *visual* features
carry latent cluster structure and whose *text* distribution depends on the
cluster (so independent experts specialize and the ensemble's parity with a
dense model is measurable). Offline we synthesize exactly that:

* features: unit-norm Gaussian mixture with ``n_latent`` components (the
  stand-in for frozen CLIP embeddings — the allowed frontend stub);
* tokens: per-cluster first-order Markov chains over a shared vocab, with a
  cluster-specific transition matrix (mixture of a shared base chain and a
  cluster chain) — giving a measurable per-cluster NLL gap.

Everything is deterministic in the seed and generated lazily per batch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np


@dataclass(frozen=True)
class SyntheticConfig:
    vocab: int = 512
    seq_len: int = 64
    feature_dim: int = 32
    n_latent: int = 4            # ground-truth clusters
    cluster_sep: float = 4.0     # mixture separation in feature space
    mix: float = 0.75            # weight of the cluster-specific chain
    n_samples: int = 4_096
    seed: int = 0


class SyntheticMultimodal:
    """Deterministic synthetic corpus with latent cluster structure."""

    def __init__(self, cfg: SyntheticConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        K, V, D = cfg.n_latent, cfg.vocab, cfg.feature_dim
        self.centroids = rng.normal(size=(K, D)) * cfg.cluster_sep
        base = rng.dirichlet(np.ones(V) * 0.5, size=V)        # shared chain
        self.trans = np.empty((K, V, V))
        for k in range(K):
            spec = rng.dirichlet(np.ones(V) * 0.05, size=V)   # peaky per-k
            self.trans[k] = (1 - cfg.mix) * base + cfg.mix * spec
        self.init_probs = rng.dirichlet(np.ones(V), size=K)
        self.labels = rng.integers(0, K, size=cfg.n_samples)

    def features(self, idx: np.ndarray, rng: np.random.Generator) -> np.ndarray:
        f = self.centroids[self.labels[idx]] + \
            rng.normal(size=(len(idx), self.cfg.feature_dim))
        return f / np.linalg.norm(f, axis=1, keepdims=True)

    def tokens(self, idx: np.ndarray, rng: np.random.Generator) -> np.ndarray:
        cfg = self.cfg
        out = np.empty((len(idx), cfg.seq_len), dtype=np.int64)
        for row, i in enumerate(idx):
            k = self.labels[i]
            t = rng.choice(cfg.vocab, p=self.init_probs[k])
            out[row, 0] = t
            cum = self.trans[k].cumsum(axis=1)
            u = rng.random(cfg.seq_len - 1)
            for s in range(1, cfg.seq_len):
                t = int(np.searchsorted(cum[t], u[s - 1]))
                out[row, s] = t
        return out

    def sample_batch(self, batch: int, step: int,
                     subset: Optional[np.ndarray] = None) -> Dict[str, np.ndarray]:
        """Batch ``step`` from the (optionally partitioned) corpus."""
        rng = np.random.default_rng((self.cfg.seed, step))
        pool = subset if subset is not None else np.arange(self.cfg.n_samples)
        idx = pool[rng.integers(0, len(pool), size=batch)]
        toks = self.tokens(idx, rng)
        return {
            "tokens": toks.astype(np.int32),
            "labels": toks.astype(np.int32),
            "features": self.features(idx, rng).astype(np.float32),
            "cluster": self.labels[idx].astype(np.int32),
        }

    def all_features(self) -> np.ndarray:
        """Features of every unique sample — partitioning input (§5.1)."""
        rng = np.random.default_rng((self.cfg.seed, 0x7FFFFFFF))
        return self.features(np.arange(self.cfg.n_samples), rng)

    def oracle_nll(self, tokens: np.ndarray, k: int) -> float:
        """Exact NLL of sequences under cluster k's chain (eval oracle)."""
        nll = -np.log(self.init_probs[k][tokens[:, 0]] + 1e-12)
        for s in range(1, tokens.shape[1]):
            nll += -np.log(self.trans[k][tokens[:, s - 1], tokens[:, s]] + 1e-12)
        return float(nll.mean() / tokens.shape[1])
