from .partition import Partition, partition_dataset
from .pipeline import LoaderConfig, ShardLoader, expert_loaders
from .synthetic import SyntheticConfig, SyntheticMultimodal
