"""Dataset partitioning (paper §5.1): balanced spherical k-means on frozen
encoder features → K disjoint shards; centroids become the router."""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

from repro.core.clustering import (ClusterResult, spherical_balanced_kmeans,
                                   two_stage_balanced_kmeans)
from repro.core.router import CentroidRouter, RouterConfig, router_from_clustering


@dataclass
class Partition:
    shards: List[np.ndarray]        # sample indices per expert
    clustering: ClusterResult
    router: CentroidRouter

    @property
    def K(self) -> int:
        return len(self.shards)


def partition_dataset(features: np.ndarray, K: int, *,
                      algorithm: str = "balanced",
                      router_config: RouterConfig = RouterConfig(),
                      seed: int = 0) -> Partition:
    """algorithm: 'balanced' (paper main) | 'two_stage' (Table 9 ablation)."""
    if algorithm == "balanced":
        res = spherical_balanced_kmeans(features, K, seed=seed)
    elif algorithm == "two_stage":
        res = two_stage_balanced_kmeans(features, K, seed=seed)
    else:
        raise ValueError(algorithm)
    shards = [np.where(res.assignment == k)[0] for k in range(K)]
    return Partition(shards=shards, clustering=res,
                     router=router_from_clustering(res.centroids,
                                                   router_config))
