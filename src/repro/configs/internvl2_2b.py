"""InternVL2-2B — InternViT (stub frontend) + InternLM2-1.8B LM backbone
[arXiv:2404.16821]. The LM consumes projected patch embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2_2b", family="vlm", n_layers=24, d_model=2_048,
    n_heads=16, n_kv_heads=8, d_ff=8_192, vocab=92_553, d_head=128,
    vision_dim=1_024, n_patches=256, source="arXiv:2404.16821",
)

def smoke_config():
    return ModelConfig(
        arch_id="internvl2_smoke", family="vlm", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab=512, d_head=32,
        vision_dim=64, n_patches=16,
        param_dtype="float32", compute_dtype="float32",
    )
