"""Assigned architecture configs (see each module for source citation)."""
from .base import (ARCH_IDS, INPUT_SHAPES, InputShape, ModelConfig,
                   MoEConfig, SSMConfig, all_configs, get_config,
                   get_smoke_config)
