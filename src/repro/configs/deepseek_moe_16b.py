"""DeepSeekMoE-16B — 2 shared + 64 routed experts, top-6, fine-grained
[arXiv:2401.06066]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek_moe_16b", family="moe", n_layers=28, d_model=2_048,
    n_heads=16, n_kv_heads=16, d_ff=1_408, vocab=102_400, d_head=128,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_ff_expert=1_408),
    source="arXiv:2401.06066",
)

def smoke_config():
    return ModelConfig(
        arch_id="deepseek_moe_smoke", family="moe", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=512, d_head=32,
        moe=MoEConfig(n_experts=4, top_k=2, n_shared=1, d_ff_expert=128, capacity_factor=8.0),
        param_dtype="float32", compute_dtype="float32",
    )
