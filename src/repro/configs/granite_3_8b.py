"""Granite-3 8B — dense GQA [hf:ibm-granite/granite-3.0 family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite_3_8b", family="dense", n_layers=40, d_model=4_096,
    n_heads=32, n_kv_heads=8, d_ff=12_800, vocab=49_155, d_head=128,
    tie_embeddings=True, source="hf:ibm-granite/granite-3.0-2b-base",
)

def smoke_config():
    return ModelConfig(
        arch_id="granite_smoke", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab=512, d_head=32,
        tie_embeddings=True, param_dtype="float32", compute_dtype="float32",
    )
