"""Llama-3 405B — dense GQA, 128k vocab [arXiv:2407.21783]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama3_405b", family="dense", n_layers=126, d_model=16_384,
    n_heads=128, n_kv_heads=8, d_ff=53_248, vocab=128_256, d_head=128,
    rope_theta=500_000.0, source="arXiv:2407.21783",
)

def smoke_config():
    return ModelConfig(
        arch_id="llama3_405b_smoke", family="dense", n_layers=2, d_model=256,
        n_heads=4, n_kv_heads=2, d_ff=512, vocab=512, d_head=64,
        rope_theta=500_000.0, param_dtype="float32", compute_dtype="float32",
    )
