"""Qwen3-8B — dense GQA with qk-norm [hf:Qwen/Qwen3-8B]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3_8b", family="dense", n_layers=36, d_model=4_096,
    n_heads=32, n_kv_heads=8, d_ff=12_288, vocab=151_936, d_head=128,
    qk_norm=True, rope_theta=1_000_000.0, source="hf:Qwen/Qwen3-8B",
)

def smoke_config():
    return ModelConfig(
        arch_id="qwen3_smoke", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab=512, d_head=32,
        qk_norm=True, param_dtype="float32", compute_dtype="float32",
    )
