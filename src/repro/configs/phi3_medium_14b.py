"""Phi-3-medium 14B — RoPE + SwiGLU + GQA [arXiv:2404.14219]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi3_medium_14b", family="dense", n_layers=40, d_model=5_120,
    n_heads=40, n_kv_heads=10, d_ff=17_920, vocab=100_352, d_head=128,
    source="arXiv:2404.14219",
)

def smoke_config():
    return ModelConfig(
        arch_id="phi3_smoke", family="dense", n_layers=2, d_model=160,
        n_heads=4, n_kv_heads=2, d_ff=320, vocab=512, d_head=40,
        param_dtype="float32", compute_dtype="float32",
    )
