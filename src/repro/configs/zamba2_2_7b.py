"""Zamba2-2.7B — Mamba2 backbone + periodically-applied *shared* attention
block [arXiv:2411.15242]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2_2_7b", family="hybrid", n_layers=54, d_model=2_560,
    n_heads=32, n_kv_heads=32, d_ff=10_240, vocab=32_000, d_head=80,
    ssm=SSMConfig(state=64, expand=2, chunk=256, shared_attn_every=6),
    source="arXiv:2411.15242",
)

def smoke_config():
    return ModelConfig(
        arch_id="zamba2_smoke", family="hybrid", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=256, vocab=512, d_head=32,
        ssm=SSMConfig(state=16, expand=2, chunk=16, shared_attn_every=2),
        param_dtype="float32", compute_dtype="float32",
    )
