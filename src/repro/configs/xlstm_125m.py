"""xLSTM-125M — alternating mLSTM/sLSTM blocks, no FFN (d_ff=0)
[arXiv:2405.04517]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="xlstm_125m", family="ssm", n_layers=12, d_model=768,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab=50_304,
    ssm=SSMConfig(expand=2, chunk=256, slstm_every=4),
    source="arXiv:2405.04517",
)

def smoke_config():
    return ModelConfig(
        arch_id="xlstm_smoke", family="ssm", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=0, vocab=512,
        ssm=SSMConfig(expand=2, chunk=16, slstm_every=2),
        param_dtype="float32", compute_dtype="float32",
    )
