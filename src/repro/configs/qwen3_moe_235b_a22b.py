"""Qwen3-MoE 235B-A22B — 128 experts, top-8, GQA, qk-norm
[hf:Qwen/Qwen3-30B-A3B family]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="qwen3_moe_235b_a22b", family="moe", n_layers=94, d_model=4_096,
    n_heads=64, n_kv_heads=4, d_ff=1_536, vocab=151_936, d_head=128,
    qk_norm=True, rope_theta=1_000_000.0,
    moe=MoEConfig(n_experts=128, top_k=8, n_shared=0, d_ff_expert=1_536),
    source="hf:Qwen/Qwen3-30B-A3B",
)

def smoke_config():
    return ModelConfig(
        arch_id="qwen3_moe_smoke", family="moe", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=512, d_head=32,
        qk_norm=True, moe=MoEConfig(n_experts=4, top_k=2, n_shared=0,
                                    d_ff_expert=128, capacity_factor=8.0),
        param_dtype="float32", compute_dtype="float32",
    )
