"""Unified architecture config + registry + assigned input shapes.

Every assigned architecture gets one module in this package defining
``CONFIG`` (the exact full-scale config, citation in ``source``) and
``smoke_config()`` (a reduced same-family variant: ≤2 layers, d_model ≤ 512,
≤4 experts — used by the CPU smoke tests). The full configs are exercised
only through the dry-run (ShapeDtypeStruct; no allocation).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field, replace

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0            # routed experts
    top_k: int = 0
    n_shared: int = 0             # always-on shared experts (DeepSeek-MoE)
    d_ff_expert: int = 0          # per-expert FFN hidden dim
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    state: int = 64               # SSM state dim N (Mamba2) / mLSTM head dim
    conv: int = 4                 # local conv width (stubbed as identity-pad)
    expand: int = 2               # d_inner = expand * d_model
    chunk: int = 256              # chunkwise-scan block length
    slstm_every: int = 0          # xLSTM: every k-th block is an sLSTM block
    shared_attn_every: int = 0    # zamba2: shared attention block period


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                   # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    source: str = ""              # citation for the assigned config
    d_head: int = 0               # 0 → d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0       # 0 → full attention; >0 → window size
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    # modality frontends (stubs — precomputed embeddings, see DESIGN.md)
    vision_dim: int = 0           # vlm: dim of incoming patch embeddings
    n_patches: int = 0            # vlm: image tokens per sample
    audio_dim: int = 0            # audio: dim of incoming frame embeddings
    n_audio_frames: int = 0       # audio: encoder sequence length
    n_enc_layers: int = 0         # audio: encoder depth (enc-dec)
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # §Perf H3b: dtype of the S×S attention logits/softmax. f32 is the
    # safe default; bf16 halves the quadratic attention traffic (the
    # dominant memory term at train_4k) at a known small quality cost.
    attn_softmax_dtype: str = "float32"
    # remat policy for the scanned layer stack: none | full | dots
    remat: str = "full"
    # unroll the layer stacks into straight-line HLO instead of lax.scan —
    # used by the dry-run depth probes (XLA cost analysis counts a while
    # body once, so scanned stacks undercount FLOPs/bytes by ~n_layers;
    # the probes fit f(G) = outside + G·per_layer on unrolled G ∈ {1,2})
    unroll: bool = False

    # §Perf H2: pad the vocab (embedding + unembedding rows) up to a
    # multiple of this so the vocab dim shards over the ``model`` mesh axis
    # even for odd tokenizer sizes (whisper 51865, internvl 92553, granite
    # 49155). 0 = no padding (paper-faithful sizes).
    pad_vocab_to: int = 0

    @property
    def padded_vocab(self) -> int:
        if self.pad_vocab_to <= 0:
            return self.vocab
        m = self.pad_vocab_to
        return ((self.vocab + m - 1) // m) * m

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def is_enc_dec(self) -> bool:
        return self.family == "audio"

    @property
    def supports_long_decode(self) -> bool:
        """long_500k needs sub-quadratic decode: recurrent state or a
        sliding window. Enc-dec audio is out of family (see DESIGN.md)."""
        if self.family == "audio":
            return False
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def reduced(self, **overrides) -> "ModelConfig":
        return replace(self, **overrides)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "llama3_405b", "qwen3_moe_235b_a22b", "internvl2_2b", "whisper_small",
    "xlstm_125m", "deepseek_moe_16b", "granite_3_8b", "qwen3_8b",
    "phi3_medium_14b", "zamba2_2_7b",
]


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id.replace('-', '_')}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id.replace('-', '_')}")
    return mod.smoke_config()


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
