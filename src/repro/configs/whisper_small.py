"""Whisper-small — encoder-decoder; conv/mel frontend is a STUB providing
precomputed frame embeddings [arXiv:2212.04356]. TPU adaptation: RoPE in
place of learned positional embeddings (noted in DESIGN.md)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper_small", family="audio", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3_072, vocab=51_865, d_head=64,
    audio_dim=768, n_audio_frames=1_500, n_enc_layers=12,
    source="arXiv:2212.04356",
)

def smoke_config():
    return ModelConfig(
        arch_id="whisper_smoke", family="audio", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=256, vocab=512, d_head=32,
        audio_dim=128, n_audio_frames=32, n_enc_layers=2,
        param_dtype="float32", compute_dtype="float32",
    )
