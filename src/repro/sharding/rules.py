"""Logical-axis sharding rules (the distribution configuration).

Meshes (launch/mesh.py):
    single-pod : (16, 16)      axes ("data", "model")
    multi-pod  : (2, 16, 16)   axes ("pod", "data", "model")

Two training modes:

* ``dense`` (the paper's centralized baseline): one model; batch and FSDP
  shard over (pod, data) — gradient all-reduce and FSDP all-gathers CROSS
  the pod boundary. This is the cost the paper's scheme removes.
* ``decentralized`` (the paper's scheme): K experts stacked on a leading
  ``dexpert`` dim sharded over ``pod``. Every collective's replica group
  stays inside one pod — the lowered HLO contains no cross-pod collective
  (launch/roofline.py verifies this from the compiled text).

Tensor parallelism (``model`` axis) rules are shared: vocab/heads/ffn/expert
dims shard over ``model``; kv_heads fall back to replicated when the head
count does not divide the axis (e.g. llama3 kv=8 on model=16).
"""
from __future__ import annotations

from typing import Dict, Tuple

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def logical_rules(*, multi_pod: bool, decentralized: bool,
                  fsdp: bool = True) -> Dict[str, object]:
    """Logical axis name → mesh axis (or tuple) mapping."""
    if decentralized:
        fsdp_axes = ("data",)          # pod is the expert axis
    else:
        fsdp_axes = ("pod", "data") if multi_pod else ("data",)
    rules: Dict[str, object] = {
        # ---- parameter axes
        "vocab": "model",
        "embed": fsdp_axes if fsdp else None,    # ZeRO-3-style weight shard
        "mlp": "model",
        "expert_mlp": None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "expert": "model",                        # MoE expert parallelism
        "inner": "model",
        "inner_qkv": "model",
        "vision": None,
        "audio": None,
        "layer": None,                            # scanned dim, never sharded
        # ---- decentralized expert stacking dim
        "dexpert": "pod" if (multi_pod and decentralized) else None,
        # ---- activation/batch axes
        "act_batch": (("pod", "data") if (multi_pod and not decentralized)
                      else ("data",)),
        "act_seq": None,
        "act_embed": None,
        "act_heads": "model",
        "act_vocab": "model",
        "kv_cache_batch": (("pod", "data") if (multi_pod and not decentralized)
                           else ("data",)),
        "kv_cache_heads": "model",
    }
    return rules


def batch_pspec(rules) -> P:
    return P(rules["act_batch"])


def data_shardings(rules, mesh: Mesh, cfg, kind: str,
                   decentralized_k: int = 0) -> Dict[str, NamedSharding]:
    """Shardings for the input batch pytree (tokens/labels/patches/frames).

    decentralized_k > 0 prepends the expert dim (sharded over pod).
    """
    lead: Tuple = (rules["dexpert"],) if decentralized_k else ()
    b = rules["act_batch"]

    def ns(*axes):
        return NamedSharding(mesh, P(*lead, *axes))

    shardings = {"tokens": ns(b, None), "labels": ns(b, None)}
    if cfg.family == "vlm":
        shardings["patches"] = ns(b, None, None)
    if cfg.family == "audio":
        shardings["frames"] = ns(b, None, None)
    return shardings


def stacked_cache_pspec_tree(stacked_cache_shapes, rules, mesh: Mesh,
                             seq_axes=None):
    """Shardings for the stacked-expert decode core's cache: every leaf
    carries the K (``dexpert``) dim at axis 1 — after its scan dim, the
    transpose-free layout of ``core/ensemble.stack_experts_for_decode`` —
    sharded over ``pod`` under the decentralized rules, with the per-expert
    remainder placed exactly as ``cache_pspec_tree`` places the unstacked
    cache. This makes the vmapped mixture ``decode_step`` one SPMD op whose
    expert slices stay on their own pods (the serving analogue of
    zero-communication training).

    Pass ``seq_axes`` — the UNSTACKED ``CacheSpec.paged.seq_axes`` pytree —
    when the stacked cache is the paged layout, so pool leaves get their
    block-pool placement."""
    import jax

    def strip(s):
        return jax.ShapeDtypeStruct(s.shape[:1] + s.shape[2:], s.dtype)

    stripped = jax.tree.map(strip, stacked_cache_shapes)
    if seq_axes is None:
        inner = cache_pspec_tree(stripped, rules, mesh)
    else:
        inner = paged_pool_pspec_tree(stripped, rules, mesh, seq_axes)
    return jax.tree.map(
        lambda ns: NamedSharding(
            mesh, P(ns.spec[0] if len(ns.spec) else None,
                    rules["dexpert"], *ns.spec[1:])), inner)


def _cache_leaf_spec(shape_struct, rules, mesh: Mesh) -> P:
    """Contiguous cache-leaf placement: batch over data, heads over model
    when divisible. Cache layouts all carry the layer/group dim first and
    batch second (attention) or inside (states) — we shard batch and leave
    exotic dims replicated when indivisible."""
    shape = shape_struct.shape
    ndim = len(shape)
    b_axes = rules["kv_cache_batch"]
    extent = 1
    for a in (b_axes if isinstance(b_axes, tuple) else (b_axes,)):
        extent *= mesh.shape[a]
    spec = [None] * ndim
    # find the batch dim: layouts here are (L, B, ...) or (G, gm, B, ...)
    for cand in (1, 2):
        if ndim > cand and shape[cand] % extent == 0 and shape[cand] > 1:
            spec[cand] = b_axes
            break
    # (L,B,S,KV,dh) attention-cache layouts: shard kv-heads over model
    # when divisible, else shard the *sequence* dim (distributed-decode
    # partial-softmax layout — XLA inserts the reduction collectives).
    if ndim == 5 and spec[1] == b_axes:
        kv, seq = shape[-2], shape[2]
        if kv % mesh.shape["model"] == 0 and kv > 1:
            spec[-2] = "model"
        elif seq % mesh.shape["model"] == 0 and seq > 1:
            spec[2] = "model"
    return P(*spec)


def cache_pspec_tree(cache_shapes, rules, mesh: Mesh):
    """KV-cache / recurrent-state shardings for the contiguous layout."""
    import jax
    return jax.tree.map(
        lambda s: NamedSharding(mesh, _cache_leaf_spec(s, rules, mesh)),
        cache_shapes)


def chunk_carry_pspec_tree(carry_shapes, rules, mesh: Mesh):
    """Shardings for a chunked-prefill carry (one request's direct-leaf
    decode states plus (1,)-shaped pool placeholders). The carry's batch
    extent is 1 — a single request mid-prefill — so nothing shards over the
    kv-cache batch axes; kv-heads of 5-D cross-attention leaves still
    follow ``model`` when divisible (they are full per-layer KV rows), and
    everything else is replicated alongside the dispatch that consumes it.
    The stacked-mixture carry additionally carries ``dexpert`` at axis 1 of
    every leaf, exactly like the stacked cache — reuse
    ``stacked_cache_pspec_tree`` semantics by mapping over this result."""
    import jax

    def one(shape_struct):
        shape = shape_struct.shape
        spec = [None] * len(shape)
        if len(shape) == 5:                    # (L, 1, F, KV, dh) cross KV
            kv = shape[-2]
            heads_ax = rules["kv_cache_heads"]
            if kv % mesh.shape[heads_ax] == 0 and kv > 1:
                spec[-2] = heads_ax
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, carry_shapes)


def block_table_pspec(rules, mesh: Mesh) -> NamedSharding:
    """Placement for the paged-cache METADATA operands: the per-step
    (n_slots, NB) decode block tables and the single-request (NB,) chunk
    table. The radix prefix-cache tree, refcounts, and LRU list are host
    state and never reach a device; the block table is the one
    device-visible piece of metadata, and it must be REPLICATED — with the
    pool's *physical block* axis sharded over the kv-cache batch axes
    (``paged_pool_pspec_tree``), every shard resolves its own
    ``pool[table]`` gather locally, so the tiny int32 table rides along
    with each dispatch instead of being scattered (and a shared-prefix
    block is readable from every shard that holds it, whichever slot's
    table points at it)."""
    return NamedSharding(mesh, P())


def slot_state_pspec_tree(state_like, rules, mesh: Mesh):
    """Placement for the fused decode step's per-slot device state (the
    tok/pos/temps/top_ks/seeds/counts/max_new/stop_ids/tables/weights dict
    of ``_SlotTable._device_state``): REPLICATED, like the block tables it
    now carries (``block_table_pspec``) — every leaf is a few-hundred-byte
    int/float row, so each shard keeps its own copy and the fused
    epilogue's sampling + stop/budget checks run locally with zero
    collectives; only the model forward inside the same dispatch touches
    sharded operands."""
    import jax
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), state_like)


def paged_pool_pspec_tree(paged_cache_shapes, rules, mesh: Mesh, seq_axes):
    """Shardings for the PAGED decode cache. ``seq_axes`` is the
    ``CacheSpec.paged.seq_axes`` pytree: leaves marked ``-1`` are direct
    per-slot rows and keep their contiguous placement; pool leaves
    (scan, P, block, KV, dh) shard the *physical block* axis over the
    kv-cache batch axes — blocks, not slots, are the unit of placement, so
    the pool scales with device count while the per-slot block table stays
    replicated host state — and kv-heads over ``model`` when divisible
    (block positions are never sharded: a block is the DMA granule)."""
    import jax

    def one(shape_struct, s_ax):
        if s_ax < 0:
            return NamedSharding(mesh,
                                 _cache_leaf_spec(shape_struct, rules, mesh))
        shape = shape_struct.shape
        ndim = len(shape)
        b_axes = rules["kv_cache_batch"]
        extent = 1
        for a in (b_axes if isinstance(b_axes, tuple) else (b_axes,)):
            extent *= mesh.shape[a]
        spec = [None] * ndim
        pool_ax = s_ax - 1          # the axis the slot (batch) axis held
        if shape[pool_ax] % extent == 0 and shape[pool_ax] > 1:
            spec[pool_ax] = b_axes
        if ndim == 5:
            kv = shape[-2]
            if kv % mesh.shape["model"] == 0 and kv > 1:
                spec[-2] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, paged_cache_shapes, seq_axes)
