from . import rules
