"""Training runtime: TrainState, jitted train_step, and the decentralized
expert trainer (the paper's scheme as a first-class mode).

Centralized (dense)    : one model, batch sharded over (pod, data).
Decentralized (experts): parameters carry a leading K dim stacked over the
``pod`` mesh axis; the per-expert step is vmapped over that dim, so experts
never exchange gradients — collectives stay inside a pod by construction.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.models.params import tree_shardings
from repro.optim.adamw import AdamWConfig, apply_updates, init_state

Array = jnp.ndarray


@dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = field(default_factory=AdamWConfig)
    use_kernel: bool = False


def init_train_state(model: Model, key, opt_cfg: AdamWConfig) -> Dict[str, Any]:
    params = model.init(key)
    return {"params": params, "opt": init_state(params)}


def make_train_step(model: Model, cfg: TrainConfig
                    ) -> Callable[[Dict, Dict], Tuple[Dict, Dict]]:
    """(state, batch) → (state, metrics). Pure; jit/pjit at the call site."""

    def train_step(state, batch):
        def loss_fn(p):
            loss, metrics = model.loss(p, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        params, opt, opt_metrics = apply_updates(
            state["params"], grads, state["opt"], cfg.opt)
        metrics = {**metrics, **opt_metrics}
        return {"params": params, "opt": opt}, metrics

    return train_step


def make_eval_step(model: Model) -> Callable:
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch)
        return metrics
    return eval_step


# ---------------------------------------------------------------------------
# Decentralized expert training (paper §5.1 "Experts training")
# ---------------------------------------------------------------------------

def stack_expert_states(states) -> Dict[str, Any]:
    """K independent TrainStates → one state with a leading K dim on every
    leaf (the dim that shards over the ``pod`` axis)."""
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *states)


def unstack_expert_states(stacked, K: int):
    return [jax.tree.map(lambda a: a[k], stacked) for k in range(K)]


def make_decentralized_train_step(model: Model, cfg: TrainConfig) -> Callable:
    """vmap of the single-expert step over the leading expert dim of both
    the state and the batch: experts advance in lockstep with ZERO mutual
    communication (the vmapped body contains no cross-expert collective)."""
    single = make_train_step(model, cfg)
    return jax.vmap(single)


# ---------------------------------------------------------------------------
# Sharding glue for pjit
# ---------------------------------------------------------------------------

def state_shardings(model: Model, rules: Dict, mesh,
                    decentralized_k: int = 0):
    """NamedShardings for the TrainState pytree (params + m/v/master like
    params, scalar count replicated)."""
    lead = ("dexpert",) if decentralized_k else ()
    pshard = tree_shardings(model.param_specs(), rules, mesh,
                            extra_leading_axes=lead)
    from jax.sharding import NamedSharding, PartitionSpec as P
    scalar = NamedSharding(mesh, P(*([None] * len(lead))))
    return {
        "params": pshard,
        "opt": {"m": pshard, "v": pshard, "master": pshard, "count": scalar},
    }


def train_host_loop(model: Model, state, loader, n_steps: int,
                    cfg: TrainConfig, *, log_every: int = 10,
                    callback: Optional[Callable] = None):
    """Simple single-host training driver (examples / parity benches)."""
    step_fn = jax.jit(make_train_step(model, cfg))
    history = []
    for step in range(n_steps):
        batch = next(loader)
        jb = {k: jnp.asarray(v) for k, v in batch.items()
              if k in ("tokens", "labels", "patches", "frames", "loss_mask")}
        state, metrics = step_fn(state, jb)
        if step % log_every == 0 or step == n_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": step, **m})
            if callback:
                callback(step, m)
    return state, history
