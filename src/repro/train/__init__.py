from .trainer import (TrainConfig, init_train_state,
                      make_decentralized_train_step, make_eval_step,
                      make_train_step, stack_expert_states, state_shardings,
                      train_host_loop, unstack_expert_states)
