"""Tables 4–6 analogue (InternVL setting, §6.2).

InternVL-2.5 full-finetunes ViT+MLP+LLM — the offline analogue is the VLM
smoke arch (stub patch embeddings + trainable projector + LM, all updated).
Table 4 = per-domain slice breakdown (OCR/chart/doc stand-ins); Table 5 =
overall QA + hallucination-proxy (NLL under deliberately mismatched image
features); Table 6 = routing/grounding quality (router↔latent alignment,
per-expert load, balance).
"""
from __future__ import annotations

import numpy as np
from scipy.optimize import linear_sum_assignment

from .common import BenchSettings, eval_metrics, fmt_row, run_parity


def run(s: BenchSettings):
    s_vlm = BenchSettings(**{**s.__dict__, "arch": "internvl2_2b"})
    res = run_parity(s_vlm, K=2)

    print("\n== Table 4 (InternVL per-domain slices analogue) ==")
    rows4 = {"dense_baseline": res.dense, "2_experts": res.experts}
    for n, m in rows4.items():
        print(fmt_row(n, m))

    print("\n== Table 5 (overall QA + hallucination-proxy) ==")
    # hallucination-proxy: evaluate the ensemble with features permuted
    # across the batch (image does not match the text) — a robust model's
    # NLL should degrade little; large degradation = feature over-reliance.
    class _RolledRouter:
        def __init__(self, inner):
            self.inner = inner

        def route(self, feats):
            import jax.numpy as jnp
            return self.inner.route(jnp.roll(feats, 1, axis=0))

    mis = eval_metrics(res.model, res.expert_params,
                       _RolledRouter(res.partition.router), res.corpus, s_vlm)
    rows5 = {
        "dense_baseline": {k: v for k, v in res.dense.items()
                           if not k.startswith("slice")},
        "2_experts": {k: v for k, v in res.experts.items()
                      if not k.startswith("slice")},
        "experts_mismatched": {k: v for k, v in mis.items()
                               if not k.startswith("slice")},
    }
    for n, m in rows5.items():
        print(fmt_row(n, m))

    print("\n== Table 6 (routing quality / grounding analogue) ==")
    part = res.partition
    labels = res.corpus.labels
    K = part.K
    conf = np.zeros((K, s.n_latent))
    for k in range(K):
        for c in labels[part.shards[k]]:
            conf[k, c] += 1
    r, c = linear_sum_assignment(-conf)
    purity = conf[r, c].sum() / conf.sum()
    sizes = [len(sh) for sh in part.shards]
    rows6 = {
        "partition_purity": float(purity),
        "balance_max_over_min": max(sizes) / max(min(sizes), 1),
        "router_self_consistency": float(
            (np.asarray(part.router.top1(
                np.asarray(res.corpus.all_features(), np.float32)))
             == part.clustering.assignment).mean()),
    }
    for k, v in rows6.items():
        print(f"{k:28s} {v:.4f}")
    return {"table4": rows4, "table5": rows5, "table6": rows6,
            "wall_s": res.wall_s}
