"""Tables 1 & 2 analogue (LLaVA setting, §6.1).

LLaVA-1.5 keeps the vision encoder frozen and finetunes the LM — the
offline analogue is a text-only LM (dense arch) whose *routing* features
come from the frozen stub frontend. Table 1 = overall + per-domain-slice
parity (academic-task breakdown); Table 2 = router-stress metrics mirroring
POPE adv/rand/pop: ensemble NLL under adversarially-noised, random, and
always-most-popular routing, vs the true centroid router.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from .common import BenchSettings, ParityResult, eval_metrics, fmt_row, run_parity


def table1(res: ParityResult, s: BenchSettings) -> Dict[str, Dict[str, float]]:
    rows = {"dense_baseline": res.dense, "2_experts": res.experts}
    print("\n== Table 1 (LLaVA academic-task parity analogue) ==")
    for n, m in rows.items():
        print(fmt_row(n, m))
    gap = res.experts["acc"] - res.dense["acc"]
    print(f"parity gap (experts − dense) = {gap:+.4f} acc "
          f"(paper: near-parity, small fluctuations)")
    return rows


def table2(res: ParityResult, s: BenchSettings) -> Dict[str, Dict[str, float]]:
    """Routing-robustness: adv = features noised to confuse the router;
    rand = uniform-random routing; pop = all traffic to the most popular
    expert. The true router should dominate."""
    model, corpus, router = res.model, res.corpus, res.partition.router
    K = len(res.expert_params)
    true_m = eval_metrics(model, res.expert_params, router, corpus, s)

    class _NoisyRouter:
        def __init__(self, inner, scale):
            self.inner, self.scale = inner, scale

        def route(self, feats):
            import jax
            noise = jax.random.normal(jax.random.PRNGKey(13), feats.shape)
            return self.inner.route(-feats + self.scale * noise)

    adv_m = eval_metrics(model, res.expert_params, _NoisyRouter(router, 1.0),
                         corpus, s)
    rand_m = eval_metrics(model, res.expert_params, None, corpus, s,
                          forced_weights=np.full((K,), 1.0 / K))
    pop = np.zeros(K)
    pop[0] = 1.0
    pop_m = eval_metrics(model, res.expert_params, None, corpus, s,
                         forced_weights=pop)
    rows = {"router_true": true_m, "router_adv": adv_m,
            "router_rand": rand_m, "router_pop": pop_m}
    print("\n== Table 2 (router-stress analogue of POPE adv/rand/pop) ==")
    for n, m in rows.items():
        print(fmt_row(n, {k: v for k, v in m.items()
                          if not k.startswith("slice")}))
    return rows


def run(s: BenchSettings):
    res = run_parity(s, K=2)
    t1 = table1(res, s)
    t2 = table2(res, s)
    return {"table1": t1, "table2": t2, "wall_s": res.wall_s}
