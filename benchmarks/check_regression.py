"""CI perf-regression gate over ``BENCH_serve.json``.

Compares the benchmark emission against a committed baseline
(``benchmarks/baseline_serve.json``) and fails on regression. Three metric
classes:

* **gated ratios** — scale-free speedups and memory ratios. These are
  stable across machines (both sides of each ratio run back-to-back on the
  same box), so they get a tolerance band around the baseline AND a hard
  floor where the serving claim itself sets one (chunked decode throughput
  under burst ≥ 1.3× monolithic; shared-prefix TTFT with the prefix cache
  warm ≥ 1.3× the uncached path).
* **invariants** — parity flags. Exact; any drift fails.
* **informational** — absolute tok/s and TTFT seconds. Machine-dependent;
  recorded in the report (and the uploaded artifact) but never gated, so a
  slow CI runner can't flake the job.

Re-baselining: run ``python -m benchmarks.serve_bench`` on a quiet
machine, inspect the printed report, then
``cp BENCH_serve.json benchmarks/baseline_serve.json`` and commit it with
a justification in the message (see docs/serving.md).

Usage: ``python -m benchmarks.check_regression [result.json] [baseline.json]``
"""
from __future__ import annotations

import json
import sys

# (section, key) -> spec. "floor" is an absolute hard bound (higher-is-
# better); "ceil" is its lower-is-better mirror — an absolute hard upper
# bound that binds even when the baseline-relative band is looser.
# "rel_tol" is the allowed relative drop (for higher-is-better) / rise
# (for lower) vs the committed baseline. All present bounds must hold.
GATED = {
    # re-calibrated when the bench's rep statistic was fixed to report one
    # self-consistent (looped, stacked, ratio) triple: the old number
    # paired a median ratio with best-of-rep raws and overstated the CPU
    # ratio. On CPU the K looped dispatches overlap via async dispatch, so
    # the honest smoke ratio sits near 0.75 — the floor only guards the
    # stacked path against collapsing (the structural win is on the mesh,
    # where looped pays K sequential per-token dispatches)
    ("serve_mixture", "stacked_over_looped"): {
        "higher_is_better": True, "rel_tol": 0.35, "floor": 0.65},
    # raised from 0.60 once the fused single-dispatch step + live-horizon
    # table truncation closed (then inverted) the paging gap: the paged
    # server now attends only written blocks while the fixed-row server
    # attends the whole provisioned context, so it wins outright (~1.3x
    # on the committed machine); 0.95 keeps "no slower than contiguous"
    # as the hard claim with margin for shared-machine noise
    ("serve_paged", "paged_over_contiguous"): {
        "higher_is_better": True, "rel_tol": 0.35, "floor": 0.95},
    ("serve_paged", "kv_memory_ratio"): {
        "higher_is_better": False, "rel_tol": 0.0},   # layout fact: exact
    # lowered from 1.30 when admission cache splices were jitted: the
    # stop-the-world prefill the chunked path amortizes got ~10x cheaper
    # to insert, so the monolithic baseline is honestly faster and the
    # chunked win over it is structurally smaller at smoke shapes. The
    # floor still asserts chunked admission WINS under burst load
    ("serve_chunked", "chunked_over_monolithic"): {
        "higher_is_better": True, "rel_tol": 0.35, "floor": 1.05},
    # TTFT ratio of two small wall-clock means: noisier than the
    # throughput ratios, so the band is wide enough that the 1.3x claim
    # floor (not the committed machine's ~3.2x) is the binding bound
    ("serve_prefix", "prefix_ttft_speedup"): {
        "higher_is_better": True, "rel_tol": 0.60, "floor": 1.30},
    # the telemetry layer's contract (docs/observability.md): full span
    # tracing + the always-on metrics registry cost ≤ 5% of serving
    # throughput on the chunked+paged+prefix configuration. The ceiling
    # is the claim itself — it binds regardless of baseline drift
    ("serve_obs", "obs_overhead_ratio"): {
        "higher_is_better": False, "rel_tol": 0.35, "ceil": 1.05},
    # noisy-neighbor isolation: the interactive tenant's p99 token gap
    # (in engine steps) with QoS on, over the same workload scheduled
    # FCFS/policy-free. Step counts are a deterministic property of the
    # host-side scheduler — no machine noise — so the band is tight; the
    # ceiling is the serving claim itself (QoS cuts the interactive
    # tail to under 0.6x of the unprotected tail on this workload)
    ("serve_qos", "qos_isolation_ratio"): {
        "higher_is_better": False, "rel_tol": 0.25, "ceil": 0.60},
}

INVARIANTS = [
    ("serve_paged", "parity"),
    ("serve_chunked", "parity"),
    ("serve_prefix", "parity"),
    # every shared-prefix token of the warm workload was served from the
    # cache — zero re-prefilled tokens for fully cached prefixes
    ("serve_prefix", "full_prefix_reuse"),
    # the streaming add_request/step API reproduces the serve() drain loop
    ("serve_stream", "parity"),
    # sanitized serving is observation-only: token-for-token identical...
    ("serve_sanitize", "parity"),
    # ...and the per-step ownership scan reports zero violations on the
    # production configuration (a violation here is a real pool bug)
    ("serve_sanitize", "sanitize_clean"),
    # speculation is a latency lever, never a sampling change: greedy AND
    # seeded-sampled outputs are token-for-token identical with it on
    ("serve_speculative", "spec_parity"),
    # span tracing is observation-only: token-for-token identical outputs
    # with the recorder on (the no-op-recorder side is the default path)
    ("serve_obs", "obs_parity"),
    # preemption + fair sharing reorder service, never tokens: both the
    # FCFS and QoS pressured runs reproduce the pressure-free reference
    # token-for-token (greedy AND seeded-sampled requests)
    ("serve_qos", "qos_parity"),
    # the policy's two halves held: the high-priority tenant was never
    # parked, and the batch tenant actually was (the mechanism engaged —
    # an isolation ratio earned without preemption pressure is vacuous)
    ("serve_qos", "qos_a_protected"),
    ("serve_qos", "qos_preemption_engaged"),
]

INFORMATIONAL = [
    ("serve_mixture", "stacked_steps_per_s"),
    ("serve_paged", "paged_tok_per_s"),
    ("serve_chunked", "chunked_decode_tok_per_s"),
    ("serve_chunked", "monolithic_burst_ttft_s"),
    ("serve_chunked", "chunked_burst_ttft_s"),
    ("serve_prefix", "uncached_ttft_s"),
    ("serve_prefix", "cached_ttft_s"),
    ("serve_prefix", "prefill_tokens_skipped"),
    # per-token latency through the streaming API (machine-dependent —
    # recorded, never gated; absent from baselines that predate them)
    ("serve_stream", "itl_p50_ms"),
    ("serve_stream", "itl_p99_ms"),
    ("serve_stream", "ttft_mean_s"),
    ("serve_stream", "stream_tok_per_s"),
    # debug-mode sanitizer cost (machine-dependent; the < 2x expectation
    # is documented in docs/analysis.md, not gated here)
    ("serve_sanitize", "sanitize_overhead_ratio"),
    ("serve_sanitize", "sanitized_tok_per_s"),
    # speculative acceptance + wall-clock: workload- and machine-
    # dependent (the CPU interpret path understates the dispatch-latency
    # win the L-position verify buys), so recorded but never gated
    ("serve_speculative", "spec_tokens_per_step"),
    ("serve_speculative", "spec_accept_rate"),
    ("serve_speculative", "spec_over_vanilla"),
    ("serve_speculative", "spec_tok_per_s"),
    # per-workload speculative diagnostics from the telemetry registry
    # (draft-source attribution + per-request accept-rate mean)
    ("serve_speculative", "spec_drafts_accepted"),
    ("serve_speculative", "spec_request_accept_rate_mean"),
    # telemetry cost + trace volume (the ratio is gated above; the raw
    # tok/s and event counts are machine-/ring-dependent)
    ("serve_obs", "traced_tok_per_s"),
    ("serve_obs", "trace_events"),
    ("serve_obs", "ttft_mean_s"),
    # QoS raws behind the gated ratio: the two p99 gaps, queueing delay,
    # and who got parked how often (all in deterministic step counts /
    # event counts, but workload-shape-dependent — the ratio is the claim)
    ("serve_qos", "fcfs_a_p99_gap_steps"),
    ("serve_qos", "qos_a_p99_gap_steps"),
    ("serve_qos", "fcfs_a_ttft_steps_mean"),
    ("serve_qos", "qos_a_ttft_steps_mean"),
    ("serve_qos", "fcfs_a_preempted"),
    ("serve_qos", "qos_b_preempted"),
]


def check(result: dict, baseline: dict) -> int:
    failures = []
    print(f"{'metric':52s} {'value':>10s} {'baseline':>10s}  verdict")
    for (sec, key), spec in GATED.items():
        got = result[sec][key]
        base = baseline[sec][key]
        tol = spec["rel_tol"]
        if spec["higher_is_better"]:
            bound = base * (1.0 - tol)
            ok = got >= bound
            if "floor" in spec:
                ok = ok and got >= spec["floor"]
                bound = max(bound, spec["floor"])
        else:
            bound = base * (1.0 + tol)
            if "ceil" in spec:
                bound = min(bound, spec["ceil"])
            ok = got <= bound
        verdict = "ok" if ok else f"REGRESSION (bound {bound:.3f})"
        print(f"{sec + '.' + key:52s} {got:10.3f} {base:10.3f}  {verdict}")
        if not ok:
            failures.append(f"{sec}.{key}: {got} vs bound {bound:.3f}")
    for sec, key in INVARIANTS:
        got = result[sec][key]
        ok = bool(got) is True
        print(f"{sec + '.' + key:52s} {str(got):>10s} {'true':>10s}  "
              f"{'ok' if ok else 'BROKEN'}")
        if not ok:
            failures.append(f"{sec}.{key}: expected true, got {got}")
    for sec, key in INFORMATIONAL:
        got = result[sec][key]
        base = baseline.get(sec, {}).get(key, float("nan"))
        print(f"{sec + '.' + key:52s} {got:10.3f} {base:10.3f}  info")
    if failures:
        print(f"\nFAIL: {len(failures)} regression(s):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nOK: no perf regression against baseline")
    return 0


def main(argv):
    result_path = argv[1] if len(argv) > 1 else "BENCH_serve.json"
    base_path = argv[2] if len(argv) > 2 \
        else "benchmarks/baseline_serve.json"
    with open(result_path) as f:
        result = json.load(f)
    with open(base_path) as f:
        baseline = json.load(f)
    return check(result, baseline)


if __name__ == "__main__":
    sys.exit(main(sys.argv))
