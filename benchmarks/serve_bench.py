"""Serving microbenchmarks.

1. Looped vs. stacked mixture decode (``run``): the pre-refactor mixture
   path ran K sequential ``decode_step`` dispatches per token (one per
   expert pytree) and mixed on the host; the stacked core runs ONE jitted
   step that vmaps over the leading K (``dexpert``) dim with
   ``mix_expert_logits`` fused in. This measures decode steps/sec for both
   at K=4 on a smoke model. Note the CPU baseline is generous: the K
   looped dispatches overlap via async dispatch, so the honest CPU ratio
   sits BELOW 1 (the gate floor only guards against the stacked path
   collapsing); the structural win (no K× per-token dispatch, pod-sharded
   experts) shows on the TPU mesh.

2. Paged vs. contiguous slot serving (``run_paged``): the same request
   queue served by the fixed-row ``SlotServer`` and the block-table paged
   one — asserts token-for-token greedy parity, then reports throughput
   and the KV-memory ratio (the paged pool holds half the contiguous
   rows' worth of blocks here and still serves the queue, because slots
   only reserve the blocks they actually write).

3. Chunked vs. monolithic prefill under bursty prompt load
   (``run_chunked``): long-running decoders share the server with a burst
   of long-prompt requests. Monolithic admission runs one stop-the-world
   prefill per burst arrival — every decoder stalls for its full duration;
   chunked prefill feeds the same prompts through the paged pool one chunk
   per step, co-scheduled with the decode dispatch. Asserts exact greedy
   parity, then reports the decoders' throughput-under-prefill-load
   (the CI gate: chunked ≥ 1.05× monolithic — the margin shrank when
   admission splices were jitted and the monolithic stall got cheaper)
   and mean burst TTFT.

4. Radix prefix cache on a shared-system-prompt workload
   (``run_prefix``): every prompt is one fixed system prefix plus a short
   unique suffix; with the cache warm each admission maps the shared
   blocks read-only and chunk-prefills only its suffix. Asserts exact
   greedy parity and full prefix reuse (zero re-prefilled shared-prefix
   tokens), then reports mean TTFT cached vs uncached (the CI gate:
   ≥ 1.3× TTFT win).

5. Streaming-API latency profile (``run_stream``): the same chunked+paged
   server driven through the incremental ``add_request``/``step`` API —
   every token's emission is stamped, so the report carries true
   per-token inter-token latency (p50/p99 ITL) and per-request TTFT
   measured through the streaming surface clients actually use. Asserts
   exact greedy parity with the legacy ``serve()`` drain loop; the
   latency numbers are machine-dependent and recorded informationally.

6. PoolSanitizer overhead (``run_sanitize``): the same chunked+paged+
   prefix-cached queue served with ``EngineConfig(sanitize=True)`` and
   without. Asserts exact greedy parity, a clean sanitizer report (zero
   violations over every checked step) and reports the step-loop overhead
   ratio — informational, but the tooling contract (docs/analysis.md)
   promises < 2× so debug-mode serving stays usable.

7. Speculative decoding (``run_speculative``): the same paged+fused
   server with ``EngineConfig(speculative="ngram", spec_len=4)`` on a
   repetitive-text workload (the regime prompt-lookup drafting targets).
   Asserts token-for-token parity — greedy AND seeded-sampled — against
   the vanilla server (the CI invariant: speculation is a latency lever,
   never a sampling change), then reports mean accepted tokens per
   verify step (> 1 means the drafts pay for themselves), the draft
   accept rate, and the wall-clock ratio (informational: on the CPU
   interpret path the L-position verify dispatch costs more than the
   accepted tokens buy back; the win shows where dispatch latency
   dominates step compute).

8. Telemetry overhead (``run_obs``): the same chunked+paged+prefix
   queue served with ``EngineConfig(trace=True)`` (full span tracing
   into the ring recorder; the metrics registry is always on) and with
   tracing off. Asserts exact greedy parity — telemetry must observe,
   never perturb — and reports the overhead ratio (the CI gate:
   ≤ 1.05×). The traced rep's Perfetto trace and metrics snapshot are
   written to ``obs_trace.json`` / ``obs_metrics.json`` so CI uploads a
   loadable sample artifact every run.

9. Multi-tenant QoS isolation (``run_qos``): a noisy-neighbor workload —
   an interactive tenant's short requests sharing the engine with a
   batch tenant's burst of long prompts on a deliberately tight block
   pool. The FCFS baseline runs the same preemption *mechanism* but no
   *policy* (no weights, every request priority 0), so the interactive
   tenant gets evicted and queued like anyone else; the QoS run adds
   tenant weights + priorities and the scheduler parks batch decoders
   instead. Latency is measured in ENGINE STEPS (which request emitted a
   token on which step), so the isolation ratio is a deterministic
   property of the scheduling policy, not a wall-clock sample. Asserts
   token-for-token parity — greedy AND seeded — for both runs against a
   pressure-free reference (preemption and fairness may reorder service,
   never change tokens); the CI gate bounds the interactive tenant's
   p99 token-gap ratio (QoS over FCFS).

Run as a module (``python -m benchmarks.serve_bench``) to execute all
nine and write ``BENCH_serve.json`` — the artifact
``benchmarks/check_regression.py`` gates CI on.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.core.ensemble import make_stacked_serving, mix_expert_logits
from repro.core.router import CentroidRouter, RouterConfig
from repro.models import build_model
from repro.serve.api import EngineConfig, SamplingParams
from repro.serve.scheduler import Request, SlotServer, make_engine


def run(_settings=None, *, K: int = 4, B: int = 32, prompt: int = 16,
        steps: int = 32, cache_len: int = 64):
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=256)
    model = build_model(cfg)
    experts = [model.init(jax.random.PRNGKey(k)) for k in range(K)]
    rng = np.random.default_rng(0)
    Df = 16
    router = CentroidRouter(
        jnp.asarray(rng.normal(size=(K, Df)), jnp.float32),
        RouterConfig(top_k=K))
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, prompt)),
                       jnp.int32)
    feats = jnp.asarray(rng.normal(size=(B, Df)), jnp.float32)
    weights = router.route(feats)                              # (B, K)
    batch = {"tokens": toks, "labels": jnp.zeros((B, prompt), jnp.int32)}

    prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len))
    decode = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos))

    # -- looped (pre-refactor): K sequential dispatches + host-side mix.
    #    The mix+argmax pick is ONE pre-jitted call taking the list-of-
    #    logits pytree: the baseline's defining cost is the K un-fused
    #    decode dispatches, not eager jnp.stack/argmax on top (repro-lint
    #    host-sync flags those on a hot path, and they'd only make the
    #    baseline look worse than it structurally is).
    states = [prefill(p, batch) for p in experts]
    caches_l = [c for _, c in states]
    looped_pick = jax.jit(
        lambda ls, w: jnp.argmax(mix_expert_logits(jnp.stack(ls), w),
                                 -1).astype(jnp.int32))
    tok = jnp.zeros((B,), jnp.int32)

    def looped_step(caches, tok, pos):  # repro: hot-path
        outs = [decode(p, c, tok, pos) for p, c in zip(experts, caches)]
        return (looped_pick([o[0] for o in outs], weights),
                [o[1] for o in outs])

    # -- stacked: one vmapped step (decode layout: K after the scan dim,
    #    so the scanned stacks need no per-step transpose), mixing fused in
    stacked, _, _, mix_decode = make_stacked_serving(model, experts,
                                                     cache_len)
    caches_s = jax.tree.map(lambda *ls: jnp.stack(ls, axis=1), *caches_l)

    def _greedy(stacked_p, caches, tok, pos, w):
        probs, caches = mix_decode(stacked_p, caches, tok, pos, w)
        return jnp.argmax(probs, -1).astype(jnp.int32), caches

    greedy_step = jax.jit(_greedy)          # argmax fused into the step

    def stacked_fn(caches, tok, pos):  # repro: hot-path
        return greedy_step(stacked, caches, tok, pos, weights)

    def bench(step_fn, caches):
        t, c = tok, caches
        t, c = step_fn(c, t, prompt)                    # warmup/compile
        jax.block_until_ready(t)
        t0 = time.perf_counter()
        for i in range(steps):
            t, c = step_fn(c, t, prompt + 1 + i)
            jax.block_until_ready(t)
        return steps / (time.perf_counter() - t0)

    # Each rep times the two impls back-to-back, so shared-machine load
    # hits both sides of that rep's ratio; the report is the median rep BY
    # ratio — one self-consistent (looped, stacked, ratio) triple. (The
    # old scheme reported max-over-reps raws next to the median ratio:
    # two numbers from different reps that need not agree — a baseline
    # could carry raws implying 0.57 beside a recorded 1.05.)
    pairs = []
    for _ in range(5):
        lo = bench(looped_step, caches_l)
        st = bench(stacked_fn, caches_s)
        pairs.append((st / lo, lo, st))
    speedup, looped_sps, stacked_sps = sorted(pairs)[len(pairs) // 2]

    result = {
        "K": K, "batch": B, "steps": steps,
        "looped_steps_per_s": round(looped_sps, 2),
        "stacked_steps_per_s": round(stacked_sps, 2),
        "stacked_over_looped": round(speedup, 3),
    }
    print("\n== Serving: looped vs stacked mixture decode ==")
    print("name,steps_per_s")
    print(f"mixture_looped,{looped_sps:.2f}")
    print(f"mixture_stacked,{stacked_sps:.2f}")
    print(f"speedup,{result['stacked_over_looped']}")
    return result


def run_paged(_settings=None, *, n_requests: int = 48, n_slots: int = 8,
              prompt: int = 12, max_new: int = 24, cache_len: int = 256,
              page_block: int = 32):
    """Paged-vs-contiguous decode: greedy parity (hard assert) +
    throughput + KV memory. The pool is provisioned at HALF the contiguous
    capacity — enough for this load because short-lived requests return
    their blocks — which is exactly the memory the fixed-row layout cannot
    give back.

    ``cache_len`` is the provisioned context limit, deliberately larger
    than any request here uses (as in real serving): the fixed-row server
    allocates AND attends over all ``cache_len`` rows per slot every step,
    while the paged server allocates blocks lazily and its dispatch sees
    only the live logical-block horizon (``_nb_live``) — so the paged
    path wins throughput outright on top of the memory ratio. ``max_new``
    pushes positions across a block boundary so the run exercises
    mid-decode growth and the table-patch upload, not just admission."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=prompt).astype(np.int32)
               for _ in range(n_requests)]

    def queue():
        return [Request(i, p, max_new) for i, p in enumerate(prompts)]

    nb_slot = -(-cache_len // page_block)
    pool_blocks = n_slots * nb_slot // 2 + 1

    def bench(server):
        t0 = time.perf_counter()
        out = server.serve(queue())
        jax.block_until_ready(server.cache)
        dt = time.perf_counter() - t0
        toks = sum(len(v) for v in out.values())
        return out, toks / dt

    from repro.serve.scheduler import make_fused_fns, make_serve_fns
    fns_c = make_serve_fns(model, cache_len)
    fns_p = make_serve_fns(model, cache_len, paged=True)
    ffns_c = make_fused_fns(model, cache_len)
    ffns_p = make_fused_fns(model, cache_len, paged=True)

    def fresh(paged: bool):
        if paged:
            return SlotServer(model, params, n_slots=n_slots,
                              cache_len=cache_len, serve_fns=fns_p,
                              fused_fns=ffns_p, page_block=page_block,
                              pool_blocks=pool_blocks)
        return SlotServer(model, params, n_slots=n_slots,
                          cache_len=cache_len, serve_fns=fns_c,
                          fused_fns=ffns_c)

    # warm the shared jits outside the timed region; then rep paired runs —
    # a single-shot ratio on a shared machine is far too noisy to gate CI
    # on, so the report is the median rep BY ratio: one self-consistent
    # (contiguous, paged, ratio) triple
    bench(fresh(False)), bench(fresh(True))
    pairs = []
    for _ in range(5):
        out_c, c = bench(fresh(False))
        out_p, p = bench(fresh(True))
        assert out_c == out_p, "paged decode diverged from contiguous"
        pairs.append((p / c, c, p))
    speedup, tps_c, tps_p = sorted(pairs)[len(pairs) // 2]

    kv_rows = n_slots * cache_len                      # contiguous KV slots
    kv_pool = pool_blocks * page_block                 # paged pool slots
    result = {
        "requests": n_requests, "slots": n_slots, "max_new": max_new,
        "contiguous_tok_per_s": round(tps_c, 2),
        "paged_tok_per_s": round(tps_p, 2),
        "paged_over_contiguous": round(speedup, 3),
        "kv_memory_ratio": round(kv_pool / kv_rows, 3),
        "parity": True,
    }
    print("\n== Serving: contiguous vs paged KV cache ==")
    print("name,tok_per_s")
    print(f"slots_contiguous,{tps_c:.2f}")
    print(f"slots_paged,{tps_p:.2f}")
    print(f"speedup,{result['paged_over_contiguous']}")
    print(f"kv_memory_ratio,{result['kv_memory_ratio']}")
    print("parity,exact")
    return result


def run_chunked(_settings=None, *, n_slots: int = 6, n_decoders: int = 4,
                decode_prompt: int = 8, decode_new: int = 48,
                n_burst: int = 32, burst_prompt: int = 64,
                burst_new: int = 2, cache_len: int = 96,
                page_block: int = 8, chunk: int = 16, reps: int = 3):
    """Decode throughput under concurrent prompt arrivals.

    ``n_decoders`` short-prompt long-budget requests occupy slots and
    decode for the whole run; ``n_burst`` long-prompt short-budget requests
    churn through the remaining slots. Monolithic admission stalls every
    decoder for one full ``burst_prompt``-wide prefill per arrival; chunked
    prefill rides one chunk per decode step. Reported decode throughput is
    the decoders' tokens over the time until the LAST decoder finishes —
    exactly the window the burst prefills compete in. The paired ratio is
    the CI gate; the median of ``reps`` back-to-back pairs is robust to a
    rep landing on a shared-machine load spike.
    """
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    dec_prompts = [rng.integers(0, cfg.vocab, size=decode_prompt)
                   .astype(np.int32) for _ in range(n_decoders)]
    burst_prompts = [rng.integers(0, cfg.vocab, size=burst_prompt)
                     .astype(np.int32) for _ in range(n_burst)]

    def queue():
        reqs = [Request(i, p, decode_new)
                for i, p in enumerate(dec_prompts)]
        reqs += [Request(n_decoders + i, p, burst_new)
                 for i, p in enumerate(burst_prompts)]
        return reqs

    # share the jitted fns across reps (a fresh server per rep resets slot
    # state; recompiling per rep would swamp the measurement)
    from repro.serve.scheduler import (make_chunk_fns, make_fused_fns,
                                       make_serve_fns)
    fns = make_serve_fns(model, cache_len, paged=True)
    cfns = make_chunk_fns(model, cache_len, chunk, paged=True)
    ffns = make_fused_fns(model, cache_len, chunk, paged=True)

    def fresh(chunked: bool):
        return SlotServer(model, params, n_slots=n_slots,
                          cache_len=cache_len, page_block=page_block,
                          serve_fns=fns, chunk=chunk if chunked else 0,
                          chunk_fns=cfns, fused_fns=ffns)

    def bench(server):
        reqs = queue()
        t0 = time.perf_counter()
        out = server.serve(reqs)
        jax.block_until_ready(server.cache)
        decoders = reqs[:n_decoders]
        bursts = reqs[n_decoders:]
        t_done = max(r.t_done for r in decoders) - t0
        decode_tps = sum(len(r.out) for r in decoders) / t_done
        ttft = float(np.mean([r.t_first - t0 for r in bursts]))
        return out, decode_tps, ttft

    bench(fresh(False)), bench(fresh(True))        # warm the jits
    mono_tps = chunked_tps = 0.0
    mono_ttft = chunked_ttft = float("inf")
    ratios = []
    for _ in range(reps):
        out_m, tps_m, ttft_m = bench(fresh(False))
        out_c, tps_c, ttft_c = bench(fresh(True))
        assert out_c == out_m, "chunked prefill diverged from monolithic"
        mono_tps, chunked_tps = max(mono_tps, tps_m), max(chunked_tps, tps_c)
        mono_ttft = min(mono_ttft, ttft_m)
        chunked_ttft = min(chunked_ttft, ttft_c)
        ratios.append(tps_c / tps_m)
    ratio = sorted(ratios)[len(ratios) // 2]

    result = {
        "decoders": n_decoders, "burst": n_burst,
        "burst_prompt": burst_prompt, "chunk": chunk,
        "monolithic_decode_tok_per_s": round(mono_tps, 2),
        "chunked_decode_tok_per_s": round(chunked_tps, 2),
        "chunked_over_monolithic": round(ratio, 3),
        "monolithic_burst_ttft_s": round(mono_ttft, 4),
        "chunked_burst_ttft_s": round(chunked_ttft, 4),
        "parity": True,
    }
    print("\n== Serving: monolithic vs chunked prefill under burst ==")
    print("name,decode_tok_per_s")
    print(f"prefill_monolithic,{mono_tps:.2f}")
    print(f"prefill_chunked,{chunked_tps:.2f}")
    print(f"speedup,{result['chunked_over_monolithic']}")
    print(f"burst_ttft_monolithic_s,{mono_ttft:.4f}")
    print(f"burst_ttft_chunked_s,{chunked_ttft:.4f}")
    print("parity,exact")
    return result


def run_prefix(_settings=None, *, n_requests: int = 16, n_slots: int = 4,
               sys_len: int = 64, suffix: int = 8, max_new: int = 8,
               cache_len: int = 96, page_block: int = 8, chunk: int = 16,
               reps: int = 3):
    """Shared-system-prompt workload: every request's prompt is one fixed
    ``sys_len``-token system prefix plus a short unique suffix — the shape
    of instruction-tuned traffic, and the per-expert routing concentrates
    it further onto single pods. With the radix prefix cache warm, each
    admission maps the system prompt's blocks read-only out of the pool
    and chunk-prefills only its suffix, so TTFT collapses from
    ceil(width / chunk) chunk-steps to ~1. Asserts exact greedy parity
    with the uncached server and FULL prefix reuse (zero re-prefilled
    tokens across the shared prefixes); the TTFT ratio is the CI gate."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    sys_prompt = rng.integers(0, cfg.vocab, size=sys_len).astype(np.int32)
    suffixes = [rng.integers(0, cfg.vocab, size=suffix).astype(np.int32)
                for _ in range(n_requests)]
    prompts = [np.concatenate([sys_prompt, s]) for s in suffixes]

    def queue():
        return [Request(i, p, max_new) for i, p in enumerate(prompts)]

    from repro.serve.scheduler import (make_chunk_fns, make_fused_fns,
                                       make_serve_fns)
    fns = make_serve_fns(model, cache_len, paged=True)
    cfns = make_chunk_fns(model, cache_len, chunk, paged=True)
    ffns = make_fused_fns(model, cache_len, chunk, paged=True)

    def fresh(prefix: bool):
        srv = SlotServer(model, params, n_slots=n_slots,
                         cache_len=cache_len, page_block=page_block,
                         serve_fns=fns, chunk=chunk, chunk_fns=cfns,
                         fused_fns=ffns, prefix_cache=prefix)
        if prefix:
            # warm the tree once (steady-state serving: the system prompt
            # is cached after the very first request that carries it)
            srv.serve([Request(10_000,
                               np.concatenate([sys_prompt, suffixes[0][:1]]),
                               1)])
        return srv

    def bench(srv):
        reqs = queue()
        t0 = time.perf_counter()
        out = srv.serve(reqs)
        jax.block_until_ready(srv.cache)
        ttft = float(np.mean([r.t_first - t0 for r in reqs]))
        return out, ttft

    bench(fresh(False)), bench(fresh(True))        # warm the jits
    off_ttft = on_ttft = float("inf")
    skipped = 0
    full_reuse = True
    ratios = []
    for _ in range(reps):
        out_off, t_off = bench(fresh(False))
        srv_on = fresh(True)
        before = srv_on.prefix.skipped_tokens
        out_on, t_on = bench(srv_on)
        assert out_on == out_off, "prefix-cached serving diverged"
        skipped = srv_on.prefix.skipped_tokens - before
        full_reuse &= skipped == n_requests * sys_len
        off_ttft, on_ttft = min(off_ttft, t_off), min(on_ttft, t_on)
        ratios.append(t_off / t_on)
    ratio = sorted(ratios)[len(ratios) // 2]

    result = {
        "requests": n_requests, "sys_prompt": sys_len, "suffix": suffix,
        "chunk": chunk,
        "uncached_ttft_s": round(off_ttft, 4),
        "cached_ttft_s": round(on_ttft, 4),
        "prefix_ttft_speedup": round(ratio, 3),
        "prefill_tokens_skipped": skipped,
        "full_prefix_reuse": full_reuse,
        "parity": True,
    }
    print("\n== Serving: shared-prefix workload, prefix cache off vs on ==")
    print("name,ttft_s")
    print(f"prefix_uncached,{off_ttft:.4f}")
    print(f"prefix_cached,{on_ttft:.4f}")
    print(f"speedup,{result['prefix_ttft_speedup']}")
    print(f"prefill_tokens_skipped,{skipped}")
    print(f"full_prefix_reuse,{full_reuse}")
    print("parity,exact")
    return result


def run_stream(_settings=None, *, n_requests: int = 16, n_slots: int = 4,
               prompt: int = 24, max_new: int = 24, cache_len: int = 64,
               page_block: int = 8, chunk: int = 8):
    """Per-token latency through the incremental streaming API.

    Drives a chunked+paged ``SlotServer`` (built by ``make_engine`` from
    one ``EngineConfig``) with ``add_request``/``step``, collecting every
    ``TokenDelta`` stamp: ITL is the gap between a request's consecutive
    deltas (p50 = steady lockstep decode; p99 catches admission/prefill
    stalls leaking into running decodes), TTFT is first-delta minus
    submission. Asserts the streamed cumulative ids equal the legacy
    ``serve()`` drain loop's outputs token-for-token."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=prompt).astype(np.int32)
               for _ in range(n_requests)]
    ecfg = EngineConfig(n_slots=n_slots, cache_len=cache_len, paged=True,
                        page_block=page_block, chunked_prefill=True,
                        chunk=chunk)
    srv = make_engine(model, params, config=ecfg)

    # legacy drain-loop reference on the SAME engine: the greedy parity
    # oracle, and it warms every jit bucket the timed streaming pass hits
    # (identical prompt widths), so the latency profile measures steady-
    # state serving rather than compilation
    ref = srv.serve([Request(i, p, max_new) for i, p in enumerate(prompts)])

    sp = SamplingParams(max_new=max_new)
    t0 = time.perf_counter()
    rids = [srv.add_request(p, sp) for p in prompts]
    stamps: dict = {r: [] for r in rids}
    finished: dict = {}
    while srv.has_unfinished():
        for o in srv.step():
            stamps[o.rid] += [d.t for d in o.deltas]
            if o.finished:
                finished[o.rid] = (o.token_ids, o.ttft)
    wall = time.perf_counter() - t0

    assert {i: finished[r][0] for i, r in enumerate(rids)} == ref, \
        "streaming outputs diverged from the serve() drain loop"
    itl = np.concatenate([np.diff(ts) for ts in stamps.values()
                          if len(ts) > 1])
    ttfts = [t for _, t in finished.values()]
    n_tok = sum(len(t) for t, _ in finished.values())
    result = {
        "requests": n_requests, "max_new": max_new, "chunk": chunk,
        "itl_p50_ms": round(float(np.percentile(itl, 50)) * 1e3, 3),
        "itl_p99_ms": round(float(np.percentile(itl, 99)) * 1e3, 3),
        "ttft_mean_s": round(float(np.mean(ttfts)), 4),
        "stream_tok_per_s": round(n_tok / wall, 2),
        "parity": True,
    }
    print("\n== Serving: streaming API latency profile ==")
    print("name,value")
    print(f"itl_p50_ms,{result['itl_p50_ms']}")
    print(f"itl_p99_ms,{result['itl_p99_ms']}")
    print(f"ttft_mean_s,{result['ttft_mean_s']}")
    print(f"stream_tok_per_s,{result['stream_tok_per_s']}")
    print("parity,exact")
    return result


def run_sanitize(_settings=None, *, n_requests: int = 24, n_slots: int = 4,
                 prompt: int = 12, max_new: int = 16, cache_len: int = 64,
                 page_block: int = 8, chunk: int = 8, reps: int = 3):
    """PoolSanitizer overhead on a chunked+paged+prefix-cached queue.

    The sanitizer shadows the allocator / prefix cache / block tables and
    re-derives full pool ownership every step, so its cost scales with
    slots × blocks-per-slot — this measures the ratio on the exact serving
    configuration the tier-1 suite gates. Asserts token-for-token greedy
    parity (the sanitizer must observe, never perturb) and a clean report;
    the overhead ratio is informational with a < 2× expectation
    (docs/analysis.md)."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=prompt).astype(np.int32)
               for _ in range(n_requests)]

    def queue():
        return [Request(i, p, max_new) for i, p in enumerate(prompts)]

    from repro.serve.scheduler import (make_chunk_fns, make_fused_fns,
                                       make_serve_fns)
    fns = make_serve_fns(model, cache_len, paged=True)
    cfns = make_chunk_fns(model, cache_len, chunk, paged=True)
    ffns = make_fused_fns(model, cache_len, chunk, paged=True)
    base = dict(n_slots=n_slots, cache_len=cache_len, paged=True,
                page_block=page_block, chunked_prefill=True, chunk=chunk,
                prefix_cache=True)

    def fresh(sanitize: bool):
        return SlotServer(model, params, serve_fns=fns, chunk_fns=cfns,
                          fused_fns=ffns,
                          config=EngineConfig(**base, sanitize=sanitize))

    def bench(srv):
        t0 = time.perf_counter()
        out = srv.serve(queue())
        jax.block_until_ready(srv.cache)
        dt = time.perf_counter() - t0
        return out, sum(len(v) for v in out.values()) / dt

    bench(fresh(False))
    bench(fresh(True))                             # warm the jits
    ratios = []
    plain_tps = san_tps = 0.0
    checked = violations = 0
    for _ in range(reps):
        out_p, tps_p = bench(fresh(False))
        srv_s = fresh(True)
        out_s, tps_s = bench(srv_s)
        assert out_s == out_p, "sanitized serving diverged from plain"
        st = srv_s.stats()
        checked = st["sanitize_checked_steps"]
        violations = st["sanitize_violations"]
        plain_tps, san_tps = max(plain_tps, tps_p), max(san_tps, tps_s)
        ratios.append(tps_p / tps_s)
    ratio = sorted(ratios)[len(ratios) // 2]

    result = {
        "requests": n_requests, "slots": n_slots, "chunk": chunk,
        "plain_tok_per_s": round(plain_tps, 2),
        "sanitized_tok_per_s": round(san_tps, 2),
        "sanitize_overhead_ratio": round(ratio, 3),
        "checked_steps": checked,
        "violations": violations,
        "sanitize_clean": violations == 0,
        "parity": True,
    }
    print("\n== Serving: PoolSanitizer overhead (debug mode) ==")
    print("name,value")
    print(f"serve_plain_tok_per_s,{plain_tps:.2f}")
    print(f"serve_sanitized_tok_per_s,{san_tps:.2f}")
    print(f"sanitize_overhead_ratio,{result['sanitize_overhead_ratio']}")
    print(f"checked_steps,{checked}")
    print(f"violations,{violations}")
    print("parity,exact")
    return result


def run_speculative(_settings=None, *, n_requests: int = 12,
                    n_slots: int = 4, max_new: int = 48,
                    cache_len: int = 64, page_block: int = 8,
                    spec_len: int = 4, reps: int = 3):
    """N-gram speculative decoding vs vanilla on a repetitive workload.

    Prompts are period-4 token tiles — the structure prompt-lookup
    drafting exploits — and the queue mixes greedy with seeded-sampled
    requests, so the parity assert covers the deterministic token-match
    accept rule on BOTH sampling paths. ``spec_tokens_per_step`` is the
    structural result (accepted tokens per verify dispatch; > 1 means
    each dispatch commits more than a vanilla step would); the
    wall-clock ratio is informational — the L-position verify costs more
    FLOPs per dispatch, so the ratio only exceeds 1 where per-step
    dispatch latency dominates, which the CPU interpret path understates."""
    # Small vocabulary + long greedy generations: a random-weight smoke
    # model's greedy trajectory falls into a short cycle quickly at
    # vocab 32, which is exactly the self-repetition prompt lookup
    # drafts from. Two seeded-sampled requests ride along so the parity
    # assert exercises the deterministic token-match rule on the
    # sampling path too (they rarely repeat — they drag the mean accept
    # down, not up).
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    lens = (7, 11, 5, 9)
    prompts = []
    for i in range(n_requests):
        n = lens[i % len(lens)]
        base = rng.integers(1, cfg.vocab, size=4)
        prompts.append(np.tile(base, n // 4 + 2)[:n].astype(np.int32))

    def queue():
        q = []
        for i, p in enumerate(prompts):
            sp = (SamplingParams(max_new=max_new, temperature=0.8,
                                 top_k=8, seed=100 + i) if i < 2 else
                  SamplingParams(max_new=max_new))
            q.append(Request(i, p, max_new, params=sp))
        return q

    from repro.serve.scheduler import (make_fused_fns, make_serve_fns,
                                       make_verify_fns)
    fns = make_serve_fns(model, cache_len, paged=True)
    ffns = make_fused_fns(model, cache_len, paged=True)
    vfns = make_verify_fns(model, cache_len)
    base = dict(n_slots=n_slots, cache_len=cache_len, paged=True,
                page_block=page_block, fused_step=True)

    def fresh(spec: bool):
        ecfg = EngineConfig(**base,
                            speculative="ngram" if spec else None,
                            spec_len=spec_len)
        return SlotServer(model, params, serve_fns=fns, fused_fns=ffns,
                          verify_fns=vfns if spec else None, config=ecfg)

    def bench(srv):
        t0 = time.perf_counter()
        out = srv.serve(queue())
        jax.block_until_ready(srv.cache)
        dt = time.perf_counter() - t0
        return out, sum(len(v) for v in out.values()) / dt

    bench(fresh(False))
    bench(fresh(True))                             # warm the jits
    ratios = []
    van_tps = spec_tps = 0.0
    st = {}
    for _ in range(reps):
        qv, qs = queue(), queue()
        srv_v, srv_s = fresh(False), fresh(True)
        t0 = time.perf_counter()
        out_v = srv_v.serve(qv)
        jax.block_until_ready(srv_v.cache)
        tps_v = sum(len(v) for v in out_v.values()) / (
            time.perf_counter() - t0)
        t0 = time.perf_counter()
        out_s = srv_s.serve(qs)
        jax.block_until_ready(srv_s.cache)
        tps_s = sum(len(v) for v in out_s.values()) / (
            time.perf_counter() - t0)
        assert out_s == out_v, "speculative decode diverged from vanilla"
        for rv, rs in zip(qv, qs):
            assert rv.finish_reason == rs.finish_reason, \
                (rv.rid, rv.finish_reason, rs.finish_reason)
        st = srv_s.stats()
        assert st["spec_steps"] > 0, "speculation never engaged"
        van_tps, spec_tps = max(van_tps, tps_v), max(spec_tps, tps_s)
        ratios.append(tps_s / tps_v)
    ratio = sorted(ratios)[len(ratios) // 2]

    steps, toks = st["spec_steps"], st["spec_tokens"]
    accept_rate = ((toks - steps) / (steps * (spec_len - 1))
                   if steps else 0.0)
    # per-workload diagnostics from the telemetry registry (PR 9): the
    # draft-source counters make the aggregate accept rate attributable
    # (which drafter proposed how much, how much survived verify), and
    # the per-request accept-rate histogram shows whether a low mean is
    # uniform or a bimodal mix of repetitive (high-accept) and sampled
    # (near-zero-accept) requests — srv_s is the LAST rep's fresh server,
    # so these cover exactly one serve() pass over the queue.
    obs = srv_s.obs
    proposed = int(obs.drafts("ngram", "proposed").value)
    accepted = int(obs.drafts("ngram", "accepted").value)
    req_rate = obs.req_accept_rate
    result = {
        "requests": n_requests, "slots": n_slots, "spec_len": spec_len,
        "vanilla_tok_per_s": round(van_tps, 2),
        "spec_tok_per_s": round(spec_tps, 2),
        "spec_over_vanilla": round(ratio, 3),
        "spec_steps": steps,
        "spec_tokens": toks,
        "spec_tokens_per_step": round(st["spec_tokens_per_step"], 3),
        "spec_accept_rate": round(accept_rate, 3),
        "spec_drafts_proposed": proposed,
        "spec_drafts_accepted": accepted,
        "spec_request_accept_rate_mean": (
            round(float(req_rate.value), 3) if req_rate.count else 0.0),
        "spec_requests_measured": req_rate.count,
        "spec_parity": True,
    }
    print("\n== Serving: n-gram speculative decoding vs vanilla ==")
    print("name,value")
    print(f"vanilla_tok_per_s,{van_tps:.2f}")
    print(f"spec_tok_per_s,{spec_tps:.2f}")
    print(f"spec_over_vanilla,{result['spec_over_vanilla']}")
    print(f"spec_tokens_per_step,{result['spec_tokens_per_step']}")
    print(f"spec_accept_rate,{result['spec_accept_rate']}")
    print(f"spec_drafts,{accepted}/{proposed} accepted (source=ngram)")
    print("spec_request_accept_rate_mean,"
          f"{result['spec_request_accept_rate_mean']}")
    print("parity,exact")
    return result


def run_obs(_settings=None, *, n_requests: int = 24, n_slots: int = 4,
            prompt: int = 12, max_new: int = 16, cache_len: int = 64,
            page_block: int = 8, chunk: int = 8, reps: int = 3,
            trace_out: str = "obs_trace.json",
            metrics_out: str = "obs_metrics.json"):
    """Telemetry overhead on the chunked+paged+prefix-cached queue.

    The per-engine metrics registry is always on, so the "plain" side
    here is exactly production default; the traced side adds
    ``EngineConfig(trace=True)`` — every scheduler-boundary span lands
    in the ring recorder. Asserts token-for-token greedy parity (the
    whole telemetry layer is host-side observation; it must never
    perturb the schedule) and gates the overhead ratio at ≤ 1.05× in
    check_regression.py. The last traced rep's Perfetto trace and
    metrics snapshot are written as CI sample artifacts."""
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=prompt).astype(np.int32)
               for _ in range(n_requests)]

    def queue():
        return [Request(i, p, max_new) for i, p in enumerate(prompts)]

    from repro.serve.scheduler import (make_chunk_fns, make_fused_fns,
                                       make_serve_fns)
    fns = make_serve_fns(model, cache_len, paged=True)
    cfns = make_chunk_fns(model, cache_len, chunk, paged=True)
    ffns = make_fused_fns(model, cache_len, chunk, paged=True)
    base = dict(n_slots=n_slots, cache_len=cache_len, paged=True,
                page_block=page_block, chunked_prefill=True, chunk=chunk,
                prefix_cache=True)

    def fresh(trace: bool):
        return SlotServer(model, params, serve_fns=fns, chunk_fns=cfns,
                          fused_fns=ffns,
                          config=EngineConfig(**base, trace=trace))

    def bench(srv):
        t0 = time.perf_counter()
        out = srv.serve(queue())
        jax.block_until_ready(srv.cache)
        dt = time.perf_counter() - t0
        return out, sum(len(v) for v in out.values()) / dt

    bench(fresh(False))
    bench(fresh(True))                             # warm the jits
    ratios = []
    plain_tps = obs_tps = 0.0
    srv_t = None
    for _ in range(reps):
        out_p, tps_p = bench(fresh(False))
        srv_t = fresh(True)
        out_t, tps_t = bench(srv_t)
        assert out_t == out_p, "traced serving diverged from plain"
        plain_tps, obs_tps = max(plain_tps, tps_p), max(obs_tps, tps_t)
        ratios.append(tps_p / tps_t)
    ratio = sorted(ratios)[len(ratios) // 2]

    # sample artifacts from the last traced rep: a Perfetto-loadable
    # trace + the registry snapshot (CI uploads both)
    doc = srv_t.export_trace(trace_out)
    srv_t.export_metrics(metrics_out)
    events = doc["traceEvents"]
    n_spans = sum(1 for e in events if e.get("ph") == "X")
    n_retired = sum(1 for e in events
                    if e.get("ph") == "i" and e.get("name") == "retire")
    assert n_retired == n_requests, (n_retired, n_requests)
    ttft = srv_t.obs.ttft_s

    result = {
        "requests": n_requests, "slots": n_slots, "chunk": chunk,
        "plain_tok_per_s": round(plain_tps, 2),
        "traced_tok_per_s": round(obs_tps, 2),
        "obs_overhead_ratio": round(ratio, 3),
        "trace_events": len(events),
        "trace_spans": n_spans,
        "ttft_mean_s": round(float(ttft.value), 4) if ttft.count else 0.0,
        "obs_parity": True,
    }
    print("\n== Serving: telemetry (trace+metrics) overhead ==")
    print("name,value")
    print(f"serve_plain_tok_per_s,{plain_tps:.2f}")
    print(f"serve_traced_tok_per_s,{obs_tps:.2f}")
    print(f"obs_overhead_ratio,{result['obs_overhead_ratio']}")
    print(f"trace_events,{len(events)} (spans {n_spans})")
    print(f"artifacts,{trace_out} {metrics_out}")
    print("parity,exact")
    return result


def run_qos(_settings=None, *, n_a: int = 6, n_b: int = 8,
            a_prompt: int = 6, a_new: int = 8,
            b_prompt: int = 24, b_new: int = 4,
            n_slots: int = 4, cache_len: int = 64, page_block: int = 8,
            chunk: int = 8, pool_blocks: int = 11):
    """Noisy-neighbor isolation: weighted fairness + priority preemption.

    Tenant "interactive" submits ``n_a`` short requests behind tenant
    "batch"'s burst of ``n_b`` long prompts; the pool holds far fewer
    blocks than the live set wants, so decoders get parked (recompute)
    whenever someone else needs a block. The FCFS baseline runs that
    mechanism policy-free — every request priority 0, no tenant weights —
    so the interactive requests queue behind the burst and, once
    running, are themselves evicted by batch growth. The QoS run gives
    the interactive tenant a 4x DRR weight and a higher priority than
    the batch tenant: admission skips ahead of the burst and pool
    pressure parks batch decoders instead, so the interactive tenant's
    token cadence is flat while the batch tenant absorbs the churn.

    All latency is in engine steps: every emitted token is tagged with
    the ``step()`` call that produced it, and a request's gap sequence
    is first-token-step (its queueing delay) followed by the step gaps
    between consecutive tokens (eviction/replay stalls). Host-side
    scheduling is deterministic, so the gated ratio reproduces exactly
    across machines. Parity: both pressured runs must emit token-for-
    token what a pressure-free reference (full pool, preemption off,
    no QoS) emits — greedy and seeded-sampled alike.
    """
    from repro.serve.api import QoSConfig
    cfg = get_smoke_config("qwen3_8b").reduced(vocab=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b_prompts = [rng.integers(0, cfg.vocab, size=b_prompt).astype(np.int32)
                 for _ in range(n_b)]
    a_prompts = [rng.integers(0, cfg.vocab, size=a_prompt).astype(np.int32)
                 for _ in range(n_a)]

    def queue(prio: bool):
        # burst first, interactive behind it — the adversarial order.
        # Odd-indexed requests sample (seeded) so parity covers the
        # seeded resume path, not just greedy.
        subs = []
        for i, p in enumerate(b_prompts):
            subs.append((p, SamplingParams(
                max_new=b_new, priority=0, tenant="batch",
                temperature=0.8 if i % 2 else 0.0, top_k=8,
                seed=200 + i)))
        for i, p in enumerate(a_prompts):
            subs.append((p, SamplingParams(
                max_new=a_new, priority=2 if prio else 0,
                tenant="interactive",
                temperature=0.8 if i % 2 else 0.0, top_k=8,
                seed=100 + i)))
        return subs

    base = dict(n_slots=n_slots, cache_len=cache_len, paged=True,
                page_block=page_block, chunked_prefill=True, chunk=chunk)

    def drive(ecfg, prio: bool):
        srv = make_engine(model, params, config=ecfg)
        rids = [srv.add_request(p, sp) for p, sp in queue(prio)]
        tok_steps: dict = {r: [] for r in rids}
        out: dict = {}
        step = 0
        while srv.has_unfinished():
            step += 1
            for o in srv.step():
                tok_steps[o.rid] += [step] * len(o.deltas)
                if o.finished:
                    out[o.rid] = o.token_ids
        a_rids = rids[n_b:]
        gaps = np.concatenate(
            [np.diff(np.asarray([0] + tok_steps[r])) for r in a_rids])
        by_idx = {i: out[r] for i, r in enumerate(rids)}
        tstats = srv.stats().get("tenants", {})
        return by_idx, gaps, step, tstats

    ref_out, _, _, _ = drive(EngineConfig(**base), prio=False)
    fcfs_out, fcfs_gaps, fcfs_steps, fcfs_t = drive(
        EngineConfig(**base, pool_blocks=pool_blocks,
                     preemption="recompute"), prio=False)
    qos_out, qos_gaps, qos_steps, qos_t = drive(
        EngineConfig(**base, pool_blocks=pool_blocks,
                     preemption="recompute",
                     qos=QoSConfig(tenant_weights=(("interactive", 4.0),
                                                   ("batch", 1.0)),
                                   quantum=chunk)), prio=True)

    parity = fcfs_out == ref_out and qos_out == ref_out
    fcfs_p99 = float(np.percentile(fcfs_gaps, 99))
    qos_p99 = float(np.percentile(qos_gaps, 99))
    fcfs_a_pre = fcfs_t.get("interactive", {}).get("preemptions", 0)
    qos_a_pre = qos_t.get("interactive", {}).get("preemptions", 0)
    qos_b_pre = qos_t.get("batch", {}).get("preemptions", 0)
    result = {
        "interactive_requests": n_a, "batch_requests": n_b,
        "batch_prompt": b_prompt, "pool_blocks": pool_blocks,
        "fcfs_a_p99_gap_steps": round(fcfs_p99, 2),
        "qos_a_p99_gap_steps": round(qos_p99, 2),
        "qos_isolation_ratio": round(qos_p99 / fcfs_p99, 3),
        "fcfs_a_ttft_steps_mean": round(float(np.mean(
            [g[0] for g in np.split(fcfs_gaps, n_a)])), 2),
        "qos_a_ttft_steps_mean": round(float(np.mean(
            [g[0] for g in np.split(qos_gaps, n_a)])), 2),
        "fcfs_a_preempted": fcfs_a_pre,
        "qos_a_preempted": qos_a_pre,
        "qos_b_preempted": qos_b_pre,
        "fcfs_total_steps": fcfs_steps, "qos_total_steps": qos_steps,
        # the two halves of the isolation claim, as hard invariants:
        # the policy protected the interactive tenant outright, and the
        # mechanism it relies on actually engaged under this pressure
        "qos_a_protected": qos_a_pre == 0,
        "qos_preemption_engaged": qos_b_pre > 0,
        "qos_parity": parity,
    }
    print("\n== Serving: multi-tenant QoS under a noisy neighbor ==")
    print("name,value")
    print(f"fcfs_a_p99_gap_steps,{fcfs_p99:.2f}")
    print(f"qos_a_p99_gap_steps,{qos_p99:.2f}")
    print(f"qos_isolation_ratio,{result['qos_isolation_ratio']}")
    print(f"a_preempted_fcfs,{fcfs_a_pre}")
    print(f"a_preempted_qos,{qos_a_pre}")
    print(f"b_preempted_qos,{qos_b_pre}")
    print(f"parity,{'exact' if parity else 'BROKEN'}")
    assert parity, "QoS/preemption run diverged from pressure-free serving"
    return result


def main(out_path: str = "BENCH_serve.json"):
    results = {
        "serve_mixture": run(),
        "serve_paged": run_paged(),
        "serve_chunked": run_chunked(),
        "serve_prefix": run_prefix(),
        "serve_stream": run_stream(),
        "serve_sanitize": run_sanitize(),
        "serve_speculative": run_speculative(),
        "serve_obs": run_obs(),
        "serve_qos": run_qos(),
    }
    with open(out_path, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    print(f"\nwrote {out_path}")
    return results


if __name__ == "__main__":
    main()
