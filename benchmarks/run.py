"""Benchmark harness — one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run              # full suite
    PYTHONPATH=src python -m benchmarks.run --quick      # reduced steps
    PYTHONPATH=src python -m benchmarks.run --only table7 kernels

Benchmarks:
    fig1      clustering structure (Figure 1)
    llava     Tables 1–2 (LLaVA parity + router-stress)
    internvl  Tables 4–6 (InternVL parity, hallucination-proxy, routing)
    table7    number of experts K ∈ {2,4,6}
    table8    vision-encoder capacity
    table9    clustering algorithm (1-stage vs 2-stage)
    kernels   Pallas kernel microbenches (CSV: name,us_per_call,derived)
    serve     looped vs stacked mixture decode steps/sec (K=4)
    roofline  aggregate the dry-run roofline artifacts
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced steps (CI-sized)")
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--out", default="experiments/bench_results.json")
    args = ap.parse_args()

    from .common import BenchSettings
    s = BenchSettings(steps=60 if args.quick else 240,
                      eval_batches=4 if args.quick else 8,
                      samples=1024 if args.quick else 2048)

    from . import (fig1_clustering, kernels_bench, roofline_report,
                   serve_bench, table7_num_experts, table8_vision_encoder,
                   table9_clustering, tables_internvl, tables_llava,
                   topk_ablation)
    suite = {
        "fig1": lambda: fig1_clustering.run(s),
        "llava": lambda: tables_llava.run(s),
        "internvl": lambda: tables_internvl.run(s),
        "table7": lambda: table7_num_experts.run(s),
        "table8": lambda: table8_vision_encoder.run(s),
        "table9": lambda: table9_clustering.run(s),
        "topk": lambda: topk_ablation.run(s),
        "kernels": lambda: kernels_bench.run(s),
        "serve": lambda: serve_bench.run(s),
        "serve_paged": lambda: serve_bench.run_paged(s),
        "roofline": lambda: roofline_report.run(s),
    }
    selected = args.only or list(suite)
    results = {}
    for name in selected:
        t0 = time.time()
        print(f"\n########## benchmark: {name} ##########", flush=True)
        try:
            results[name] = {"result": suite[name](),
                             "wall_s": round(time.time() - t0, 1),
                             "status": "ok"}
        except Exception as e:  # keep the suite going; report at the end
            import traceback
            traceback.print_exc()
            results[name] = {"status": "error", "error": str(e)}
        print(f"[{name}] {results[name]['status']} "
              f"in {time.time()-t0:.1f}s", flush=True)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1, default=str)
    bad = [n for n, r in results.items() if r["status"] != "ok"]
    print(f"\nbenchmarks complete → {args.out}; "
          f"{len(selected)-len(bad)}/{len(selected)} ok"
          + (f"; FAILED: {bad}" if bad else ""))
    if bad:
        sys.exit(1)


if __name__ == "__main__":
    main()
