"""Figure 1 analogue: structure of the balanced spherical k-means
partition (the paper shows a t-SNE; headless here, we report the structural
statistics the figure conveys: balanced main clusters composed of coherent
sub-groups)."""
from __future__ import annotations

import numpy as np

from repro.core.clustering import spherical_balanced_kmeans
from repro.data.partition import partition_dataset

from .common import BenchSettings, make_corpus


def run(s: BenchSettings):
    corpus = make_corpus(s)
    feats = corpus.all_features()
    part = partition_dataset(feats, 2, seed=s.seed)
    labels = corpus.labels
    print("\n== Figure 1 (clustering structure, K=2) ==")
    rows = {}
    for k, shard in enumerate(part.shards):
        comp = np.bincount(labels[shard], minlength=s.n_latent)
        # sub-structure: fine clusters inside the coarse cluster
        fine = spherical_balanced_kmeans(feats[shard],
                                         min(8, len(shard) // 4 or 1),
                                         balanced=False, seed=k)
        intra = float(np.mean(fine.sims.max(1)))
        rows[f"cluster_{k}"] = {
            "size": int(len(shard)),
            "latent_composition": comp.tolist(),
            "fine_subclusters": int(fine.centroids.shape[0]),
            "mean_intra_sim": round(intra, 4),
        }
        print(f"cluster {k}: size={len(shard)} latent={comp.tolist()} "
              f"sub-groups={fine.centroids.shape[0]} "
              f"intra-sim={intra:.3f}")
    sims01 = float(part.clustering.centroids[0] @ part.clustering.centroids[1])
    print(f"inter-centroid cosine = {sims01:.3f} "
          "(well-separated main clusters of coherent sub-groups)")
    rows["inter_centroid_cos"] = sims01
    return rows
