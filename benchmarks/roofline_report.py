"""Aggregate the dry-run artifacts (experiments/dryrun/*.json) into the
§Roofline table: three terms, bottleneck, MODEL_FLOPS/HLO_FLOPs ratio, and
cross-pod traffic per (arch × shape × mesh × mode).

Two artifact kinds per case:
  <case>.json        raw lowering of the scanned (production) program —
                     proves compile; its cost numbers undercount scanned
                     stacks (XLA counts a while body once).
  <case>.probe.json  depth-corrected terms from two unrolled shallow
                     compiles, f(G) = outside + G·per_group (preferred).
"""
from __future__ import annotations

import glob
import json
import os

HEADER = ("case", "status", "src", "bottleneck", "compute_s", "memory_s",
          "collective_s", "useful_flops", "xpod_GB", "compile_s")


def load_records(dirpath: str = "experiments/dryrun"):
    raw, probe = {}, {}
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        (probe if path.endswith(".probe.json") else raw)[rec["case"]] = rec
    return raw, probe


def merged_rows(dirpath: str = "experiments/dryrun"):
    raw, probe = load_records(dirpath)
    rows = []
    for case in sorted(set(raw) | set(probe)):
        r = raw.get(case)
        p = probe.get(case)
        best = p if (p and p.get("status") == "ok") else r
        if best is None:
            continue
        if best["status"] != "ok":
            rows.append({"case": case, "status": best["status"],
                         "reason": best.get("reason", best.get("error"))})
            continue
        rl = best["roofline"]
        xpod = (best.get("xpod_corrected")
                if "xpod_corrected" in best
                else best.get("collectives", {}).get("cross_pod_bytes", 0))
        rows.append({
            "case": case, "status": "ok",
            "src": "probe" if best is p else "raw",
            "bottleneck": rl["bottleneck"],
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"],
            "useful_flops": rl["useful_flops_ratio"],
            "xpod_GB": (xpod or 0) / 1e9,
            "compile_s": r["compile_s"] if (r and "compile_s" in r)
            else best.get("wall_s", 0),
            "lowered_ok": bool(r and r["status"] == "ok"),
        })
    return rows


def run(_settings=None, dirpath: str = "experiments/dryrun"):
    rows = merged_rows(dirpath)
    if not rows:
        print("(no dry-run artifacts found — run repro.launch.dryrun first)")
        return []
    print("\n== Roofline table (from compiled dry-run artifacts) ==")
    print(",".join(HEADER))
    for r in rows:
        if r["status"] != "ok":
            print(f"{r['case']},{r['status']},,,,,,,,")
            continue
        print(",".join(str(x) for x in (
            r["case"], "ok", r["src"], r["bottleneck"],
            round(r["compute_s"], 4), round(r["memory_s"], 4),
            round(r["collective_s"], 4), round(r["useful_flops"], 3),
            round(r["xpod_GB"], 2), r["compile_s"])))
    ok = sum(1 for r in rows if r["status"] == "ok")
    nsk = sum(1 for r in rows if r["status"] == "skipped")
    nerr = len(rows) - ok - nsk
    print(f"# {ok} ok / {nsk} skipped / {nerr} error")
    return rows
