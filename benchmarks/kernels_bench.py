"""Kernel microbenchmarks: us/call for each Pallas kernel (interpret mode
on CPU — structural timing only; real perf comes from the TPU dry-run
roofline) and for the jnp reference, plus the derived ratio."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def run(_settings=None):
    key = jax.random.PRNGKey(0)
    rows = []

    B, S, H, KV, dh = 1, 256, 4, 2, 64
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, S, H, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, dh), jnp.float32)
    rows.append(("flash_attention_pallas",
                 _time(lambda a, b, c: ops.flash_attention(a, b, c), q, k, v),
                 "interpret"))
    rows.append(("flash_attention_ref",
                 _time(jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c)),
                       q, k, v), "xla_cpu"))

    qd = q[:, 0]
    pos = jnp.asarray([S - 1])
    rows.append(("decode_attention_pallas",
                 _time(lambda a, b, c, p: ops.decode_attention(a, b, c, p),
                       qd, k, v, pos), "interpret"))
    rows.append(("decode_attention_ref",
                 _time(jax.jit(lambda a, b, c, p:
                               ref.decode_attention_ref(a, b, c, p)),
                       qd, k, v, pos), "xla_cpu"))

    x = jax.random.normal(ks[3], (256, 128), jnp.float32)
    c = jax.random.normal(ks[0], (8, 128), jnp.float32)
    rows.append(("router_scores_pallas",
                 _time(lambda a, b: ops.router_scores(a, b, 10.0), x, c),
                 "interpret"))
    rows.append(("router_scores_ref",
                 _time(jax.jit(lambda a, b: ref.router_scores_ref(a, b, 10.0)),
                       x, c), "xla_cpu"))

    qc = jax.random.normal(ks[1], (1, 4, 64, 2, 32), jnp.float32)
    vc = jax.random.normal(ks[2], (1, 4, 64, 2, 32), jnp.float32)
    cum = jnp.cumsum(-jnp.abs(jax.random.normal(ks[3], (1, 4, 64, 2))) * 0.1,
                     axis=2)
    rows.append(("chunk_scan_pallas",
                 _time(lambda a, b, c_, d: ops.chunk_scan(a, b, c_, d),
                       qc, qc, vc, cum), "interpret"))
    rows.append(("chunk_scan_ref",
                 _time(jax.jit(lambda a, b, c_, d:
                               ref.chunk_scan_ref(a, b, c_, d)),
                       qc, qc, vc, cum), "xla_cpu"))

    # paged decode: page-size x blocks-per-step sweep over one 128-position
    # logical span. bps > 1 folds several logical blocks into one grid
    # step (fewer grid steps, same DMA volume — past-horizon sub-tiles
    # clamp to a revisited index and skip their copy); every timed config
    # is first checked against the jnp oracle so the sweep can't quietly
    # drift from the definition.
    B, H, KV, dh, span = 4, 4, 2, 32, 128
    kp = jax.random.split(key, 3)
    qp = jax.random.normal(kp[0], (B, H, dh), jnp.float32)
    ppos = jnp.asarray([span - 1, span // 2, 7, 0][:B])
    # jit the reference once: wrapping a fresh lambda per loop iteration
    # defeats the trace cache and retraces every rep (repro-lint
    # retrace-hazard)
    jit_ref = jax.jit(ref.paged_decode_attention_ref)
    for block in (8, 16, 32):
        NB = span // block
        P = B * NB + 2
        kpool = jax.random.normal(kp[1], (P, block, KV, dh), jnp.float32)
        vpool = jax.random.normal(kp[2], (P, block, KV, dh), jnp.float32)
        bt = jnp.arange(1, B * NB + 1, dtype=jnp.int32).reshape(B, NB)
        oracle = ref.paged_decode_attention_ref(qp, kpool, vpool, ppos, bt)
        for bps in (1, 2, 4):
            got = ops.paged_decode_attention(qp, kpool, vpool, ppos, bt,
                                             blocks_per_step=bps)
            assert jnp.allclose(got, oracle, atol=1e-5), (block, bps)
            rows.append((f"paged_decode_b{block}_bps{bps}_pallas",
                         _time(lambda a, b_, c_, p, t, n=bps:
                               ops.paged_decode_attention(
                                   a, b_, c_, p, t, blocks_per_step=n),
                               qp, kpool, vpool, ppos, bt), "interpret"))
        rows.append((f"paged_decode_b{block}_ref",
                     _time(jit_ref, qp, kpool, vpool, ppos, bt),
                     "xla_cpu"))

    print("\n== Kernel microbenchmarks (CPU; kernels in interpret mode) ==")
    print("name,us_per_call,derived")
    for name, us, tag in rows:
        print(f"{name},{us:.1f},{tag}")
    return rows
