"""Table 9 analogue: the impact of the clustering algorithm —
single-stage balanced spherical k-means (paper main) vs the 2-stage variant
(fine unbalanced k=1024 → coarse balanced; McAllister et al. style)."""
from __future__ import annotations

from .common import BenchSettings, fmt_row, run_parity


def run(s: BenchSettings):
    rows = {}
    for alg, name in (("balanced", "balanced_kmeans"),
                      ("two_stage", "two_stage_balanced_kmeans")):
        s_alg = BenchSettings(**{**s.__dict__, "clustering": alg})
        res = run_parity(s_alg, K=2)
        rows[name] = res.experts
        print(fmt_row(name, res.experts), flush=True)
    print("\n== Table 9 (impact of clustering algorithm) ==")
    for n, m in rows.items():
        print(fmt_row(n, m))
    return rows
