"""Beyond-paper ablation: serving top-k. The paper fixes k=1 (compute-
matched with dense). The theory (Eq. 27) says the EXACT recomposition uses
all K experts with posterior weights — so k>1 should interpolate between
the compute-matched point and the exact mixture. We measure ensemble NLL
at k = 1, 2 (=K) and the uniform-mixture control."""
from __future__ import annotations

import numpy as np

from repro.core.router import CentroidRouter, RouterConfig

from .common import BenchSettings, eval_metrics, fmt_row, run_parity


def run(s: BenchSettings):
    res = run_parity(s, K=2)
    rows = {"dense_baseline": {k: v for k, v in res.dense.items()
                               if not k.startswith("slice")}}
    base_router = res.partition.router
    for k in (1, 2):
        router = CentroidRouter(
            base_router.centroids,
            RouterConfig(temperature=s.router_temperature, top_k=k))
        m = eval_metrics(res.model, res.expert_params, router,
                         res.corpus, s)
        rows[f"top{k}_routing"] = {kk: v for kk, v in m.items()
                                   if not kk.startswith("slice")}
    uni = eval_metrics(res.model, res.expert_params, None, res.corpus, s,
                       forced_weights=np.full((2,), 0.5))
    rows["uniform_mixture"] = {k: v for k, v in uni.items()
                               if not k.startswith("slice")}
    print("\n== Beyond-paper: serving top-k ablation ==")
    for n, m in rows.items():
        print(fmt_row(n, m))
    return rows
