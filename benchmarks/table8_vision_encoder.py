"""Table 8 analogue: the impact of the vision encoder used for
partitioning/routing.

Paper: ViT-L/14 ≳ ViT-B/16 > RN50. The offline analogue varies the frozen
feature extractor's *capacity* as its feature dimensionality (64/32/8):
weaker features ⇒ worse clusters ⇒ worse routing ⇒ lower ensemble scores.
"""
from __future__ import annotations

from .common import BenchSettings, fmt_row, run_parity

ENCODERS = {"vitL14_proxy_d64": 64, "vitB16_proxy_d32": 32,
            "rn50_proxy_d8": 8}


def run(s: BenchSettings):
    rows = {}
    for name, dim in ENCODERS.items():
        s_enc = BenchSettings(**{**s.__dict__, "feature_dim": dim})
        res = run_parity(s_enc, K=2)
        rows[name] = res.experts
        print(fmt_row(name, res.experts), flush=True)
    print("\n== Table 8 (impact of vision encoder capacity) ==")
    for n, m in rows.items():
        print(fmt_row(n, m))
    return rows
