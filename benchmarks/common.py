"""Shared harness for the paper-table benchmarks.

The paper's tables compare a compute-matched dense baseline against K
decentralized experts on multimodal QA benchmarks. Offline, the analogue is
the synthetic clustered corpus (repro/data/synthetic.py): per-cluster token
distributions play the role of benchmark task domains, and the metrics are
teacher-forced next-token accuracy / NLL — overall and per benchmark slice.
Absolute VQA scores do not transfer at this scale; the *claims* (parity,
specialization, K-fragmentation, encoder sensitivity) do.

Compute matching follows §6.1: experts use per-device batch = dense/K with
the same number of optimization steps.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.core.router import RouterConfig
from repro.data.partition import Partition, partition_dataset
from repro.data.pipeline import LoaderConfig, ShardLoader
from repro.data.synthetic import SyntheticConfig, SyntheticMultimodal
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import (TrainConfig, init_train_state,
                                 train_host_loop)

VOCAB = 64
SEQ = 48


@dataclass
class BenchSettings:
    arch: str = "qwen3_8b"
    steps: int = 240
    dense_batch: int = 16
    n_latent: int = 4
    feature_dim: int = 32
    samples: int = 2048
    seed: int = 0
    eval_batches: int = 8
    eval_batch: int = 32
    clustering: str = "balanced"
    router_temperature: float = 10.0


def make_corpus(s: BenchSettings, feature_dim: Optional[int] = None
                ) -> SyntheticMultimodal:
    return SyntheticMultimodal(SyntheticConfig(
        vocab=VOCAB, seq_len=SEQ, feature_dim=feature_dim or s.feature_dim,
        n_latent=s.n_latent, n_samples=s.samples, seed=s.seed))


def _to_jax(batch, cfg):
    out = {"tokens": jnp.asarray(batch["tokens"]),
           "labels": jnp.asarray(batch["labels"])}
    if cfg.family == "vlm":
        # stub frontend: patch embeddings derived deterministically from the
        # routing features (broadcast to n_patches with positional jitter)
        f = batch["features"]
        rng = np.random.default_rng(0)
        proj = rng.standard_normal((f.shape[1], cfg.n_patches,
                                    cfg.vision_dim)).astype(np.float32) * 0.3
        out["patches"] = jnp.asarray(np.einsum("bd,dpv->bpv", f, proj))
    return out


def train_model(model, corpus, subset, batch, steps, seed, offset=0):
    opt = AdamWConfig(lr=1e-3, warmup_steps=max(steps // 20, 5),
                      total_steps=steps)
    tc = TrainConfig(opt=opt)
    loader = ShardLoader(corpus, LoaderConfig(batch_size=batch),
                         subset=subset, offset=offset)
    if model.cfg.family == "vlm":
        loader = _VLMLoader(loader, model.cfg)
    state = init_train_state(model, jax.random.PRNGKey(seed), opt)
    state, hist = train_host_loop(model, state, loader, steps, tc,
                                  log_every=max(steps // 4, 1))
    return state, hist


class _VLMLoader:
    def __init__(self, inner, cfg):
        self.inner, self.cfg = inner, cfg

    def __iter__(self):
        return self

    def __next__(self):
        b = next(self.inner)
        jb = _to_jax(b, self.cfg)
        return {k: np.asarray(v) for k, v in jb.items()}


def eval_metrics(model, params_list, router, corpus, s: BenchSettings,
                 *, forced_weights: Optional[np.ndarray] = None
                 ) -> Dict[str, float]:
    """Teacher-forced eval of the (possibly single-member) ensemble.

    Returns overall acc/nll + per-latent-cluster slice accs. Eval batches
    come from a disjoint step range (offset 1e6)."""
    cfg = model.cfg
    K = len(params_list)
    fwd = jax.jit(lambda p, b: model.forward(p, b))
    tot_correct = tot_tokens = 0.0
    tot_nll = 0.0
    slice_correct: Dict[int, float] = {}
    slice_tokens: Dict[int, float] = {}
    for i in range(s.eval_batches):
        raw = corpus.sample_batch(s.eval_batch, step=1_000_000 + i)
        jb = _to_jax(raw, cfg)
        feats = jnp.asarray(raw["features"])
        if forced_weights is not None:
            w = jnp.asarray(np.tile(forced_weights, (s.eval_batch, 1)))
        elif K == 1:
            w = jnp.ones((s.eval_batch, 1))
        else:
            w = router.route(feats)                      # (B, K)
        probs = None
        for k, params in enumerate(params_list):
            logits = fwd(params, jb)
            if cfg.family == "vlm":
                logits = logits[:, cfg.n_patches:]
            pk = jax.nn.softmax(logits.astype(jnp.float32), -1)
            contrib = w[:, k][:, None, None] * pk
            probs = contrib if probs is None else probs + contrib
        labels = jb["labels"][:, 1:]
        p = probs[:, :-1]
        pred = jnp.argmax(p, -1)
        correct = np.asarray((pred == labels).astype(np.float32))
        nll = -np.log(np.asarray(
            jnp.take_along_axis(p, labels[..., None], -1))[..., 0] + 1e-30)
        tot_correct += correct.sum()
        tot_tokens += correct.size
        tot_nll += nll.sum()
        for c in range(s.n_latent):
            m = raw["cluster"] == c
            if m.any():
                slice_correct[c] = slice_correct.get(c, 0) + correct[m].sum()
                slice_tokens[c] = slice_tokens.get(c, 0) + correct[m].size
    out = {"acc": tot_correct / tot_tokens, "nll": tot_nll / tot_tokens}
    for c in sorted(slice_correct):
        out[f"slice{c}_acc"] = slice_correct[c] / slice_tokens[c]
    return out


@dataclass
class ParityResult:
    dense: Dict[str, float]
    experts: Dict[str, float]
    partition: Partition
    expert_params: list
    dense_params: object
    model: object
    corpus: object
    wall_s: float


def run_parity(s: BenchSettings, K: int = 2) -> ParityResult:
    """Train dense + K experts (compute-matched) and evaluate both."""
    t0 = time.time()
    cfg = get_smoke_config(s.arch).reduced(vocab=VOCAB)
    model = build_model(cfg)
    corpus = make_corpus(s)

    dense_state, _ = train_model(model, corpus, None, s.dense_batch,
                                 s.steps, s.seed)
    part = partition_dataset(
        corpus.all_features(), K, algorithm=s.clustering,
        router_config=RouterConfig(temperature=s.router_temperature,
                                   top_k=1), seed=s.seed)
    expert_params = []
    for k in range(K):
        st, _ = train_model(model, corpus, part.shards[k],
                            max(s.dense_batch // K, 1), s.steps,
                            s.seed + 100 + k, offset=10_000 * k)
        expert_params.append(st["params"])

    dense_m = eval_metrics(model, [dense_state["params"]], None, corpus, s)
    exp_m = eval_metrics(model, expert_params, part.router, corpus, s)
    return ParityResult(dense=dense_m, experts=exp_m, partition=part,
                        expert_params=expert_params,
                        dense_params=dense_state["params"], model=model,
                        corpus=corpus, wall_s=time.time() - t0)


def fmt_row(name: str, metrics: Dict[str, float]) -> str:
    cols = " ".join(f"{k}={v:.4f}" for k, v in metrics.items())
    return f"{name:24s} {cols}"
