"""Table 7 analogue: the impact of the number of experts (K = 2, 4, 6).

Paper finding: K=4 stays comparable to dense; K=6 shows fragmentation
regression (fewer samples per expert at fixed total data). Compute-matched
per §6.2 (per-expert batch = dense/K, same steps)."""
from __future__ import annotations

from .common import BenchSettings, fmt_row, run_parity


def run(s: BenchSettings):
    rows = {}
    for K in (2, 4, 6):
        res = run_parity(s, K=K)
        rows[f"{K}_experts"] = res.experts
        if "dense_baseline" not in rows:
            rows["dense_baseline"] = res.dense
        print(fmt_row(f"{K}_experts", res.experts), flush=True)
    print("\n== Table 7 (impact of number of experts) ==")
    for n, m in rows.items():
        print(fmt_row(n, m))
    return rows
