"""Walk through the paper's §4 theory numerically, step by step:

1. discrete-time DFM: the AR path satisfies the Continuity Equation;
2. the sampling rule generates the path (1-sparsity ⇒ generation);
3. a 2-position counterexample shows why 1-sparsity is necessary;
4. a *trained tiny LM*'s next-token conditionals, plugged in as the
   velocity, reach the empirical target distribution — connecting the
   theory to the production serving loop.

    PYTHONPATH=src python examples/theory_walkthrough.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core.autoregressive import (ar_marginal_velocity, ar_path,
                                       next_token_conditional)
from repro.core.dfm import (apply_sampling_rule, continuity_residual,
                            enumerate_states, is_one_sparse, n_states,
                            neighbor_table, encode)

d, N, P = 3, 3, 0
mask = d - 1
rng = np.random.default_rng(0)
states = enumerate_states(d, N)
q = rng.random(n_states(d, N))
q[(states == mask).any(1)] = 0.0
q /= q.sum()
q = jnp.asarray(q)

print("== 1–2. AR path: continuity + generation ==")
path = ar_path(q, P, d, N, mask)
nbr = neighbor_table(d, N)
p = path.marginal(0)
for t in range(N):
    u = ar_marginal_velocity(q, P, t, d, N, mask)
    r = float(jnp.abs(continuity_residual(p, path.marginal(t + 1), u,
                                          nbr)).max())
    p = apply_sampling_rule(p, u, nbr)
    print(f"  t={t}: 1-sparse={is_one_sparse(u, p)}  CE residual={r:.2e}")
print(f"  final TV(p_T, q) = {0.5 * float(jnp.abs(p - q).sum()):.2e} ✓\n")

print("== 3. Why 1-sparsity is necessary ==")
d2 = 2
nbr2 = neighbor_table(d2, 2)
p0 = jnp.zeros(4).at[0].set(1.0)
p1 = jnp.zeros(4).at[1].set(0.5).at[2].set(0.5)
u_bad = np.zeros((2, d2, 4))
u_bad[:, 1, 0], u_bad[:, 0, 0] = 0.5, -0.5
u_bad = jnp.asarray(u_bad)
ce = float(jnp.abs(continuity_residual(p0, p1, u_bad, nbr2)).max())
pushed = apply_sampling_rule(p0, u_bad, nbr2)
print(f"  2-position velocity: CE residual={ce:.1e} (holds!) but "
      f"TV(pushed, p1)={0.5*float(jnp.abs(pushed-p1).sum()):.3f} ≠ 0\n")

print("== 4. A learned LM as the generating velocity ==")
# fit next-token conditionals by counting (the LM limit) and decode with the
# sampling rule: the chain must land on the empirical distribution.
p = path.marginal(0)
for t in range(N):
    u = np.zeros((N, d, n_states(d, N)))
    for z in range(n_states(d, N)):
        if float(p[z]) <= 0:
            continue
        prefix = states[z, :t]
        cond = next_token_conditional(q, prefix, d, N)   # ≈ trained LM head
        u[t, :, z] = cond
        u[t, mask, z] -= 1.0
    p = apply_sampling_rule(p, jnp.asarray(u), nbr)
print(f"  TV(decoded, q) = {0.5 * float(jnp.abs(p - q).sum()):.2e} ✓")
print("\ntheory walkthrough complete ✓")
