"""End-to-end driver: decentralized training of a ~100M-class model family
for a few hundred steps (the deliverable-(b) end-to-end run).

Uses the xLSTM-125M *family* at reduced width (CPU container) with the full
pipeline: feature extraction → balanced k-means partition → K independent
expert runs (own data/optimizer/checkpoints, zero communication) → router
saved for serving. On a TPU cluster the identical flow runs the full config
with each expert on its own pod (see repro/launch/dryrun.py for the mesh).

    PYTHONPATH=src python examples/train_decentralized.py [--steps 300]
"""
import argparse
import subprocess
import sys

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="xlstm_125m")
    ap.add_argument("--experts", type=int, default=2)
    ap.add_argument("--out", default="/tmp/repro_decentralized")
    args = ap.parse_args()

    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", args.arch, "--mode", "decentralized",
           "--experts", str(args.experts), "--steps", str(args.steps),
           "--batch", "16", "--samples", "2048", "--out", args.out]
    print("running:", " ".join(cmd))
    raise SystemExit(subprocess.call(cmd))
