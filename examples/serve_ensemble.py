"""Serve a trained decentralized ensemble with batched requests.

Requires a run directory from examples/train_decentralized.py (or
repro.launch.train). Routes each request on its frozen-encoder features,
decodes with the top-1 expert (compute-matched, paper §5.2), and reports
throughput + routing stats. Use --strategy mixture for the exact Eq. 27
top-k probability mixture.

The launcher drives the incremental serving API (``EngineConfig`` +
``add_request``/``step``): pass ``--stream`` to watch every request's
token deltas arrive as they decode, and ``--stop-token ID`` (repeatable)
to retire requests early with ``finish_reason="stop"``.

    PYTHONPATH=src python examples/train_decentralized.py --steps 100
    PYTHONPATH=src python examples/serve_ensemble.py
    PYTHONPATH=src python examples/serve_ensemble.py --stream \
        --stop-token 7
"""
import argparse
import subprocess
import sys

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--run", default="/tmp/repro_decentralized")
    ap.add_argument("--arch", default="xlstm_125m")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--strategy", choices=["top1", "mixture"],
                    default="top1")
    ap.add_argument("--stream", action="store_true",
                    help="print per-token deltas from the streaming API")
    ap.add_argument("--stop-token", type=int, action="append", default=None,
                    help="stop/eos token id (repeatable)")
    args = ap.parse_args()

    cmd = [sys.executable, "-m", "repro.launch.serve",
           "--run", args.run, "--arch", args.arch,
           "--requests", str(args.requests), "--strategy", args.strategy,
           "--new-tokens", "24"]
    if args.stream:
        cmd.append("--stream")
    for t in args.stop_token or ():
        cmd += ["--stop-token", str(t)]
    print("running:", " ".join(cmd))
    raise SystemExit(subprocess.call(cmd))
